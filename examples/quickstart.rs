//! Quickstart: compute the optimal design for a small heterogeneous
//! cluster and run one coded MapReduce job end to end.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```

use hetcdc::engine::{Engine, NativeBackend, PlacementStrategy};
use hetcdc::model::cluster::ClusterSpec;
use hetcdc::model::job::{JobSpec, ShuffleMode};
use hetcdc::theory::load;

fn main() {
    // A 3-node cluster with heterogeneous storage: 6, 7 and 7 files of
    // capacity, processing N = 12 input files (the paper's Fig-3 example).
    let cluster = ClusterSpec::ec2_like_3node(12);
    let n_files = 12;
    let p = cluster.params3(n_files).expect("valid parameters");

    println!("cluster storage (M1,M2,M3) = {:?}, files N = {n_files}", cluster.storage());
    println!("Theorem 1: regime {}, minimum load L* = {} IV equations", load::classify(&p), load::lstar(&p));
    println!("uncoded baseline: {} -> saving {:.0}%\n", load::uncoded(&p), 100.0 * load::saving(&p) / load::uncoded(&p));

    // Run a TeraSort-style job twice: coded vs uncoded shuffle.
    let job = JobSpec::terasort(n_files);
    let mut backend = NativeBackend;
    let mut engine = Engine::new(&cluster, &job, &mut backend);

    for mode in [ShuffleMode::Coded, ShuffleMode::Uncoded] {
        let r = engine.run(&PlacementStrategy::OptimalK3, mode).expect("job run");
        assert!(r.verified, "reduce outputs must match the single-node oracle");
        println!(
            "{:?}: load = {} IV equations, {} payload bytes, {} broadcasts, shuffle {:.1} ms (verified)",
            mode, r.load_equations, r.payload_bytes, r.messages, r.shuffle_time_s * 1e3
        );
    }
    println!("\nNext: examples/terasort.rs (full pipeline + XLA backend),");
    println!("      examples/paper_figures.rs (every number from the paper).");
}
