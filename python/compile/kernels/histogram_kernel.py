"""Pallas bucketed-histogram kernel: the TeraSort Map function.

TeraSort's Map stage range-partitions keys: for each file (a block of keys)
it emits, per reducer ``q``, the count of keys falling in each of the
reducer's ``T`` sub-ranges.  Those counts are the intermediate values
``v_{q,n}`` shuffled by hetcdc; the Reduce stage merges them into a global
key-distribution (the classic sampled-splitter pipeline).

Kernel shape: ``keys[B, D] x bounds[QT + 1] -> counts[B, QT]`` where ``B``
is the file batch, ``D`` keys per file, and ``QT = Q * T`` total buckets.
Buckets are half-open ``[bounds[i], bounds[i+1])``.

TPU mapping: one grid step owns a ``(bb, D)`` block of keys in VMEM and the
full (small) bounds vector; the compare+reduce is VPU-elementwise over the
8x128 lanes -- there is no MXU work here, so the tile is chosen to keep the
one-hot intermediate ``(bb, D, QT)`` under the VMEM budget (default
``bb=8, D<=1024, QT<=256`` -> 8 MiB of i32 before reduction; interpret mode
materializes it, real TPU fuses the reduction).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BB = 8


def _histogram_kernel(keys_ref, bounds_ref, o_ref):
    keys = keys_ref[...]  # (bb, D) int32
    bounds = bounds_ref[...]  # (QT + 1,) int32
    lo = bounds[:-1]
    hi = bounds[1:]
    in_bucket = (keys[:, :, None] >= lo[None, None, :]) & (
        keys[:, :, None] < hi[None, None, :]
    )
    o_ref[...] = jnp.sum(in_bucket.astype(jnp.int32), axis=1)


def histogram(
    keys: jax.Array,
    bounds: jax.Array,
    *,
    bb: int = DEFAULT_BB,
    interpret: bool = True,
) -> jax.Array:
    """Per-row bucket counts of ``keys`` against half-open ``bounds``."""
    b, _d = keys.shape
    (nb,) = bounds.shape
    qt = nb - 1
    bb = min(bb, b)
    if b % bb:
        raise ValueError(f"batch {b} does not tile by {bb}")
    grid = (b // bb,)
    return pl.pallas_call(
        _histogram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, keys.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((nb,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, qt), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, qt), jnp.int32),
        interpret=interpret,
    )(keys, bounds)


@functools.partial(jax.jit, static_argnames=("bb",))
def histogram_jit(keys, bounds, bb=DEFAULT_BB):
    return histogram(keys, bounds, bb=bb)
