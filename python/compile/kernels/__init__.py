"""Layer-1 Pallas kernels for hetcdc.

All kernels are authored for TPU-style tiling (VMEM-resident blocks, MXU
matmul shapes) but are lowered with ``interpret=True`` so the emitted HLO
runs on the CPU PJRT client used by the Rust runtime (real-TPU lowering
emits Mosaic custom-calls the CPU plugin cannot execute).

Kernels:
  * :mod:`matmul_kernel` -- tiled matmul, the Map-stage projection hot spot.
  * :mod:`histogram_kernel` -- bucketed key histogram (TeraSort Map).
  * :mod:`xor_kernel` -- bitwise XOR combine (the coded-shuffle primitive).
  * :mod:`ref` -- pure-jnp oracles used by pytest as correctness ground truth.
"""
