"""Pallas multi-way XOR reduce: the (r+1)-group multicast encoder.

The homogeneous CDC multicast of Li et al. [2] (and the paper's §V
j-subsystems) XORs **r segments** into one broadcast, not just two:
node k in group A sends ``⊕_{j∈A\\{k}} seg_k(v_j)``. This kernel folds a
stack of ``R`` int32 blocks into their XOR in one pass.

Shape: ``stack[R, B, C] -> out[B, C]`` with the fold over axis 0 unrolled
inside the kernel (R is static — it is the coding-group size, 1..=K-1).

TPU mapping: VPU elementwise over (8,128) int32 lanes; the R-fold keeps
the accumulator in VMEM registers, streaming each layer HBM->VMEM once —
the same structure an r-way GPU warp reduction would use, minus shared
memory (not needed: pure elementwise).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8


def _xor_reduce_kernel(stack_ref, o_ref):
    acc = stack_ref[0]
    r = stack_ref.shape[0]
    for i in range(1, r):  # static unroll: R is a compile-time constant
        acc = jax.lax.bitwise_xor(acc, stack_ref[i])
    o_ref[...] = acc


def xor_reduce(
    stack: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Fold ``stack[R, B, C]`` (int32) into the elementwise XOR ``[B, C]``."""
    if stack.ndim != 3:
        raise ValueError(f"expected [R, B, C], got {stack.shape}")
    r, rows, cols = stack.shape
    if r < 1:
        raise ValueError("need at least one layer")
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows {rows} do not tile by {br}")
    grid = (rows // br,)
    return pl.pallas_call(
        _xor_reduce_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((r, br, cols), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), stack.dtype),
        interpret=interpret,
    )(stack)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def xor_reduce_jit(stack, block_rows=DEFAULT_BLOCK_ROWS):
    return xor_reduce(stack, block_rows=block_rows)
