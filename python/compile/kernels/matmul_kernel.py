"""Tiled Pallas matmul: the Map-stage projection hot spot.

The hetcdc Map function for the WordCount/feature workload computes, per
file ``n``, the intermediate-value matrix ``V[:, n] = W @ counts[:, n]``
(eq. (1) of the paper with ``g_{q,n}`` realized as a linear feature
projection).  Batched over files this is a single matmul
``IV[QT, B] = W[QT, V] @ C[V, B]`` -- the compute hot spot of the Map phase.

TPU mapping (see DESIGN.md section "Hardware adaptation"):

* blocks of ``(bm, bk) x (bk, bn)`` live in VMEM; the default 128 tile
  matches the MXU systolic array (128x128);
* the grid iterates ``(m, n, k)`` with ``k`` innermost so the f32 scratch
  accumulator stays VMEM-resident across the contraction;
* ``BlockSpec`` index maps express the HBM->VMEM schedule a CUDA kernel
  would express with threadblocks + shared-memory staging.

VMEM footprint per step (f32): ``bm*bk + bk*bn + 2*bm*bn`` words; with the
default 128 tiles that is 256 KiB -- well under the ~16 MiB/core budget,
leaving room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 128


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref):
    """One (bm, bn) output tile; accumulates over the k-grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = DEFAULT_BLOCK,
    bn: int = DEFAULT_BLOCK,
    bk: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """``a @ b`` via a Pallas kernel tiled ``(bm, bn, bk)``.

    Shapes must tile evenly after clamping each block to the full dimension;
    callers with ragged sizes should pad (the AOT entry points use fixed,
    even shapes recorded in the artifact manifest).
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"shapes ({m},{k})x({k},{n}) do not tile by ({bm},{bn},{bk})"
        )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_jit(a, b, bm=DEFAULT_BLOCK, bn=DEFAULT_BLOCK, bk=DEFAULT_BLOCK):
    return matmul(a, b, bm=bm, bn=bn, bk=bk)
