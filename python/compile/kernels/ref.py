"""Pure-jnp oracles for every Pallas kernel (pytest ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Reference for :func:`matmul_kernel.matmul`."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def histogram_ref(keys: jax.Array, bounds: jax.Array) -> jax.Array:
    """Reference for :func:`histogram_kernel.histogram`.

    Per-row counts of keys in half-open buckets ``[bounds[i], bounds[i+1])``.
    """
    lo = bounds[:-1]
    hi = bounds[1:]
    in_bucket = (keys[:, :, None] >= lo[None, None, :]) & (
        keys[:, :, None] < hi[None, None, :]
    )
    return jnp.sum(in_bucket.astype(jnp.int32), axis=1)


def xor_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Reference for :func:`xor_kernel.xor_combine`."""
    return jnp.bitwise_xor(a, b)


def xor_reduce_ref(stack: jax.Array) -> jax.Array:
    """Reference for :func:`xor_reduce_kernel.xor_reduce`."""
    out = stack[0]
    for i in range(1, stack.shape[0]):
        out = jnp.bitwise_xor(out, stack[i])
    return out
