"""Pallas bitwise-XOR kernel: the coded-shuffle combining primitive.

The paper's Shuffle phase broadcasts XORs of intermediate values
(eqs. (8)-(10)): node 1 sends ``v_{3,a} XOR v_{2,b}`` so that two receivers
each recover their missing IV from one transmission.  This kernel is that
combiner expressed over int32 lanes (IV payloads are bit-exact byte blocks;
the Rust hot path views them as ``u64`` words -- see ``coding/xor.rs`` --
and cross-checks against this kernel's artifact in integration tests).

TPU mapping: pure VPU elementwise op on (8, 128)-lane int32 tiles; blocks
stream HBM->VMEM with no reuse, so the kernel is bandwidth-bound and the
block size only needs to be large enough to amortize grid overhead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8


def _xor_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jax.lax.bitwise_xor(a_ref[...], b_ref[...])


def xor_combine(
    a: jax.Array,
    b: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Elementwise ``a ^ b`` for equal-shape int32 2-D arrays."""
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch {a.shape} vs {b.shape}")
    rows, cols = a.shape
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows {rows} do not tile by {br}")
    grid = (rows // br,)
    return pl.pallas_call(
        _xor_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def xor_combine_jit(a, b, block_rows=DEFAULT_BLOCK_ROWS):
    return xor_combine(a, b, block_rows=block_rows)
