"""Layer-2 JAX model: the Map/Reduce compute graphs of the hetcdc job.

The paper (eq. (1)) decomposes each output function as
``phi_q = h_q(g_{q,1}(w_1), ..., g_{q,N}(w_N))``.  This module defines the
concrete ``g`` (Map) and ``h`` (Reduce) graphs used by the framework's two
built-in workloads, each calling the Layer-1 Pallas kernels so that the
kernels lower into the same HLO module:

* **WordCount / feature projection** -- ``map_project``: per-file token-count
  vectors are projected by a weight matrix into the ``Q x T`` intermediate
  values; ``reduce_sum`` merges IVs across files (``h_q`` = sum).
* **TeraSort range partition** -- ``map_histogram``: per-file keys are
  bucketed against splitter boundaries into per-reducer count vectors.
* **Coded shuffle combiner** -- ``xor_blocks``: the XOR encoder of
  eqs. (8)-(10), exported so integration tests can cross-check the Rust
  hot-path XOR bit-for-bit against the XLA artifact.

Every public function returns a 1-tuple: the AOT path lowers with
``return_tuple=True`` and the Rust runtime unwraps with ``to_tuple1``.

Python here is build-time only: these graphs are lowered once by
:mod:`compile.aot` into ``artifacts/*.hlo.txt`` and executed from Rust via
PJRT; nothing in this package runs on the request path.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import matmul_kernel, histogram_kernel, xor_kernel, xor_reduce_kernel


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shapes baked into the AOT artifacts (see manifest.json).

    Attributes:
      vocab:       feature dimension V of the WordCount Map projection.
      q:           number of reduce functions Q (== K nodes by default).
      t:           IV length T per (function, file) pair, in f32 words.
      map_batch:   files per Map invocation B (ragged tails are zero-padded;
                   zero columns produce zero IVs which are harmless to sum).
      keys_per_file: D, keys per TeraSort file.
      xor_rows/xor_cols: block shape of the XOR-combiner artifact.
    """

    vocab: int = 256
    q: int = 3
    t: int = 32
    map_batch: int = 16
    keys_per_file: int = 512
    reduce_batch: int = 16
    xor_rows: int = 8
    xor_cols: int = 128
    xor_layers: int = 3

    @property
    def qt(self) -> int:
        return self.q * self.t


DEFAULT_CONFIG = ModelConfig()


def map_project(w: jax.Array, counts: jax.Array):
    """WordCount Map: ``IV[QT, B] = W[QT, V] @ counts[V, B]``.

    Column ``n`` of the result, reshaped ``(Q, T)``, is the stack of
    intermediate values ``v_{1,n}, ..., v_{Q,n}`` for file ``n``.
    """
    return (matmul_kernel.matmul(w, counts),)


def map_histogram(keys: jax.Array, bounds: jax.Array):
    """TeraSort Map: per-file bucket counts ``[B, QT]`` (int32).

    Row ``n`` reshaped ``(Q, T)`` gives ``v_{q,n}`` = counts of file ``n``'s
    keys in reducer ``q``'s ``T`` sub-ranges.
    """
    return (histogram_kernel.histogram(keys, bounds),)


def reduce_sum(ivs: jax.Array):
    """Reduce ``h_q``: merge a block of per-file IVs ``[RB, T] -> [T]``.

    The Rust reduce phase folds file IVs in blocks of ``RB`` (padding the
    tail with zeros), chaining partial sums, so one fixed-shape artifact
    serves any N.
    """
    return (jnp.sum(ivs, axis=0),)


def xor_blocks(a: jax.Array, b: jax.Array):
    """Coded-shuffle combiner: elementwise ``a ^ b`` over int32 blocks."""
    return (xor_kernel.xor_combine(a, b),)


def xor_reduce(stack: jax.Array):
    """Multi-way multicast encoder: XOR-fold ``stack[R, B, C] -> [B, C]``
    (the (r+1)-group encoder of the homogeneous scheme [2])."""
    return (xor_reduce_kernel.xor_reduce(stack),)


def entry_points(cfg: ModelConfig = DEFAULT_CONFIG):
    """AOT entry points: name -> (function, example argument shapes).

    The shape specs drive both :mod:`compile.aot` lowering and the manifest
    the Rust runtime reads to build input literals.
    """
    f32, i32 = jnp.float32, jnp.int32
    return {
        "map_project": (
            map_project,
            (
                jax.ShapeDtypeStruct((cfg.qt, cfg.vocab), f32),
                jax.ShapeDtypeStruct((cfg.vocab, cfg.map_batch), f32),
            ),
        ),
        "map_histogram": (
            map_histogram,
            (
                jax.ShapeDtypeStruct((cfg.map_batch, cfg.keys_per_file), i32),
                jax.ShapeDtypeStruct((cfg.qt + 1,), i32),
            ),
        ),
        "reduce_sum": (
            reduce_sum,
            (jax.ShapeDtypeStruct((cfg.reduce_batch, cfg.t), f32),),
        ),
        "xor_blocks": (
            xor_blocks,
            (
                jax.ShapeDtypeStruct((cfg.xor_rows, cfg.xor_cols), i32),
                jax.ShapeDtypeStruct((cfg.xor_rows, cfg.xor_cols), i32),
            ),
        ),
        "xor_reduce": (
            xor_reduce,
            (
                jax.ShapeDtypeStruct(
                    (cfg.xor_layers, cfg.xor_rows, cfg.xor_cols), i32
                ),
            ),
        ),
    }
