"""AOT compiler: lower every Layer-2 entry point to HLO text artifacts.

Interchange format is **HLO text**, not ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the HLO *text* parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Outputs (under ``--out-dir``, default ``../artifacts``):
  * ``<name>.hlo.txt``  per entry point in :func:`compile.model.entry_points`
  * ``manifest.json``   shapes/dtypes per artifact + the ModelConfig, read by
                        the Rust runtime to construct input literals.

Run once at build time (``make artifacts``); Python never runs on the
request path.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import jax

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-reassigning path)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, arg_specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*arg_specs))


def build_manifest(cfg: model.ModelConfig, entries) -> dict:
    manifest = {
        "config": dataclasses.asdict(cfg),
        "artifacts": {},
    }
    for name, (_fn, specs) in entries.items():
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
        }
    return manifest


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default=None, help="artifact directory")
    # Back-compat with the scaffold Makefile: --out <file> sets out-dir.
    p.add_argument("--out", default=None, help=argparse.SUPPRESS)
    p.add_argument("--vocab", type=int, default=model.DEFAULT_CONFIG.vocab)
    p.add_argument("--q", type=int, default=model.DEFAULT_CONFIG.q)
    p.add_argument("--t", type=int, default=model.DEFAULT_CONFIG.t)
    p.add_argument(
        "--map-batch", type=int, default=model.DEFAULT_CONFIG.map_batch
    )
    p.add_argument(
        "--keys-per-file",
        type=int,
        default=model.DEFAULT_CONFIG.keys_per_file,
    )
    args = p.parse_args(argv)

    out_dir = args.out_dir
    if out_dir is None and args.out is not None:
        out_dir = os.path.dirname(os.path.abspath(args.out))
    if out_dir is None:
        out_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    out_dir = os.path.abspath(out_dir)
    os.makedirs(out_dir, exist_ok=True)

    cfg = model.ModelConfig(
        vocab=args.vocab,
        q=args.q,
        t=args.t,
        map_batch=args.map_batch,
        keys_per_file=args.keys_per_file,
    )
    entries = model.entry_points(cfg)

    for name, (fn, specs) in entries.items():
        text = lower_entry(fn, specs)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"aot: wrote {path} ({len(text)} chars)")

    manifest = build_manifest(cfg, entries)
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"aot: wrote {mpath}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
