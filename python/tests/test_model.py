"""Layer-2 model graphs: shapes, semantics, and pipeline-level invariants."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


CFG = model.DEFAULT_CONFIG


def _rand(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


class TestEntryPoints:
    def test_all_entry_points_present(self):
        eps = model.entry_points()
        assert set(eps) == {
            "map_project",
            "map_histogram",
            "reduce_sum",
            "xor_blocks",
            "xor_reduce",
        }

    def test_entry_point_specs_are_consistent(self):
        eps = model.entry_points()
        w_spec, c_spec = eps["map_project"][1]
        assert w_spec.shape == (CFG.qt, CFG.vocab)
        assert c_spec.shape == (CFG.vocab, CFG.map_batch)
        k_spec, b_spec = eps["map_histogram"][1]
        assert k_spec.shape == (CFG.map_batch, CFG.keys_per_file)
        assert b_spec.shape == (CFG.qt + 1,)

    def test_custom_config_propagates(self):
        cfg = model.ModelConfig(vocab=64, q=4, t=8, map_batch=4, keys_per_file=32)
        eps = model.entry_points(cfg)
        assert eps["map_project"][1][0].shape == (32, 64)
        assert eps["map_histogram"][1][1].shape == (33,)


class TestMapProject:
    def test_column_semantics(self):
        # Column n of the IV matrix is W @ counts[:, n] -- per-file Map.
        w = _rand((CFG.qt, CFG.vocab), 0)
        counts = _rand((CFG.vocab, CFG.map_batch), 1)
        (ivs,) = model.map_project(w, counts)
        assert ivs.shape == (CFG.qt, CFG.map_batch)
        for n in (0, CFG.map_batch - 1):
            np.testing.assert_allclose(
                ivs[:, n], w @ counts[:, n], rtol=1e-4, atol=1e-4
            )

    def test_zero_padding_is_harmless(self):
        # Padding the file batch with zero columns yields zero IVs, so the
        # Rust runtime can pad ragged tails safely.
        w = _rand((CFG.qt, CFG.vocab), 2)
        counts = _rand((CFG.vocab, CFG.map_batch), 3)
        padded = counts.at[:, CFG.map_batch // 2 :].set(0.0)
        (ivs,) = model.map_project(w, padded)
        np.testing.assert_array_equal(
            ivs[:, CFG.map_batch // 2 :],
            jnp.zeros((CFG.qt, CFG.map_batch - CFG.map_batch // 2)),
        )


class TestReduceSum:
    def test_matches_sum(self):
        ivs = _rand((CFG.reduce_batch, CFG.t), 4)
        (out,) = model.reduce_sum(ivs)
        np.testing.assert_allclose(out, jnp.sum(ivs, axis=0), rtol=1e-6)

    def test_chained_partial_sums_equal_full_sum(self):
        # The Rust reduce phase folds blocks of RB files, carrying the
        # partial sum in row 0 of the next block.
        n_files = 3 * CFG.reduce_batch - 5
        ivs = _rand((n_files, CFG.t), 5)
        acc = jnp.zeros((CFG.t,), jnp.float32)
        i = 0
        while i < n_files:
            blk = ivs[i : i + CFG.reduce_batch]
            pad = CFG.reduce_batch - blk.shape[0]
            if pad:
                blk = jnp.pad(blk, ((0, pad), (0, 0)))
            (s,) = model.reduce_sum(blk)
            acc = acc + s
            i += CFG.reduce_batch
        np.testing.assert_allclose(acc, jnp.sum(ivs, axis=0), rtol=1e-4, atol=1e-4)


class TestPipelineInvariant:
    def test_reduce_of_map_equals_map_of_sum(self):
        """The end-to-end WordCount identity the engine verifies against:
        sum_n W @ c_n == W @ (sum_n c_n); linear Map commutes with Reduce."""
        w = _rand((CFG.qt, CFG.vocab), 6)
        counts = jnp.abs(_rand((CFG.vocab, CFG.map_batch), 7))
        (ivs,) = model.map_project(w, counts)
        lhs = jnp.sum(ivs, axis=1)
        rhs = w @ jnp.sum(counts, axis=1)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)

    def test_histogram_map_reduce_counts_total(self):
        keys = jax.random.randint(
            jax.random.PRNGKey(8), (CFG.map_batch, CFG.keys_per_file), 0, 960, jnp.int32
        )
        bounds = jnp.arange(CFG.qt + 1, dtype=jnp.int32) * 10  # covers [0, 960)
        (counts,) = model.map_histogram(keys, bounds)
        assert counts.shape == (CFG.map_batch, CFG.qt)
        # Reduce across files preserves the global key count.
        assert int(jnp.sum(counts)) == CFG.map_batch * CFG.keys_per_file
        np.testing.assert_array_equal(counts, ref.histogram_ref(keys, bounds))
