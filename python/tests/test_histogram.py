"""Pallas histogram kernel vs oracle; TeraSort Map-stage invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import histogram_kernel, ref


def _keys(shape, seed, lo=0, hi=1000):
    return jax.random.randint(jax.random.PRNGKey(seed), shape, lo, hi, jnp.int32)


def _uniform_bounds(qt, width):
    return jnp.arange(qt + 1, dtype=jnp.int32) * width


class TestHistogramBasic:
    def test_default_artifact_shape(self):
        keys = _keys((16, 512), 0)
        bounds = _uniform_bounds(96, 11)
        np.testing.assert_array_equal(
            histogram_kernel.histogram(keys, bounds),
            ref.histogram_ref(keys, bounds),
        )

    def test_all_keys_in_one_bucket(self):
        keys = jnp.full((8, 32), 5, jnp.int32)
        bounds = jnp.array([0, 10, 20, 30], jnp.int32)
        out = histogram_kernel.histogram(keys, bounds)
        np.testing.assert_array_equal(out[:, 0], jnp.full((8,), 32, jnp.int32))
        np.testing.assert_array_equal(out[:, 1:], jnp.zeros((8, 2), jnp.int32))

    def test_keys_outside_all_buckets_dropped(self):
        keys = jnp.array([[-5, 100, 100, 3]], jnp.int32)
        bounds = jnp.array([0, 4, 8], jnp.int32)
        out = histogram_kernel.histogram(keys, bounds, bb=1)
        np.testing.assert_array_equal(out, jnp.array([[1, 0]], jnp.int32))

    def test_boundary_half_open(self):
        # key == bounds[i] lands in bucket i; key == bounds[i+1] does not.
        keys = jnp.array([[0, 4, 7, 8]], jnp.int32)
        bounds = jnp.array([0, 4, 8], jnp.int32)
        out = histogram_kernel.histogram(keys, bounds, bb=1)
        # 0 -> [0,4); 4,7 -> [4,8); 8 == bounds[-1] is excluded.
        np.testing.assert_array_equal(out, jnp.array([[1, 2]], jnp.int32))

    def test_total_count_preserved_when_covering(self):
        keys = _keys((8, 256), 1, 0, 999)
        bounds = _uniform_bounds(10, 100)  # covers [0, 1000)
        out = histogram_kernel.histogram(keys, bounds)
        np.testing.assert_array_equal(
            jnp.sum(out, axis=1), jnp.full((8,), 256, jnp.int32)
        )

    def test_multi_block_batch(self):
        keys = _keys((32, 64), 2)
        bounds = _uniform_bounds(16, 64)
        out = histogram_kernel.histogram(keys, bounds, bb=4)
        np.testing.assert_array_equal(out, ref.histogram_ref(keys, bounds))

    def test_ragged_batch_raises(self):
        with pytest.raises(ValueError, match="does not tile"):
            histogram_kernel.histogram(_keys((10, 8), 0), _uniform_bounds(4, 10), bb=4)

    def test_jit_wrapper(self):
        keys = _keys((8, 64), 3)
        bounds = _uniform_bounds(8, 125)
        np.testing.assert_array_equal(
            histogram_kernel.histogram_jit(keys, bounds),
            histogram_kernel.histogram(keys, bounds),
        )


class TestHistogramProperty:
    @settings(max_examples=12, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 4, 8]),
        d=st.sampled_from([16, 64, 128]),
        qt=st.sampled_from([4, 16, 96]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, b, d, qt, seed):
        keys = _keys((b, d), seed, -50, 5000)
        # Non-uniform, sorted, possibly-empty buckets.
        raw = jax.random.randint(
            jax.random.PRNGKey(seed + 1), (qt + 1,), -100, 5100, jnp.int32
        )
        bounds = jnp.sort(raw)
        out = histogram_kernel.histogram(keys, bounds, bb=min(b, 8))
        np.testing.assert_array_equal(out, ref.histogram_ref(keys, bounds))

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_counts_sum_to_keys_under_cover(self, seed):
        keys = _keys((4, 128), seed, 0, 2**20)
        bounds = jnp.linspace(0, 2**20, 33).astype(jnp.int32)
        out = histogram_kernel.histogram(keys, bounds, bb=4)
        assert int(jnp.sum(out)) == 4 * 128
