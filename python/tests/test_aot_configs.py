"""AOT lowering works for non-default shape configs (the `aot.py` flags a
deployment would actually change), and the kernels stay correct there."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


# A deployment-shaped variant: more reducers, shorter IVs, small batch.
VARIANT = model.ModelConfig(
    vocab=128, q=4, t=16, map_batch=8, keys_per_file=64, reduce_batch=8
)


class TestVariantLowering:
    @pytest.mark.parametrize("name", sorted(model.entry_points(VARIANT)))
    def test_each_entry_point_lowers_to_hlo(self, name):
        fn, specs = model.entry_points(VARIANT)[name]
        text = aot.lower_entry(fn, specs)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        assert "mosaic" not in text.lower()

    def test_variant_map_project_numerics(self):
        cfg = VARIANT
        w = jax.random.normal(jax.random.PRNGKey(0), (cfg.qt, cfg.vocab), jnp.float32)
        c = jax.random.normal(jax.random.PRNGKey(1), (cfg.vocab, cfg.map_batch), jnp.float32)
        (ivs,) = model.map_project(w, c)
        np.testing.assert_allclose(ivs, ref.matmul_ref(w, c), rtol=1e-4, atol=1e-4)

    def test_variant_histogram_numerics(self):
        cfg = VARIANT
        keys = jax.random.randint(
            jax.random.PRNGKey(2), (cfg.map_batch, cfg.keys_per_file), 0, 1 << 20, jnp.int32
        )
        bounds = jnp.linspace(0, 1 << 20, cfg.qt + 1).astype(jnp.int32)
        (counts,) = model.map_histogram(keys, bounds)
        np.testing.assert_array_equal(counts, ref.histogram_ref(keys, bounds))

    def test_manifest_for_variant(self):
        entries = model.entry_points(VARIANT)
        manifest = aot.build_manifest(VARIANT, entries)
        assert manifest["config"]["q"] == 4
        assert manifest["config"]["t"] == 16
        got = [tuple(i["shape"]) for i in manifest["artifacts"]["map_project"]["inputs"]]
        assert got == [(64, 128), (128, 8)]
