"""Multi-way XOR reduce kernel: oracle equality + coding-theoretic use."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import xor_reduce_kernel, ref


def _stack(shape, seed):
    return jax.random.randint(
        jax.random.PRNGKey(seed), shape, -(2**31), 2**31 - 1, jnp.int32
    )


class TestXorReduceBasic:
    def test_default_artifact_shape(self):
        s = _stack((3, 8, 128), 0)
        np.testing.assert_array_equal(
            xor_reduce_kernel.xor_reduce(s), ref.xor_reduce_ref(s)
        )

    def test_single_layer_is_identity(self):
        s = _stack((1, 8, 32), 1)
        np.testing.assert_array_equal(xor_reduce_kernel.xor_reduce(s), s[0])

    def test_even_layer_count_of_same_block_is_zero(self):
        block = _stack((1, 8, 16), 2)[0]
        s = jnp.stack([block, block, block, block])
        np.testing.assert_array_equal(
            xor_reduce_kernel.xor_reduce(s), jnp.zeros_like(block)
        )

    def test_receiver_cancellation(self):
        # Receiver knows layers 1..r-1; XOR of the message with them
        # recovers layer 0 — the multicast decode of [2].
        s = _stack((4, 8, 64), 3)
        msg = xor_reduce_kernel.xor_reduce(s)
        known = ref.xor_reduce_ref(s[1:])
        np.testing.assert_array_equal(jnp.bitwise_xor(msg, known), s[0])

    def test_multi_block_rows(self):
        s = _stack((2, 32, 16), 4)
        out = xor_reduce_kernel.xor_reduce(s, block_rows=8)
        np.testing.assert_array_equal(out, ref.xor_reduce_ref(s))

    def test_bad_rank_raises(self):
        with pytest.raises(ValueError, match="expected"):
            xor_reduce_kernel.xor_reduce(_stack((8, 16), 0))

    def test_ragged_rows_raises(self):
        with pytest.raises(ValueError, match="do not tile"):
            xor_reduce_kernel.xor_reduce(_stack((2, 10, 8), 0), block_rows=4)


class TestXorReduceProperty:
    @settings(max_examples=10, deadline=None)
    @given(
        r=st.integers(1, 5),
        rows=st.sampled_from([1, 4, 8]),
        cols=st.sampled_from([8, 32, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, r, rows, cols, seed):
        s = _stack((r, rows, cols), seed)
        out = xor_reduce_kernel.xor_reduce(s, block_rows=min(rows, 8))
        np.testing.assert_array_equal(out, ref.xor_reduce_ref(s))

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_layer_order_invariance(self, seed):
        s = _stack((3, 4, 16), seed)
        perm = s[jnp.array([2, 0, 1])]
        np.testing.assert_array_equal(
            xor_reduce_kernel.xor_reduce(s, block_rows=4),
            xor_reduce_kernel.xor_reduce(perm, block_rows=4),
        )
