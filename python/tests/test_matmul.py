"""Pallas matmul kernel vs pure-jnp oracle (the core L1 correctness signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_kernel, ref


def _rand(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


class TestMatmulBasic:
    def test_default_artifact_shape(self):
        a = _rand((96, 256), 0)
        b = _rand((256, 16), 1)
        np.testing.assert_allclose(
            matmul_kernel.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5
        )

    def test_square_mxu_tile(self):
        a = _rand((128, 128), 2)
        b = _rand((128, 128), 3)
        np.testing.assert_allclose(
            matmul_kernel.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5
        )

    def test_multi_tile_all_axes(self):
        a = _rand((64, 96), 4)
        b = _rand((96, 64), 5)
        out = matmul_kernel.matmul(a, b, bm=32, bn=32, bk=32)
        np.testing.assert_allclose(out, ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5)

    def test_k_accumulation_over_many_steps(self):
        # k-grid of 8 steps exercises the scratch accumulator init/store.
        a = _rand((16, 256), 6)
        b = _rand((256, 16), 7)
        out = matmul_kernel.matmul(a, b, bm=16, bn=16, bk=32)
        np.testing.assert_allclose(out, ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5)

    def test_identity(self):
        eye = jnp.eye(64, dtype=jnp.float32)
        b = _rand((64, 32), 8)
        np.testing.assert_allclose(
            matmul_kernel.matmul(eye, b), b, rtol=1e-6, atol=1e-6
        )

    def test_zeros(self):
        a = jnp.zeros((32, 32), jnp.float32)
        b = _rand((32, 32), 9)
        np.testing.assert_array_equal(
            matmul_kernel.matmul(a, b), jnp.zeros((32, 32), jnp.float32)
        )

    def test_vector_like_batch_one(self):
        a = _rand((96, 256), 10)
        b = _rand((256, 1), 11)
        np.testing.assert_allclose(
            matmul_kernel.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5
        )

    def test_contraction_mismatch_raises(self):
        with pytest.raises(ValueError, match="contraction mismatch"):
            matmul_kernel.matmul(_rand((8, 16), 0), _rand((8, 8), 1))

    def test_ragged_tiling_raises(self):
        with pytest.raises(ValueError, match="do not tile"):
            matmul_kernel.matmul(_rand((10, 16), 0), _rand((16, 8), 1), bm=4)

    def test_jit_wrapper_matches_eager(self):
        a = _rand((32, 64), 12)
        b = _rand((64, 32), 13)
        np.testing.assert_allclose(
            matmul_kernel.matmul_jit(a, b, bm=32, bn=32, bk=32),
            matmul_kernel.matmul(a, b, bm=32, bn=32, bk=32),
            rtol=0,
            atol=0,
        )


# Hypothesis sweep: random even-tiling shapes and block sizes.
_dims = st.sampled_from([8, 16, 32, 48, 64, 96])
_blocks = st.sampled_from([8, 16, 32, 128])


class TestMatmulProperty:
    @settings(max_examples=12, deadline=None)
    @given(m=_dims, k=_dims, n=_dims, bm=_blocks, bn=_blocks, bk=_blocks, seed=st.integers(0, 2**16))
    def test_matches_ref_on_even_tilings(self, m, k, n, bm, bn, bk, seed):
        bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
        if m % bm or n % bn or k % bk:
            return  # only even tilings are supported (AOT uses fixed shapes)
        a = _rand((m, k), seed)
        b = _rand((k, n), seed + 1)
        out = matmul_kernel.matmul(a, b, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(out, ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_linearity(self, seed):
        # matmul(W, x + y) == matmul(W, x) + matmul(W, y): the property the
        # coded pipeline relies on (Reduce-of-Map == Map-of-summed-counts).
        w = _rand((32, 64), seed)
        x = _rand((64, 8), seed + 1)
        y = _rand((64, 8), seed + 2)
        lhs = matmul_kernel.matmul(w, x + y, bm=32, bn=8, bk=32)
        rhs = matmul_kernel.matmul(w, x, bm=32, bn=8, bk=32) + matmul_kernel.matmul(
            w, y, bm=32, bn=8, bk=32
        )
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)
