"""Pallas XOR-combiner kernel: oracle equality + algebraic invariants.

These are the invariants the coded shuffle relies on: a receiver recovers
``v_a = (v_a ^ v_b) ^ v_b`` (involution), and encoding order is irrelevant
(commutativity/associativity).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import xor_kernel, ref


def _blk(shape, seed):
    return jax.random.randint(
        jax.random.PRNGKey(seed), shape, -(2**31), 2**31 - 1, jnp.int32
    )


class TestXorBasic:
    def test_default_artifact_shape(self):
        a, b = _blk((8, 128), 0), _blk((8, 128), 1)
        np.testing.assert_array_equal(
            xor_kernel.xor_combine(a, b), ref.xor_ref(a, b)
        )

    def test_self_xor_is_zero(self):
        a = _blk((8, 64), 2)
        np.testing.assert_array_equal(
            xor_kernel.xor_combine(a, a), jnp.zeros_like(a)
        )

    def test_xor_zero_is_identity(self):
        a = _blk((8, 64), 3)
        np.testing.assert_array_equal(
            xor_kernel.xor_combine(a, jnp.zeros_like(a)), a
        )

    def test_decode_roundtrip(self):
        # Node 1 sends X = v3a ^ v2b; node 2 recovers v2b = X ^ v3a.
        v3a, v2b = _blk((8, 128), 4), _blk((8, 128), 5)
        x = xor_kernel.xor_combine(v3a, v2b)
        np.testing.assert_array_equal(xor_kernel.xor_combine(x, v3a), v2b)
        np.testing.assert_array_equal(xor_kernel.xor_combine(x, v2b), v3a)

    def test_multi_block_rows(self):
        a, b = _blk((32, 16), 6), _blk((32, 16), 7)
        out = xor_kernel.xor_combine(a, b, block_rows=8)
        np.testing.assert_array_equal(out, ref.xor_ref(a, b))

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError, match="shape mismatch"):
            xor_kernel.xor_combine(_blk((8, 8), 0), _blk((8, 16), 1))

    def test_ragged_rows_raises(self):
        with pytest.raises(ValueError, match="do not tile"):
            xor_kernel.xor_combine(_blk((10, 8), 0), _blk((10, 8), 1), block_rows=4)


class TestXorProperty:
    @settings(max_examples=10, deadline=None)
    @given(
        rows=st.sampled_from([1, 4, 8, 16]),
        cols=st.sampled_from([8, 32, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, rows, cols, seed):
        a, b = _blk((rows, cols), seed), _blk((rows, cols), seed + 1)
        out = xor_kernel.xor_combine(a, b, block_rows=min(rows, 8))
        np.testing.assert_array_equal(out, ref.xor_ref(a, b))

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_commutative_associative(self, seed):
        a, b, c = _blk((4, 32), seed), _blk((4, 32), seed + 1), _blk((4, 32), seed + 2)
        x = xor_kernel.xor_combine
        np.testing.assert_array_equal(x(a, b, block_rows=4), x(b, a, block_rows=4))
        np.testing.assert_array_equal(
            x(x(a, b, block_rows=4), c, block_rows=4),
            x(a, x(b, c, block_rows=4), block_rows=4),
        )
