"""AOT pipeline: artifacts are emitted, parseable-looking, and manifested."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    rc = aot.main(["--out-dir", str(out), "--vocab", "64", "--q", "3", "--t", "8",
                   "--map-batch", "4", "--keys-per-file", "32"])
    assert rc == 0
    return out


class TestAotOutputs:
    def test_all_artifacts_emitted(self, built):
        for name in model.entry_points():
            assert (built / f"{name}.hlo.txt").exists()
        assert (built / "manifest.json").exists()

    def test_hlo_text_headers(self, built):
        for name in model.entry_points():
            text = (built / f"{name}.hlo.txt").read_text()
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_entry_layout_mentions_tuple_output(self, built):
        # return_tuple=True => the entry computation returns a tuple; the
        # Rust runtime unwraps with to_tuple1.
        text = (built / "map_project.hlo.txt").read_text()
        header = text.splitlines()[0]
        assert "->(" in header.replace(" ", ""), header

    def test_manifest_matches_entry_points(self, built):
        manifest = json.loads((built / "manifest.json").read_text())
        cfg = model.ModelConfig(vocab=64, q=3, t=8, map_batch=4, keys_per_file=32)
        eps = model.entry_points(cfg)
        assert set(manifest["artifacts"]) == set(eps)
        for name, (_fn, specs) in eps.items():
            entry = manifest["artifacts"][name]
            assert entry["file"] == f"{name}.hlo.txt"
            got = [tuple(i["shape"]) for i in entry["inputs"]]
            want = [tuple(s.shape) for s in specs]
            assert got == want, name

    def test_manifest_records_config(self, built):
        manifest = json.loads((built / "manifest.json").read_text())
        assert manifest["config"]["vocab"] == 64
        assert manifest["config"]["q"] == 3
        assert manifest["config"]["t"] == 8

    def test_no_mosaic_custom_calls(self, built):
        # interpret=True must lower to plain HLO the CPU PJRT client can run.
        for name in model.entry_points():
            text = (built / f"{name}.hlo.txt").read_text()
            assert "tpu_custom_call" not in text, name
            assert "mosaic" not in text.lower(), name
