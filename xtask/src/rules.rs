//! The determinism rules. Each rule is a line-and-scope-aware scan over
//! a [`SourceFile`]'s comment-free text; every rule maps to one
//! invariant of the crate's byte-identical-artifact contract (see
//! DESIGN.md "Machine-checked determinism invariants").
//!
//! Justifications: a site can be exempted with a written reason using
//!
//! ```text
//! // lint: allow(<rule>): <why>
//! ```
//!
//! on the offending line or the line immediately above it. The reason is
//! mandatory — a bare `allow` without a `why` does not count. The
//! panic-path rule is the exception: it is governed by the committed
//! ratchet baseline (`lint_baseline.json`), not by per-site allows.

use crate::lexer::SourceFile;
use std::collections::{BTreeMap, BTreeSet};

/// Stable rule identifiers — these are the names the `allow(...)`
/// grammar, the reports, and DESIGN.md use.
pub const UNORDERED_ITER: &str = "unordered-iter";
pub const WALL_CLOCK: &str = "wall-clock";
pub const PANIC_PATH: &str = "panic-path";
pub const CONSTRUCTION_PATH: &str = "construction-path";
pub const UNORDERED_MERGE: &str = "unordered-merge";

/// One rule violation at a specific site.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

impl Violation {
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// Everything one lint pass produces.
#[derive(Debug, Default)]
pub struct LintOutcome {
    /// Hard violations (rules 1, 2, 4, 5) net of justifications.
    pub violations: Vec<Violation>,
    /// Non-test panic-path site count per file (rule 3), to be compared
    /// against the committed ratchet baseline.
    pub panic_counts: BTreeMap<String, usize>,
    /// The individual panic sites, for reporting when a file exceeds its
    /// ratchet budget.
    pub panic_sites: Vec<Violation>,
}

/// Run every rule over one file, appending into `out`.
pub fn check_file(f: &SourceFile, out: &mut LintOutcome) {
    if in_artifact_modules(&f.path) {
        rule_unordered_iter(f, out);
    }
    if rule2_scope(&f.path) {
        rule_wall_clock(f, out);
    }
    if rule3_scope(&f.path) {
        rule_panic_paths(f, out);
    }
    if rule4_scope(&f.path) {
        rule_construction_path(f, out);
    }
    if in_plan_build_modules(&f.path) {
        rule_unordered_merge(f, out);
    }
}

// ---------------------------------------------------------------- scoping

/// Artifact-affecting modules: everything whose in-memory order can leak
/// into plan JSON bytes or metered costs (rule 1).
fn in_artifact_modules(path: &str) -> bool {
    path.starts_with("rust/src/placement/")
        || path.starts_with("rust/src/coding/")
        || path.starts_with("rust/src/lp/")
        || path == "rust/src/engine/plan.rs"
        || path == "rust/src/engine/cache.rs"
}

/// Plan-build modules: where `thread::scope` fan-outs construct plan
/// structure and must merge in index order (rule 5).
fn in_plan_build_modules(path: &str) -> bool {
    path.starts_with("rust/src/placement/")
        || path.starts_with("rust/src/coding/")
        || path.starts_with("rust/src/lp/")
        || path == "rust/src/engine/plan.rs"
}

/// Wall-clock sources are banned everywhere in the library except the
/// opt-in timing harness (rule 2): the virtual clock in `net/sim.rs` is
/// the only time source metering may read.
fn rule2_scope(path: &str) -> bool {
    path.starts_with("rust/src/") && !path.starts_with("rust/src/bench/")
}

/// Panic paths are ratcheted across the whole library (rule 3).
fn rule3_scope(path: &str) -> bool {
    path.starts_with("rust/src/")
}

/// The removed `Executor` construction shims may not reappear anywhere
/// in library, bench, or example code (rule 4); test code is exempt so
/// the shims can be named in assertions about their absence.
fn rule4_scope(path: &str) -> bool {
    path.starts_with("rust/src/")
        || path.starts_with("rust/benches/")
        || path.starts_with("rust/examples/")
}

// ----------------------------------------------------------- justifications

/// True when 1-based `line` (or the line immediately above it) carries a
/// `// lint: allow(<rule>): <why>` directive with a non-empty reason.
pub fn justified(f: &SourceFile, line: usize, rule: &str) -> bool {
    let has = |l: usize| -> bool {
        l >= 1
            && f.raw
                .get(l - 1)
                .map(|raw| directive_allows(raw, rule))
                .unwrap_or(false)
    };
    has(line) || has(line - 1)
}

/// Parse `lint: allow(<rule>): <why>` out of one raw line.
fn directive_allows(raw: &str, rule: &str) -> bool {
    let Some(pos) = raw.find("lint: allow(") else { return false };
    let rest = &raw[pos + "lint: allow(".len()..];
    let Some(close) = rest.find(')') else { return false };
    if rest[..close].trim() != rule {
        return false;
    }
    let after = &rest[close + 1..];
    let Some(why) = after.strip_prefix(':') else { return false };
    !why.trim().is_empty()
}

// ------------------------------------------------- rule 1: unordered-iter

/// Methods whose results depend on `HashMap`/`HashSet` internal order.
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "into_keys",
    "values",
    "values_mut",
    "into_values",
    "drain",
    "retain",
];

/// Ban `HashMap`/`HashSet` iteration in artifact-affecting modules: any
/// hash-ordered walk there can leak nondeterministic order into plan
/// JSON bytes. Keyed lookups (`get`, `contains_key`, `map[&k]` indexing)
/// are fine — only *iteration* is order-dependent.
fn rule_unordered_iter(f: &SourceFile, out: &mut LintOutcome) {
    let hashed = hash_typed_names(f);
    for (i, line) in f.code.iter().enumerate() {
        let ln = i + 1;
        if f.is_test_line(ln) {
            continue;
        }
        // `name.iter()` / `name.keys()` / ... on a hash-typed binding.
        for (name, method) in ident_method_calls(line, ITER_METHODS) {
            if hashed.contains(&name) && !justified(f, ln, UNORDERED_ITER) {
                out.violations.push(Violation {
                    rule: UNORDERED_ITER,
                    path: f.path.clone(),
                    line: ln,
                    message: format!(
                        "`{name}.{method}()` iterates a HashMap/HashSet in an \
                         artifact-affecting module; use BTreeMap/BTreeSet or sort \
                         before anything order-dependent"
                    ),
                });
            }
        }
        // `for x in &name` / `for x in name` over a hash-typed binding.
        if let Some(target) = for_loop_target(line) {
            let last = target.rsplit('.').next().unwrap_or(&target);
            if hashed.contains(last) && !justified(f, ln, UNORDERED_ITER) {
                out.violations.push(Violation {
                    rule: UNORDERED_ITER,
                    path: f.path.clone(),
                    line: ln,
                    message: format!(
                        "`for .. in {target}` iterates a HashMap/HashSet in an \
                         artifact-affecting module; use BTreeMap/BTreeSet or sort first"
                    ),
                });
            }
        }
    }
}

/// Collect identifiers bound or declared with a `HashMap`/`HashSet`
/// type in this file: `let [mut] x = HashMap::new()`, `x: HashMap<..>`
/// (bindings, params, struct fields), `let [mut] x: HashSet<..> = ..`,
/// and turbofish collects `let x = ...collect::<HashMap<..>>()`.
fn hash_typed_names(f: &SourceFile) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for line in &f.code {
        if !line.contains("HashMap") && !line.contains("HashSet") {
            continue;
        }
        // `ident: HashMap<` / `ident: HashSet<` — fields, params, ascriptions.
        for marker in ["HashMap", "HashSet"] {
            let mut start = 0;
            while let Some(p) = line[start..].find(marker) {
                let at = start + p;
                if let Some(name) = ident_before_colon(&line[..at]) {
                    names.insert(name);
                }
                start = at + marker.len();
            }
        }
        // `let [mut] ident = HashMap::new()` etc (and turbofish collect).
        if let Some(eq) = line.find('=') {
            let (lhs, rhs) = line.split_at(eq);
            if rhs.contains("HashMap") || rhs.contains("HashSet") {
                if let Some(name) = let_binding_name(lhs) {
                    names.insert(name);
                }
            }
        }
    }
    names
}

/// From text ending just before a `HashMap`/`HashSet` token, extract the
/// identifier of an `ident:` prefix (allowing whitespace, `&`, `&mut`).
fn ident_before_colon(before: &str) -> Option<String> {
    let mut t = before.trim_end();
    t = t.strip_suffix("mut").unwrap_or(t).trim_end();
    while let Some(s) = t.strip_suffix('&') {
        t = s.trim_end();
    }
    let t = t.strip_suffix(':')?;
    let t = t.trim_end();
    let name: String = t
        .chars()
        .rev()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect::<String>()
        .chars()
        .rev()
        .collect();
    if name.is_empty() || name.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        None
    } else {
        Some(name)
    }
}

/// From the left-hand side of an `=`, extract a `let [mut] name` binding.
fn let_binding_name(lhs: &str) -> Option<String> {
    let t = lhs.trim();
    let t = t.strip_prefix("let ")?;
    let t = t.trim_start();
    let t = t.strip_prefix("mut ").unwrap_or(t).trim_start();
    let name: String = t
        .chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// Find `ident.method(` call sites on one line for methods in `set`,
/// returning (ident, method) pairs. The ident is the path segment
/// immediately before the dot (`a.b.iter()` yields `b`).
fn ident_method_calls(line: &str, set: &[&str]) -> Vec<(String, String)> {
    let mut found = Vec::new();
    let b = line.as_bytes();
    for &m in set {
        let pat = format!(".{m}");
        let mut start = 0;
        while let Some(p) = line[start..].find(&pat) {
            let at = start + p;
            start = at + pat.len();
            // must be a call: next non-space char after the method name is `(`
            let after = &line[at + pat.len()..];
            if !after.trim_start().starts_with('(') {
                continue;
            }
            // method name must end exactly here (`.iter(` not `.iterate(`)
            if after
                .chars()
                .next()
                .is_some_and(|c| c.is_alphanumeric() || c == '_')
            {
                continue;
            }
            // walk back over the identifier before the dot
            let mut j = at;
            while j > 0 && (b[j - 1].is_ascii_alphanumeric() || b[j - 1] == b'_') {
                j -= 1;
            }
            if j == at {
                continue; // `.iter()` chained off `)` or `]` — not a named binding
            }
            found.push((line[j..at].to_string(), m.to_string()));
        }
    }
    found
}

/// Extract the iterated expression of a `for .. in EXPR {` line when it
/// is a plain (possibly `&`-borrowed) path. Returns `None` for indexed
/// expressions (`map[&k]` yields the *value*, not map order) and calls
/// (handled by the method scan).
fn for_loop_target(line: &str) -> Option<String> {
    let t = line.trim_start();
    if !t.starts_with("for ") {
        return None;
    }
    let in_pos = t.find(" in ")?;
    let expr = t[in_pos + 4..].trim();
    let expr = expr.split('{').next().unwrap_or(expr).trim();
    let expr = expr.strip_prefix("&mut ").unwrap_or(expr);
    let expr = expr.strip_prefix('&').unwrap_or(expr);
    // plain path only: idents and dots
    if expr.is_empty() || !expr.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '.') {
        return None;
    }
    Some(expr.to_string())
}

// -------------------------------------------------- rule 2: wall-clock

/// Wall-clock reads are banned outside `bench/`: metering must go
/// through the deterministic virtual clock, or artifacts grow
/// machine-dependent bytes.
fn rule_wall_clock(f: &SourceFile, out: &mut LintOutcome) {
    for (i, line) in f.code.iter().enumerate() {
        let ln = i + 1;
        if f.is_test_line(ln) {
            continue;
        }
        for tok in ["Instant::now", "SystemTime"] {
            if line.contains(tok) && !justified(f, ln, WALL_CLOCK) {
                out.violations.push(Violation {
                    rule: WALL_CLOCK,
                    path: f.path.clone(),
                    line: ln,
                    message: format!(
                        "`{tok}` outside bench/: the net simulator's virtual clock \
                         is the only time source for metering"
                    ),
                });
            }
        }
    }
}

// -------------------------------------------------- rule 3: panic paths

/// Panic-path tokens (method calls and macros) counted by the ratchet.
const PANIC_TOKENS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!(",
    "unreachable!(",
    "todo!(",
    "unimplemented!(",
];

/// Count non-test panic sites per file. Enforcement happens against the
/// committed `lint_baseline.json` ratchet, not per-site allows: the
/// count may only go down (re-bless with `--bless` after a burndown).
fn rule_panic_paths(f: &SourceFile, out: &mut LintOutcome) {
    let mut count = 0usize;
    for (i, line) in f.code.iter().enumerate() {
        let ln = i + 1;
        if f.is_test_line(ln) {
            continue;
        }
        for tok in PANIC_TOKENS {
            let mut start = 0;
            while let Some(p) = line[start..].find(tok) {
                let at = start + p;
                start = at + tok.len();
                // `.unwrap()` must not also double-count `.unwrap().expect(`
                // sites — each token occurrence is one site, which is what
                // we want; but avoid matching `.expect(` inside
                // `.expect_err(` style names: the token already ends in
                // `(` so a longer method name cannot match.
                count += 1;
                out.panic_sites.push(Violation {
                    rule: PANIC_PATH,
                    path: f.path.clone(),
                    line: ln,
                    message: format!("`{}` in non-test library code", tok.trim_matches('.')),
                });
            }
        }
    }
    if count > 0 || f.path.starts_with("rust/src/") {
        out.panic_counts.insert(f.path.clone(), count);
    }
}

// ------------------------------------------- rule 4: construction path

/// The removed `Executor::new` / `Executor::with_mode` /
/// `.set_threads(..)` shims are banned outside tests:
/// `Executor::with_config` is the single construction path, so every
/// executor in the codebase is configured the same way.
fn rule_construction_path(f: &SourceFile, out: &mut LintOutcome) {
    for (i, line) in f.code.iter().enumerate() {
        let ln = i + 1;
        if f.is_test_line(ln) {
            continue;
        }
        for tok in ["Executor::new", "Executor::with_mode", ".set_threads("] {
            if line.contains(tok) && !justified(f, ln, CONSTRUCTION_PATH) {
                out.violations.push(Violation {
                    rule: CONSTRUCTION_PATH,
                    path: f.path.clone(),
                    line: ln,
                    message: format!(
                        "deprecated construction shim `{tok}`: use \
                         `Executor::with_config(plan, ExecConfig ..)`"
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------- rule 5: ordered merge

/// Markers that indicate an index-ordered merge of fan-out results.
const MERGE_MARKERS: &[&str] = &["shard_indexed", "sort_by_key", "sort_unstable_by_key", ".sort("];

/// `thread::scope` fan-outs in plan-build modules must merge their
/// results in index order — through `util/shard.rs::shard_indexed` or an
/// explicit index sort — or plan bytes could depend on thread finish
/// order. Heuristic: the scope's closure body (balanced parens from the
/// `scope(` call) plus a few following lines must contain a merge
/// marker, or the site carries a justification.
fn rule_unordered_merge(f: &SourceFile, out: &mut LintOutcome) {
    for (i, line) in f.code.iter().enumerate() {
        let ln = i + 1;
        if f.is_test_line(ln) {
            continue;
        }
        if !line.contains("thread::scope") {
            continue;
        }
        let end = scope_call_end(f, i);
        let window_end = (end + 10).min(f.code.len());
        let window = &f.code[i..window_end];
        let merged = window.iter().any(|l| MERGE_MARKERS.iter().any(|m| l.contains(m)));
        if !merged && !justified(f, ln, UNORDERED_MERGE) {
            out.violations.push(Violation {
                rule: UNORDERED_MERGE,
                path: f.path.clone(),
                line: ln,
                message: "`thread::scope` fan-out without an index-ordered merge \
                          (`shard_indexed` / `sort_by_key`) in a plan-build module"
                    .to_string(),
            });
        }
    }
}

/// Find the 0-based line index just past the `thread::scope(..)` call
/// starting on line `start`, by balancing parens from the first `(`
/// after the `scope` token.
fn scope_call_end(f: &SourceFile, start: usize) -> usize {
    let mut depth = 0i32;
    let mut seen_open = false;
    for (off, line) in f.code[start..].iter().enumerate() {
        let text: &str = if off == 0 {
            let p = line.find("thread::scope").unwrap_or(0);
            &line[p..]
        } else {
            line
        };
        for c in text.chars() {
            match c {
                '(' => {
                    depth += 1;
                    seen_open = true;
                }
                ')' => {
                    depth -= 1;
                    if seen_open && depth == 0 {
                        return start + off + 1;
                    }
                }
                _ => {}
            }
        }
    }
    f.code.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::SourceFile;

    fn lint(path: &str, src: &str) -> LintOutcome {
        let f = SourceFile::scan(path.to_string(), src);
        let mut out = LintOutcome::default();
        check_file(&f, &mut out);
        out
    }

    #[test]
    fn hashmap_iteration_flagged_only_in_artifact_modules() {
        let src = "\
use std::collections::HashMap;
fn f() {
    let mut m: HashMap<u32, u32> = HashMap::new();
    for (k, v) in &m { use_it(k, v); }
    let s: u32 = m.values().sum();
}
";
        let out = lint("rust/src/coding/x.rs", src);
        let iters: Vec<_> =
            out.violations.iter().filter(|v| v.rule == UNORDERED_ITER).collect();
        assert_eq!(iters.len(), 2, "{:?}", out.violations);
        // Same file outside the artifact modules: no iteration rule.
        let out = lint("rust/src/net/x.rs", src);
        assert!(out.violations.iter().all(|v| v.rule != UNORDERED_ITER));
    }

    #[test]
    fn keyed_lookup_and_indexing_not_flagged() {
        let src = "\
fn f(m: &HashMap<u32, Vec<u32>>) {
    let v = m.get(&3);
    if m.contains_key(&4) {}
    for x in &m[&5] { use_it(x); }
}
";
        let out = lint("rust/src/coding/x.rs", src);
        assert!(out.violations.is_empty(), "{:?}", out.violations);
    }

    #[test]
    fn justified_iteration_passes() {
        let src = "\
fn f(m: &HashMap<u32, u32>) {
    // lint: allow(unordered-iter): order-insensitive reduction (sum)
    let s: u32 = m.values().sum();
}
";
        let out = lint("rust/src/coding/x.rs", src);
        assert!(out.violations.is_empty(), "{:?}", out.violations);
        // A bare allow without a reason does NOT count.
        let src = src.replace(": order-insensitive reduction (sum)", ":");
        let out = lint("rust/src/coding/x.rs", &src);
        assert_eq!(out.violations.len(), 1);
    }

    #[test]
    fn wall_clock_flagged_outside_bench() {
        let src = "fn f() { let t = std::time::Instant::now(); }\n";
        assert_eq!(lint("rust/src/engine/x.rs", src).violations.len(), 1);
        assert!(lint("rust/src/bench/x.rs", src).violations.is_empty());
    }

    #[test]
    fn panic_paths_counted_not_hard_failed() {
        let src = "\
fn f(x: Option<u32>) -> u32 {
    x.unwrap()
}
#[cfg(test)]
mod tests {
    #[test]
    fn t() { Some(1).unwrap(); }
}
";
        let out = lint("rust/src/engine/x.rs", src);
        assert!(out.violations.is_empty());
        assert_eq!(out.panic_counts.get("rust/src/engine/x.rs"), Some(&1));
    }

    #[test]
    fn construction_shims_flagged_everywhere_outside_tests() {
        // The shims are deleted: reintroducing one anywhere in library
        // code — including executor.rs, their former definition site —
        // is a violation. Test code stays exempt.
        let src = "fn f(p: &Plan) { let e = Executor::new(p); }\n";
        assert_eq!(lint("rust/src/engine/exec.rs", src).violations.len(), 1);
        assert_eq!(lint("rust/src/engine/executor.rs", src).violations.len(), 1);
        let test_src = format!("#[test]\nfn t() {{ {} }}\n", "let e = Executor::new(p);");
        assert!(lint("rust/src/engine/exec.rs", &test_src).violations.is_empty());
    }

    #[test]
    fn unmerged_thread_scope_flagged_in_plan_build() {
        let src = "\
fn build() -> Vec<u32> {
    let mut all = Vec::new();
    std::thread::scope(|s| {
        s.spawn(|| all.push(1));
    });
    all
}
";
        let out = lint("rust/src/placement/x.rs", src);
        assert_eq!(out.violations.len(), 1, "{:?}", out.violations);
        assert_eq!(out.violations[0].rule, UNORDERED_MERGE);
        // An index-ordered merge right after the scope satisfies the rule.
        let merged = src.replace("    all\n", "    all.sort_by_key(|&x| x);\n    all\n");
        assert!(lint("rust/src/placement/x.rs", &merged).violations.is_empty());
    }
}
