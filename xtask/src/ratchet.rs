//! The panic-path ratchet: a committed per-file count baseline
//! (`lint_baseline.json`) that can only go down.
//!
//! The file is a flat `{"counts": {"path": n, ...}}` object; the parser
//! below reads exactly that shape (written by `--bless`), keeping xtask
//! at zero dependencies. Counts cover non-test panic sites (`unwrap`,
//! `expect`, `panic!`, `unreachable!`, `todo!`, `unimplemented!`) in
//! `rust/src/**`; files with zero sites are omitted.

use std::collections::BTreeMap;
use std::path::Path;

/// Baseline file name, committed at the workspace root.
pub const BASELINE_FILE: &str = "lint_baseline.json";

/// Outcome of comparing current counts against the baseline.
#[derive(Debug, Default)]
pub struct RatchetReport {
    /// Files whose count exceeds the baseline (file, current, allowed):
    /// hard failures.
    pub over: Vec<(String, usize, usize)>,
    /// Files now strictly below their baseline (file, current, allowed):
    /// informational — re-bless to lock in the progress.
    pub under: Vec<(String, usize, usize)>,
    /// Baseline entries whose file is no longer scanned (deleted or
    /// moved): informational — re-bless to drop them.
    pub stale: Vec<String>,
}

impl RatchetReport {
    pub fn is_over(&self) -> bool {
        !self.over.is_empty()
    }

    pub fn can_tighten(&self) -> bool {
        !self.under.is_empty() || !self.stale.is_empty()
    }
}

/// Compare current per-file counts against the committed baseline.
/// Files absent from the baseline have an allowance of zero — new code
/// must be panic-free from the start.
pub fn compare(
    current: &BTreeMap<String, usize>,
    baseline: &BTreeMap<String, usize>,
) -> RatchetReport {
    let mut report = RatchetReport::default();
    for (file, &count) in current {
        let allowed = baseline.get(file).copied().unwrap_or(0);
        if count > allowed {
            report.over.push((file.clone(), count, allowed));
        } else if count < allowed {
            report.under.push((file.clone(), count, allowed));
        }
    }
    for (file, &allowed) in baseline {
        if allowed > 0 && !current.contains_key(file) {
            report.stale.push(file.clone());
        }
    }
    report
}

/// Serialize counts (nonzero entries only, sorted by path) to the
/// baseline JSON text.
pub fn to_json(counts: &BTreeMap<String, usize>) -> String {
    let mut s = String::from("{\n  \"rule\": \"panic-path\",\n  \"counts\": {\n");
    let nonzero: Vec<_> = counts.iter().filter(|(_, &c)| c > 0).collect();
    for (i, (file, count)) in nonzero.iter().enumerate() {
        let comma = if i + 1 == nonzero.len() { "" } else { "," };
        s.push_str(&format!("    \"{file}\": {count}{comma}\n"));
    }
    s.push_str("  }\n}\n");
    s
}

/// Parse the baseline JSON. Only the exact shape written by
/// [`to_json`] is supported: a `"counts"` object of string keys to
/// non-negative integers (other top-level keys are ignored).
pub fn parse(text: &str) -> Result<BTreeMap<String, usize>, String> {
    let counts_pos = text
        .find("\"counts\"")
        .ok_or_else(|| "baseline: missing \"counts\" key".to_string())?;
    let rest = &text[counts_pos + "\"counts\"".len()..];
    let brace = rest
        .find('{')
        .ok_or_else(|| "baseline: \"counts\" is not an object".to_string())?;
    let body = &rest[brace + 1..];
    let end = body
        .find('}')
        .ok_or_else(|| "baseline: unterminated counts object".to_string())?;
    let mut counts = BTreeMap::new();
    for entry in body[..end].split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (key, value) = entry
            .split_once(':')
            .ok_or_else(|| format!("baseline: malformed entry `{entry}`"))?;
        let key = key.trim();
        let key = key
            .strip_prefix('"')
            .and_then(|k| k.strip_suffix('"'))
            .ok_or_else(|| format!("baseline: unquoted key `{key}`"))?;
        let value: usize = value
            .trim()
            .parse()
            .map_err(|_| format!("baseline: non-integer count for `{key}`"))?;
        counts.insert(key.to_string(), value);
    }
    Ok(counts)
}

/// Load the baseline from `<root>/lint_baseline.json`. A missing file is
/// an empty baseline (zero allowance everywhere) — the ratchet then
/// fails until `--bless` commits one.
pub fn load(root: &Path) -> Result<BTreeMap<String, usize>, String> {
    let path = root.join(BASELINE_FILE);
    if !path.exists() {
        return Ok(BTreeMap::new());
    }
    let text = std::fs::read_to_string(&path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    parse(&text)
}

/// Write counts to `<root>/lint_baseline.json` (the `--bless` path).
pub fn bless(root: &Path, counts: &BTreeMap<String, usize>) -> Result<(), String> {
    let path = root.join(BASELINE_FILE);
    std::fs::write(&path, to_json(counts)).map_err(|e| format!("write {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(pairs: &[(&str, usize)]) -> BTreeMap<String, usize> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn roundtrip() {
        let c = counts(&[("rust/src/a.rs", 3), ("rust/src/b.rs", 1), ("rust/src/z.rs", 0)]);
        let parsed = parse(&to_json(&c)).unwrap();
        // zero-count entries are dropped on write
        assert_eq!(parsed, counts(&[("rust/src/a.rs", 3), ("rust/src/b.rs", 1)]));
    }

    #[test]
    fn empty_counts_roundtrip() {
        assert_eq!(parse(&to_json(&BTreeMap::new())).unwrap(), BTreeMap::new());
    }

    #[test]
    fn ratchet_direction() {
        let baseline = counts(&[("a.rs", 3), ("gone.rs", 2)]);
        let current = counts(&[("a.rs", 2), ("new.rs", 1)]);
        let r = compare(&current, &baseline);
        assert_eq!(r.over, vec![("new.rs".to_string(), 1, 0)]);
        assert_eq!(r.under, vec![("a.rs".to_string(), 2, 3)]);
        assert_eq!(r.stale, vec!["gone.rs".to_string()]);
        assert!(r.is_over() && r.can_tighten());
    }

    #[test]
    fn regression_is_over() {
        let baseline = counts(&[("a.rs", 1)]);
        let current = counts(&[("a.rs", 2)]);
        let r = compare(&current, &baseline);
        assert_eq!(r.over, vec![("a.rs".to_string(), 2, 1)]);
    }

    #[test]
    fn malformed_baseline_is_an_error() {
        assert!(parse("{}").is_err());
        assert!(parse(r#"{"counts": {"a.rs": "x"}}"#).is_err());
    }
}
