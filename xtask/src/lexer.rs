//! A minimal Rust source "lexer" for the determinism linter: strips
//! comments, string literals, and char literals (replacing their bytes
//! with spaces so line/column structure survives), and computes which
//! lines live inside test-only code (`#[cfg(test)]` items, `#[test]`
//! functions).
//!
//! This is deliberately *not* a real parser. The rules it feeds are
//! repo-local conventions over a codebase with rustfmt-normalized style,
//! so a line-oriented scan over comment-free text plus brace-depth
//! tracking is enough — and keeps `xtask` at zero dependencies, matching
//! the crate's no-deps ethos.

/// One scanned source file.
#[derive(Debug)]
pub struct SourceFile {
    /// Repo-relative path, `/`-separated (stable across platforms).
    pub path: String,
    /// Raw source lines, as read (used to find `// lint: allow(...)`
    /// justification directives, which live in comments).
    pub raw: Vec<String>,
    /// Source lines with comments and string/char literal *contents*
    /// blanked to spaces. Rule patterns match against these, so a rule
    /// can never fire on prose inside a doc comment or a format string.
    pub code: Vec<String>,
    /// `test[i]` is true when line `i` (0-based) belongs to test-only
    /// code: a `#[cfg(test)]` item (typically `mod tests { ... }`) or a
    /// `#[test]` function, including the attribute lines themselves.
    pub test: Vec<bool>,
}

impl SourceFile {
    /// Scan one file's source text.
    pub fn scan(path: String, source: &str) -> SourceFile {
        let raw: Vec<String> = source.lines().map(|l| l.to_string()).collect();
        let code = strip(source);
        let test = test_mask(&code);
        SourceFile { path, raw, code, test }
    }

    /// True when 1-based `line` is inside test-only code.
    pub fn is_test_line(&self, line: usize) -> bool {
        self.test.get(line.saturating_sub(1)).copied().unwrap_or(false)
    }
}

/// Strip comments and literal contents from `source`, preserving the
/// line structure. Handles nested block comments, raw strings with any
/// number of `#`s, and the `'a` lifetime vs `'a'` char-literal
/// ambiguity (a lifetime has no closing quote within two characters).
fn strip(source: &str) -> Vec<String> {
    #[derive(PartialEq)]
    enum St {
        Code,
        Line,            // // comment (to end of line)
        Block(usize),    // /* ... */ with nesting depth
        Str,             // "..."
        RawStr(usize),   // r##"..."## with `usize` hashes
        Char,            // '...'
    }
    let mut st = St::Code;
    let mut out = String::with_capacity(source.len());
    let b = source.as_bytes();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match st {
            St::Code => {
                if c == b'/' && b.get(i + 1) == Some(&b'/') {
                    st = St::Line;
                    out.push_str("  ");
                    i += 2;
                } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
                    st = St::Block(1);
                    out.push_str("  ");
                    i += 2;
                } else if c == b'"' {
                    st = St::Str;
                    out.push('"');
                    i += 1;
                } else if c == b'r' && matches!(b.get(i + 1), Some(b'"') | Some(b'#'))
                    && raw_str_hashes(b, i).is_some()
                {
                    let h = raw_str_hashes(b, i).unwrap();
                    st = St::RawStr(h);
                    for _ in 0..(2 + h) {
                        out.push(' ');
                    }
                    i += 2 + h; // r, hashes, opening quote
                } else if c == b'\'' && is_char_literal(b, i) {
                    st = St::Char;
                    out.push('\'');
                    i += 1;
                } else {
                    out.push(c as char);
                    i += 1;
                }
            }
            St::Line => {
                if c == b'\n' {
                    st = St::Code;
                    out.push('\n');
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            St::Block(d) => {
                if c == b'*' && b.get(i + 1) == Some(&b'/') {
                    st = if d == 1 { St::Code } else { St::Block(d - 1) };
                    out.push_str("  ");
                    i += 2;
                } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
                    st = St::Block(d + 1);
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(if c == b'\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            St::Str => {
                if c == b'\\' && i + 1 < b.len() {
                    // Keep line structure across `\`-newline continuations.
                    out.push(' ');
                    out.push(if b[i + 1] == b'\n' { '\n' } else { ' ' });
                    i += 2;
                } else if c == b'"' {
                    st = St::Code;
                    out.push('"');
                    i += 1;
                } else {
                    out.push(if c == b'\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == b'"' && b[i + 1..].iter().take(h).filter(|&&x| x == b'#').count() == h {
                    st = St::Code;
                    for _ in 0..(1 + h) {
                        out.push(' ');
                    }
                    i += 1 + h;
                } else {
                    out.push(if c == b'\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            St::Char => {
                if c == b'\\' && i + 1 < b.len() {
                    out.push_str("  ");
                    i += 2;
                } else if c == b'\'' {
                    st = St::Code;
                    out.push('\'');
                    i += 1;
                } else {
                    out.push(' ');
                    i += 1;
                }
            }
        }
    }
    out.lines().map(|l| l.to_string()).collect()
}

/// At byte `i` (pointing at `r`), return `Some(hashes)` if this starts a
/// raw string literal `r"`, `r#"`, `r##"`, ...
fn raw_str_hashes(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i + 1;
    let mut h = 0;
    while b.get(j) == Some(&b'#') {
        h += 1;
        j += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some(h)
    } else {
        None
    }
}

/// At byte `i` (pointing at `'`), decide char literal vs lifetime: a
/// char literal closes its quote within a few bytes (`'x'`, `'\n'`,
/// `'\u{1F600}'`); a lifetime (`'a`, `'static`) never closes.
fn is_char_literal(b: &[u8], i: usize) -> bool {
    if b.get(i + 1) == Some(&b'\\') {
        return true; // escape sequence: always a char literal
    }
    // `'x'` — one scalar then a closing quote. Multi-byte UTF-8 chars
    // also land within the lookahead window.
    for j in (i + 2)..(i + 6).min(b.len()) {
        if b[j] == b'\'' {
            return true;
        }
        if b[j] == b'\n' {
            return false;
        }
    }
    false
}

/// Compute the test-only mask from comment-free lines: any item
/// introduced by `#[cfg(test)]` (or `#[cfg(all(test, ...))]`) or
/// `#[test]` is test code through its balanced-brace extent (or through
/// its terminating `;` for brace-less items).
fn test_mask(code: &[String]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    let mut depth: i32 = 0;
    // When inside a test region: the depth the region must return to,
    // and whether we've entered the region's braces yet.
    let mut region: Option<(i32, bool)> = None;
    let mut attr_pending = false; // saw the attribute, awaiting the item
    for (ln, line) in code.iter().enumerate() {
        let t = line.trim();
        let is_test_attr = t.starts_with("#[cfg(test)")
            || t.starts_with("#[cfg(all(test")
            || t == "#[test]"
            || t.starts_with("#[test]");
        if region.is_none() && is_test_attr {
            attr_pending = true;
        }
        if region.is_some() || attr_pending {
            mask[ln] = true;
        }
        for c in line.chars() {
            match c {
                '{' => {
                    if attr_pending && region.is_none() {
                        region = Some((depth, true));
                        attr_pending = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if let Some((d, entered)) = region {
                        if entered && depth == d {
                            region = None;
                        }
                    }
                }
                ';' => {
                    // Brace-less test item (e.g. `#[cfg(test)] use ...;`)
                    if attr_pending && region.is_none() {
                        attr_pending = false;
                    }
                }
                _ => {}
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings() {
        let src = concat!(
            "let a = 1; // HashMap::new()\n",
            "let s = \"Instant::now\"; /* unwrap() */ let b = 2;\n"
        );
        let code = strip(src);
        assert!(!code[0].contains("HashMap"));
        assert!(code[0].contains("let a = 1;"));
        assert!(!code[1].contains("Instant"));
        assert!(!code[1].contains("unwrap"));
        assert!(code[1].contains("let b = 2;"));
    }

    #[test]
    fn raw_strings_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let r = r#\"panic!( \"#; let c = '\\''; let d = 'x'; }";
        let code = strip(src).join("\n");
        assert!(!code.contains("panic!"));
        assert!(code.contains("fn f<'a>(x: &'a str)"));
        assert!(!code.contains("'x'") || code.contains("''"), "{code}");
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner unwrap() */ still comment */ let x = 1;";
        let code = strip(src).join("\n");
        assert!(!code.contains("unwrap"));
        assert!(code.contains("let x = 1;"));
    }

    #[test]
    fn test_mask_covers_cfg_test_mod_and_test_fn() {
        let src = "\
fn real() {
    x.unwrap();
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        y.unwrap();
    }
}

fn also_real() {}
";
        let f = SourceFile::scan("a.rs".into(), src);
        assert!(!f.is_test_line(2)); // x.unwrap() in real()
        assert!(f.is_test_line(5)); // #[cfg(test)]
        assert!(f.is_test_line(9)); // y.unwrap()
        assert!(!f.is_test_line(13)); // also_real
    }

    #[test]
    fn test_mask_handles_braceless_items_and_inline_test_fn() {
        let src = "\
#[cfg(test)]
use std::collections::HashSet;

fn real() {}

#[test]
fn t() { z.unwrap(); }

fn real2() {}
";
        let f = SourceFile::scan("a.rs".into(), src);
        assert!(f.is_test_line(2)); // the use item
        assert!(!f.is_test_line(4)); // real()
        assert!(f.is_test_line(7)); // z.unwrap()
        assert!(!f.is_test_line(9)); // real2()
    }
}
