//! `cargo run -p xtask -- lint [--bless] [--root <path>]`
//!
//! Exit codes: 0 = clean, 1 = violations or ratchet regression,
//! 2 = usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "\
xtask — repo-local developer tooling

USAGE:
    cargo run -p xtask -- lint [--bless] [--root <path>]

COMMANDS:
    lint        run the determinism linter over rust/src, rust/benches,
                rust/examples (see DESIGN.md \"Machine-checked
                determinism invariants\")

OPTIONS:
    --bless     rewrite lint_baseline.json with the current panic-path
                counts (only meaningful after a deliberate burndown)
    --root      workspace root to lint (default: parent of xtask/,
                via CARGO_MANIFEST_DIR)
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd = None;
    let mut bless = false;
    let mut root: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "lint" if cmd.is_none() => cmd = Some("lint"),
            "--bless" => bless = true,
            "--root" => match it.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("--root requires a path\n\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" | "help" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("unknown argument `{other}`\n\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    if cmd != Some("lint") {
        print!("{USAGE}");
        return ExitCode::from(2);
    }

    // Default root: the workspace root, i.e. the parent of this crate.
    let root = root.unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(|p| p.to_path_buf())
            .unwrap_or_else(|| PathBuf::from("."))
    });

    let lint = match xtask::lint_repo(&root) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("xtask lint: {e}");
            return ExitCode::from(2);
        }
    };

    if bless {
        if let Err(e) = xtask::ratchet::bless(&root, &lint.outcome.panic_counts) {
            eprintln!("xtask lint --bless: {e}");
            return ExitCode::from(2);
        }
        println!(
            "blessed {}: {} file(s) with non-test panic sites",
            xtask::ratchet::BASELINE_FILE,
            lint.outcome.panic_counts.values().filter(|&&c| c > 0).count()
        );
        // Report against the freshly blessed baseline (always clean on
        // the ratchet axis; hard violations still fail).
        let lint = match xtask::lint_repo(&root) {
            Ok(l) => l,
            Err(e) => {
                eprintln!("xtask lint: {e}");
                return ExitCode::from(2);
            }
        };
        print!("{}", xtask::render_report(&lint));
        return if lint.clean() { ExitCode::SUCCESS } else { ExitCode::FAILURE };
    }

    print!("{}", xtask::render_report(&lint));
    if lint.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
