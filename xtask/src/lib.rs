//! `xtask`: repo-local developer tooling — currently the determinism
//! linter (`cargo run -p xtask -- lint`).
//!
//! The linter machine-checks the invariants behind the crate's
//! byte-identical-artifact contract (see DESIGN.md "Machine-checked
//! determinism invariants"): no hash-ordered iteration in
//! artifact-affecting modules, no wall-clock outside `bench/`, a
//! panic-path ratchet that only goes down, a single `Executor`
//! construction path, and index-ordered merges for plan-build fan-outs.
//! Zero external dependencies, matching the main crate's ethos.

pub mod lexer;
pub mod ratchet;
pub mod rules;

use lexer::SourceFile;
use rules::LintOutcome;
use std::path::{Path, PathBuf};

/// Directories scanned relative to the workspace root. `rust/tests/` is
/// deliberately absent: integration tests are test code end to end.
pub const SCAN_ROOTS: &[&str] = &["rust/src", "rust/benches", "rust/examples"];

/// Result of a full repo lint.
pub struct RepoLint {
    pub outcome: LintOutcome,
    pub ratchet: ratchet::RatchetReport,
    pub files_scanned: usize,
}

impl RepoLint {
    /// True when the lint passes: no hard violations and no file over
    /// its panic ratchet.
    pub fn clean(&self) -> bool {
        self.outcome.violations.is_empty() && !self.ratchet.is_over()
    }
}

/// Collect the repo-relative `/`-separated paths of every `.rs` file
/// under the scan roots, sorted for deterministic report order.
pub fn collect_sources(root: &Path) -> Result<Vec<PathBuf>, String> {
    let mut files = Vec::new();
    for scan in SCAN_ROOTS {
        let dir = root.join(scan);
        if dir.is_dir() {
            walk(&dir, &mut files)?;
        }
    }
    files.sort();
    Ok(files)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint the repository at `root` against its committed ratchet baseline.
pub fn lint_repo(root: &Path) -> Result<RepoLint, String> {
    let files = collect_sources(root)?;
    let mut outcome = LintOutcome::default();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .map_err(|_| format!("{} escapes the repo root", path.display()))?;
        let rel = rel
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let source = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let scanned = SourceFile::scan(rel, &source);
        rules::check_file(&scanned, &mut outcome);
    }
    let baseline = ratchet::load(root)?;
    let ratchet = ratchet::compare(&outcome.panic_counts, &baseline);
    Ok(RepoLint { outcome, ratchet, files_scanned: files.len() })
}

/// Render a full lint report to a string (the CLI prints this).
pub fn render_report(lint: &RepoLint) -> String {
    let mut s = String::new();
    for v in &lint.outcome.violations {
        s.push_str(&v.render());
        s.push('\n');
    }
    for (file, cur, allowed) in &lint.ratchet.over {
        s.push_str(&format!(
            "{file}: [{}] {cur} non-test panic site(s), ratchet allows {allowed}\n",
            rules::PANIC_PATH
        ));
        for site in lint
            .outcome
            .panic_sites
            .iter()
            .filter(|site| &site.path == file)
        {
            s.push_str(&format!("  {}\n", site.render()));
        }
    }
    for (file, cur, allowed) in &lint.ratchet.under {
        s.push_str(&format!(
            "note: {file} is below its panic ratchet ({cur} < {allowed}) — \
             run `cargo run -p xtask -- lint --bless` to lock in the progress\n"
        ));
    }
    for file in &lint.ratchet.stale {
        s.push_str(&format!(
            "note: baseline entry for {file} is stale (file gone) — re-bless to drop it\n"
        ));
    }
    let status = if lint.clean() { "clean" } else { "FAILED" };
    s.push_str(&format!(
        "lint {status}: {} file(s), {} violation(s), {} file(s) over the panic ratchet\n",
        lint.files_scanned,
        lint.outcome.violations.len(),
        lint.ratchet.over.len()
    ));
    s
}
