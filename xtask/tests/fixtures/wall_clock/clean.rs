//! Fixture: metering through the deterministic virtual clock only.
fn meter(elapsed_s: f64, bytes: u64, mbps: f64) -> f64 {
    elapsed_s + (bytes as f64 * 8.0) / (mbps * 1e6)
}
