//! Fixture: wall-clock reads outside bench/.
fn meter() -> f64 {
    let t0 = std::time::Instant::now();
    let _epoch = std::time::SystemTime::now();
    t0.elapsed().as_secs_f64()
}
