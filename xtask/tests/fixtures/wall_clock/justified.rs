//! Fixture: a justified wall-clock read (never part of an artifact).
fn jitter_seed() -> u64 {
    // lint: allow(wall-clock): seeds a log tag only, never an artifact byte
    std::time::SystemTime::now().elapsed().map(|d| d.as_nanos() as u64).unwrap_or(0)
}
