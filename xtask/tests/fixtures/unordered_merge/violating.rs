//! Fixture: a thread::scope fan-out whose merge depends on finish order.
fn build(n: usize, workers: usize) -> Vec<u32> {
    let results = std::sync::Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for w in 0..workers {
            let results = &results;
            s.spawn(move || {
                results.lock().unwrap().push(w as u32);
            });
        }
    });
    results.into_inner().unwrap()
}
