//! Fixture: a fan-out whose merge is order-insensitive by construction.
fn min_over_chunks(chunks: &[Vec<u32>]) -> Option<u32> {
    let mut firsts: Vec<Option<u32>> = vec![None; chunks.len()];
    // lint: allow(unordered-merge): each worker writes its own slot; min() is finish-order independent
    std::thread::scope(|s| {
        for (slot, chunk) in firsts.iter_mut().zip(chunks) {
            s.spawn(move || {
                *slot = chunk.iter().copied().min();
            });
        }
    });
    firsts.into_iter().flatten().min()
}
