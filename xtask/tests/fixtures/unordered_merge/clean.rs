//! Fixture: fan-out merged in index order.
fn build(n: usize, workers: usize) -> Vec<(usize, u32)> {
    let mut shards: Vec<(usize, Vec<u32>)> = Vec::new();
    let m = std::sync::Mutex::new(&mut shards);
    std::thread::scope(|s| {
        for w in 0..workers {
            let m = &m;
            s.spawn(move || {
                m.lock().unwrap().push((w, vec![w as u32]));
            });
        }
    });
    shards.sort_by_key(|&(w, _)| w);
    shards.into_iter().flat_map(|(w, v)| v.into_iter().map(move |x| (w, x))).collect()
}
