//! Fixture: typed-error style; test code may panic freely.
fn f(x: Option<u32>) -> Result<u32, String> {
    x.ok_or_else(|| "x must be set".to_string())
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        assert_eq!(super::f(Some(3)).unwrap(), 3);
    }
}
