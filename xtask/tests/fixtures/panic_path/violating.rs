//! Fixture: panic paths in non-test library code (ratcheted, 4 sites).
fn f(x: Option<u32>, y: Result<u32, ()>) -> u32 {
    let a = x.unwrap();
    let b = y.expect("y must be set");
    if a > b {
        panic!("a > b");
    }
    match a {
        0 => a + b,
        _ => unreachable!("only zero reaches here"),
    }
}
