//! Fixture: a justified shim use (e.g. an FFI boundary pinned to the
//! old signature).
fn legacy_entry(plan: &Plan) -> Result<()> {
    // lint: allow(construction-path): C ABI wrapper pinned to the 0.1 signature
    let mut exec = Executor::new(plan)?;
    exec.run(())
}
