//! Fixture: the single construction path.
fn run(plan: &Plan) -> Result<()> {
    let mut exec = Executor::with_config(plan, ExecConfig::default())?;
    let cfg = ExecConfig::default().mode(ExecMode::Parallel).threads(4);
    let mut par = Executor::with_config(plan, cfg)?;
    exec.run(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn shims_allowed_in_tests() {
        let _ = Executor::new(&plan());
    }
}
