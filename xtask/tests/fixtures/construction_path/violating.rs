//! Fixture: deprecated Executor construction shims outside executor.rs.
fn run(plan: &Plan) -> Result<()> {
    let mut exec = Executor::new(plan)?;
    let mut par = Executor::with_mode(plan, ExecMode::Parallel)?;
    par.set_threads(4);
    exec.run(())
}
