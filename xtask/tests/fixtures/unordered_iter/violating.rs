//! Fixture: HashMap/HashSet iteration in an artifact-affecting module.
use std::collections::{HashMap, HashSet};

fn build(holders: &[u32]) -> Vec<u32> {
    let mut counts: HashMap<u32, usize> = HashMap::new();
    for &h in holders {
        *counts.entry(h).or_insert(0) += 1;
    }
    let mut out = Vec::new();
    for (mask, n) in &counts {
        out.push(mask + *n as u32);
    }
    let seen: HashSet<u32> = holders.iter().copied().collect();
    out.extend(seen.iter().copied());
    out
}
