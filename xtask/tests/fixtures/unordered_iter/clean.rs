//! Fixture: ordered collections iterate freely; hash maps are only
//! used for keyed lookup (never iterated), and `map[&k]` indexing
//! yields the value, not map order.
use std::collections::{BTreeMap, HashMap};

fn build(holders: &[u32], index: &HashMap<u32, Vec<u32>>) -> Vec<u32> {
    let mut counts: BTreeMap<u32, usize> = BTreeMap::new();
    for &h in holders {
        *counts.entry(h).or_insert(0) += 1;
    }
    let mut out = Vec::new();
    for (mask, n) in &counts {
        out.push(mask + *n as u32);
    }
    if index.contains_key(&7) {
        for x in &index[&7] {
            out.push(*x);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;

    #[test]
    fn test_code_is_exempt() {
        let s: HashSet<u32> = [1, 2].into_iter().collect();
        for x in &s {
            assert!(*x > 0);
        }
    }
}
