//! Fixture: a hash iteration with a written order-insensitivity argument.
use std::collections::HashMap;

fn uniform(counts: &HashMap<u32, usize>, per: usize) -> bool {
    // lint: allow(unordered-iter): any()/all() over values is order-insensitive
    counts.values().all(|&c| c == per)
}
