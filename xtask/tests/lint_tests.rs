//! Per-rule fixture coverage for the determinism linter, plus the
//! tier-1 repo gate: the real repository must lint clean against the
//! committed `lint_baseline.json` ratchet.
//!
//! The fixtures under `tests/fixtures/` are never compiled — they are
//! lexed and linted as text, under pseudo-paths that place them in each
//! rule's scope.

use std::path::Path;
use xtask::lexer::SourceFile;
use xtask::rules::{self, LintOutcome};

/// Lint `source` as if it lived at `pseudo_path` inside the repo.
fn lint_fixture(pseudo_path: &str, source: &str) -> LintOutcome {
    let f = SourceFile::scan(pseudo_path.to_string(), source);
    let mut out = LintOutcome::default();
    rules::check_file(&f, &mut out);
    out
}

fn rules_hit(out: &LintOutcome) -> Vec<&'static str> {
    out.violations.iter().map(|v| v.rule).collect()
}

// ------------------------------------------------ rule 1: unordered-iter

#[test]
fn unordered_iter_violating_fixture_is_flagged() {
    let src = include_str!("fixtures/unordered_iter/violating.rs");
    let out = lint_fixture("rust/src/coding/fixture.rs", src);
    // The for-loop over `counts` and `seen.iter()` are both hash-ordered.
    assert_eq!(rules_hit(&out), vec![rules::UNORDERED_ITER, rules::UNORDERED_ITER]);
    // Outside the artifact-affecting modules the same code is legal.
    let out = lint_fixture("rust/src/net/fixture.rs", src);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

#[test]
fn unordered_iter_clean_fixture_passes() {
    let src = include_str!("fixtures/unordered_iter/clean.rs");
    let out = lint_fixture("rust/src/coding/fixture.rs", src);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

#[test]
fn unordered_iter_justified_fixture_passes() {
    let src = include_str!("fixtures/unordered_iter/justified.rs");
    let out = lint_fixture("rust/src/coding/fixture.rs", src);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
    // Stripping the reason invalidates the directive.
    let bare = src.replace(": any()/all() over values is order-insensitive", ":");
    let out = lint_fixture("rust/src/coding/fixture.rs", &bare);
    assert_eq!(rules_hit(&out), vec![rules::UNORDERED_ITER]);
}

// --------------------------------------------------- rule 2: wall-clock

#[test]
fn wall_clock_violating_fixture_is_flagged() {
    let src = include_str!("fixtures/wall_clock/violating.rs");
    let out = lint_fixture("rust/src/engine/fixture.rs", src);
    assert_eq!(rules_hit(&out), vec![rules::WALL_CLOCK, rules::WALL_CLOCK]);
    // bench/ is the opt-in timing harness: wall clock is legal there.
    let out = lint_fixture("rust/src/bench/fixture.rs", src);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

#[test]
fn wall_clock_clean_fixture_passes() {
    let src = include_str!("fixtures/wall_clock/clean.rs");
    let out = lint_fixture("rust/src/engine/fixture.rs", src);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

#[test]
fn wall_clock_justified_fixture_passes() {
    let src = include_str!("fixtures/wall_clock/justified.rs");
    let out = lint_fixture("rust/src/engine/fixture.rs", src);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

// -------------------------------------------------- rule 3: panic paths

#[test]
fn panic_path_violating_fixture_is_counted_not_hard_failed() {
    let src = include_str!("fixtures/panic_path/violating.rs");
    let out = lint_fixture("rust/src/engine/fixture.rs", src);
    // Panic paths never hard-fail: they feed the ratchet.
    assert!(out.violations.is_empty(), "{:?}", out.violations);
    assert_eq!(out.panic_counts.get("rust/src/engine/fixture.rs"), Some(&4));
    assert_eq!(out.panic_sites.len(), 4);
}

#[test]
fn panic_path_clean_fixture_counts_zero() {
    let src = include_str!("fixtures/panic_path/clean.rs");
    let out = lint_fixture("rust/src/engine/fixture.rs", src);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
    // The `#[cfg(test)]` unwrap is exempt; non-test code is panic-free.
    assert_eq!(out.panic_counts.get("rust/src/engine/fixture.rs"), Some(&0));
}

#[test]
fn panic_ratchet_rejects_regression_and_notes_progress() {
    let src = include_str!("fixtures/panic_path/violating.rs");
    let out = lint_fixture("rust/src/engine/fixture.rs", src);
    // Baseline below the current count: over-budget, lint must fail.
    let mut tight = std::collections::BTreeMap::new();
    tight.insert("rust/src/engine/fixture.rs".to_string(), 3usize);
    let report = xtask::ratchet::compare(&out.panic_counts, &tight);
    assert!(report.is_over());
    assert_eq!(report.over, vec![("rust/src/engine/fixture.rs".to_string(), 4, 3)]);
    // Baseline above the current count: passes, but can tighten.
    let mut loose = std::collections::BTreeMap::new();
    loose.insert("rust/src/engine/fixture.rs".to_string(), 9usize);
    let report = xtask::ratchet::compare(&out.panic_counts, &loose);
    assert!(!report.is_over() && report.can_tighten());
    // Absent from the baseline entirely: allowance is zero.
    let report = xtask::ratchet::compare(&out.panic_counts, &std::collections::BTreeMap::new());
    assert!(report.is_over());
}

// ------------------------------------------- rule 4: construction path

#[test]
fn construction_path_violating_fixture_is_flagged() {
    let src = include_str!("fixtures/construction_path/violating.rs");
    let out = lint_fixture("rust/src/engine/fixture.rs", src);
    assert_eq!(
        rules_hit(&out),
        vec![rules::CONSTRUCTION_PATH, rules::CONSTRUCTION_PATH, rules::CONSTRUCTION_PATH]
    );
    // The definition site itself is exempt.
    let out = lint_fixture("rust/src/engine/executor.rs", src);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

#[test]
fn construction_path_clean_fixture_passes() {
    let src = include_str!("fixtures/construction_path/clean.rs");
    let out = lint_fixture("rust/src/engine/fixture.rs", src);
    // `with_config` + the test-module shim use are both legal.
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

#[test]
fn construction_path_justified_fixture_passes() {
    let src = include_str!("fixtures/construction_path/justified.rs");
    let out = lint_fixture("rust/src/engine/fixture.rs", src);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

// ---------------------------------------------- rule 5: ordered merge

#[test]
fn unordered_merge_violating_fixture_is_flagged() {
    let src = include_str!("fixtures/unordered_merge/violating.rs");
    let out = lint_fixture("rust/src/placement/fixture.rs", src);
    assert_eq!(rules_hit(&out), vec![rules::UNORDERED_MERGE]);
    // engine/cache.rs is artifact-affecting but not plan-build: rule 5
    // does not apply there.
    let out = lint_fixture("rust/src/engine/cache.rs", src);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

#[test]
fn unordered_merge_clean_fixture_passes() {
    let src = include_str!("fixtures/unordered_merge/clean.rs");
    let out = lint_fixture("rust/src/placement/fixture.rs", src);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

#[test]
fn unordered_merge_justified_fixture_passes() {
    let src = include_str!("fixtures/unordered_merge/justified.rs");
    let out = lint_fixture("rust/src/placement/fixture.rs", src);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

// ------------------------------------------------- the tier-1 repo gate

/// The real repository lints clean against the committed ratchet. This
/// is the test CI leans on: any hash-ordered iteration, wall-clock read,
/// deprecated shim, unmerged fan-out, or panic-path regression in the
/// scanned tree fails `cargo test` even before the dedicated lint job
/// runs.
#[test]
fn repo_lints_clean_against_committed_ratchet() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let lint = xtask::lint_repo(&root).expect("repo lint must run");
    assert!(lint.files_scanned > 50, "scan roots missing? saw {}", lint.files_scanned);
    let report = xtask::render_report(&lint);
    assert!(lint.outcome.violations.is_empty(), "hard violations:\n{report}");
    assert!(!lint.ratchet.is_over(), "panic ratchet exceeded:\n{report}");
    assert!(lint.clean());
    // The committed baseline has no stale entries for files that no
    // longer exist.
    assert!(lint.ratchet.stale.is_empty(), "stale baseline entries:\n{report}");
}
