//! File placement: subset algebra over allocations, the paper's optimal
//! K=3 placements (Figs 5–11), Lemma 1's pairing computation, the
//! homogeneous cyclic placement of [2], the §V general-K LP, the
//! combinatorial grid design for large K — and the [`Placer`] trait that
//! puts every strategy behind one interface.

pub mod alloc;
pub mod collection_cache;
pub mod combinatorial;
pub mod homogeneous;
pub mod k3;
pub mod lemma1;
pub mod lp_general;
pub mod memshare;
pub mod placer;

pub use alloc::Allocation;
pub use placer::{
    builtin_placers, placer_by_name, placer_by_name_cfg, Placement, Placer, PlacerConfig,
};
