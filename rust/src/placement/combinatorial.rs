//! Combinatorial grid placement for large K — the hypercube/grid design
//! of the combinatorial CDC line (Woolsey et al.; see PAPERS.md), which
//! builds multi-group multicast schedules **without** the §V LP's
//! perfect-collection enumeration (Remark 7): group structure is known by
//! construction, so plan-build cost is polynomial in K and there is no
//! enumeration cap to truncate.
//!
//! Structure: factor `K = q·r` (`q, r >= 2`) and arrange the nodes as an
//! `r`-dimensional grid with `q` nodes per dimension. Subfiles are the
//! lattice points `[q]^r` (subpacketized so every point gets an equal
//! count); lattice point `(p_1, …, p_r)` is stored at the `r` nodes
//! `{X_d[p_d]}` — one holder per dimension. Every node stores `N/q` files
//! worth of subfiles, so the design fits any cluster whose **minimum**
//! storage is at least `N/q` (capacities are upper bounds, like the
//! oblivious baseline — surplus storage is unused).
//!
//! The matching [`crate::coding::combinatorial`] coder exchanges IVs
//! inside the `q^r` *transversal* groups (one node per dimension) with
//! `(r−1)`-segment XOR multicasts: coding gain `r − 1` over uncoded at
//! subpacketization `q^r` instead of `C(K, r)` — the large-K regime the
//! ROADMAP's "cascaded / larger-K" item asks for.

use super::alloc::{Allocation, AllocationBuilder, NodeMask};
use super::homogeneous::gcd;
use crate::error::{HetcdcError, Result};

/// Guardrails for automatic parameter choice: subpacketization and total
/// subfile count beyond these make plans large enough to hurt interactive
/// plan-build latency, so [`choose_grid`] skips such factorizations.
pub const MAX_SP: u64 = 256;
pub const MAX_SUBFILES: u64 = 8192;

/// A feasible grid shape for (K, N): `K = q·r`, subpacketization `sp`
/// (smallest with `q^r | sp·N`), and the per-point subfile multiplicity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GridParams {
    pub q: usize,
    pub r: usize,
    pub sp: u32,
    /// Subfiles per lattice point: `sp·N / q^r`.
    pub per: u64,
}

impl GridParams {
    pub fn n_sub(&self, n: u64) -> usize {
        (self.sp as u64 * n) as usize
    }

    /// Coding gain of the matching combinatorial coder over uncoded.
    pub fn gain(&self) -> usize {
        self.r - 1
    }
}

/// `q^r` with overflow saturation (saturated values always fail the
/// feasibility caps, so the exact magnitude never matters).
fn pow_sat(q: u64, r: u32) -> u64 {
    let mut out = 1u64;
    for _ in 0..r {
        out = out.saturating_mul(q);
    }
    out
}

/// Pick the best grid factorization of `K` for `n` files on a cluster
/// whose smallest node stores `m_min` files: among all `K = q·r` with
/// `q, r >= 2`, per-node footprint `N/q <= m_min`, segment count
/// `r − 1 <= 64`, and subpacketization within [`MAX_SP`]/[`MAX_SUBFILES`],
/// choose the one with the largest coding gain `r − 1` (ties cannot
/// occur: `r` determines the gain). Typed [`HetcdcError::Unsupported`]
/// when no factorization fits.
pub fn choose_grid(k: usize, n: u64, m_min: u64) -> Result<GridParams> {
    let unsupported = |reason: String| HetcdcError::Unsupported {
        strategy: "combinatorial placer",
        reason,
    };
    if k < 4 {
        return Err(unsupported(format!(
            "K={k} has no q·r factorization with q, r >= 2"
        )));
    }
    let mut best: Option<GridParams> = None;
    for r in 2..=k / 2 {
        if k % r != 0 {
            continue;
        }
        let q = k / r;
        if q < 2 || r - 1 > 64 {
            continue;
        }
        // Per-node footprint: N/q files (sp·N/q subfiles at sp subfiles
        // per file). Feasible iff N <= q · m_min.
        if n > q as u64 * m_min {
            continue;
        }
        let lattice = pow_sat(q as u64, r as u32);
        let sp = lattice / gcd(lattice, n);
        if sp > MAX_SP || sp.saturating_mul(n) > MAX_SUBFILES {
            continue;
        }
        let params = GridParams {
            q,
            r,
            sp: sp as u32,
            per: sp * n / lattice,
        };
        if best.map(|b| params.r > b.r).unwrap_or(true) {
            best = Some(params);
        }
    }
    best.ok_or_else(|| {
        unsupported(format!(
            "no q·r grid fits K={k}, N={n}, min storage {m_min} \
             (need N/q <= min storage and subpacketization <= {MAX_SP})"
        ))
    })
}

/// Node `i` of dimension `d` under the contiguous-block convention the
/// placer lays nodes out with: dimensions are blocks of `q` consecutive
/// node ids.
pub fn grid_node(q: usize, d: usize, i: usize) -> usize {
    d * q + i
}

/// Build the grid allocation: lattice points enumerated lexicographically
/// (last coordinate fastest), `per` consecutive subfiles per point, each
/// held by its transversal `{X_d[p_d]}`.
pub fn grid_allocation(k: usize, n: u64, g: &GridParams) -> Allocation {
    debug_assert_eq!(g.q * g.r, k);
    let n_sub = g.n_sub(n);
    let lattice = pow_sat(g.q as u64, g.r as u32) as usize;
    debug_assert_eq!(lattice as u64 * g.per, n_sub as u64);
    let mut b = AllocationBuilder::new(k, g.sp, n_sub);
    let mut coords = vec![0usize; g.r];
    for point in 0..lattice {
        let mut mask: NodeMask = 0;
        for (d, &c) in coords.iter().enumerate() {
            mask |= 1 << grid_node(g.q, d, c);
        }
        let lo = point * g.per as usize;
        b.assign(lo, lo + g.per as usize, mask);
        // Increment the lattice odometer (last coordinate fastest).
        for d in (0..g.r).rev() {
            coords[d] += 1;
            if coords[d] < g.q {
                break;
            }
            coords[d] = 0;
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choose_grid_picks_max_gain_within_storage() {
        // K=8, N=8, m_min=4: q=2/r=4 feasible (N/q = 4), gain 3.
        let g = choose_grid(8, 8, 4).unwrap();
        assert_eq!((g.q, g.r), (2, 4));
        assert_eq!(g.gain(), 3);
        assert_eq!(g.sp, 2); // q^r = 16, gcd(16, 8) = 8
        assert_eq!(g.per, 1);

        // Same K but m_min=2: only q=4/r=2 fits (N/q = 2), gain 1.
        let g = choose_grid(8, 8, 2).unwrap();
        assert_eq!((g.q, g.r), (4, 2));
        assert_eq!(g.gain(), 1);

        // K=16, N=16, m_min=8: q=2/r=8, gain 7, sp=16.
        let g = choose_grid(16, 16, 8).unwrap();
        assert_eq!((g.q, g.r), (2, 8));
        assert_eq!(g.sp, 16);

        // K=12, N=12, m_min=4: q=2 needs storage 6 -> q=3/r=4, gain 3.
        let g = choose_grid(12, 12, 4).unwrap();
        assert_eq!((g.q, g.r), (3, 4));
        assert_eq!(g.sp, 27);
        assert_eq!(g.per, 4);
    }

    #[test]
    fn choose_grid_rejects_infeasible_shapes() {
        for (k, n, m) in [
            (3usize, 6u64, 6u64), // prime K
            (5, 10, 10),          // prime K
            (8, 8, 1),            // storage floor below N/q for every q
            (2, 4, 4),            // K < 4: no q,r >= 2 factorization
        ] {
            let err = choose_grid(k, n, m).unwrap_err();
            assert!(
                matches!(err, HetcdcError::Unsupported { .. }),
                "k={k} n={n} m={m}: {err:?}"
            );
        }
    }

    #[test]
    fn grid_allocation_is_a_uniform_transversal_design() {
        let g = choose_grid(8, 8, 4).unwrap();
        let alloc = grid_allocation(8, 8, &g);
        assert_eq!(alloc.n_sub(), 16);
        // Every subfile held by exactly r nodes, one per dimension block.
        for &h in &alloc.holders {
            assert_eq!(h.count_ones() as usize, g.r);
            for d in 0..g.r {
                let block = ((1u32 << g.q) - 1) << (d * g.q);
                assert_eq!((h & block).count_ones(), 1, "dimension {d}");
            }
        }
        // Uniform multiplicity: every lattice point appears `per` times.
        let sizes = alloc.subset_sizes();
        let occupied: Vec<u64> = sizes.iter().copied().filter(|&c| c > 0).collect();
        assert_eq!(occupied.len(), 16); // q^r distinct transversals
        assert!(occupied.iter().all(|&c| c == g.per));
        // Per-node footprint: n_sub/q subfiles.
        for node in 0..8 {
            assert_eq!(alloc.node_count(node), (alloc.n_sub() / g.q) as u64);
        }
        // Fits a cluster with >= N/q = 4 files everywhere.
        alloc.validate_le(&[4, 4, 5, 5, 6, 6, 7, 7], 8).unwrap();
    }

    #[test]
    fn grid_allocation_with_multiplicity() {
        // K=12, N=12 -> q=3, r=4, per=4: 81 lattice points, 324 subfiles.
        let g = choose_grid(12, 12, 4).unwrap();
        let alloc = grid_allocation(12, 12, &g);
        assert_eq!(alloc.n_sub(), 324);
        let sizes = alloc.subset_sizes();
        assert_eq!(sizes.iter().filter(|&&c| c > 0).count(), 81);
        assert!(sizes.iter().all(|&c| c == 0 || c == 4));
    }
}
