//! Cross-shape memo store for perfect-collection enumeration.
//!
//! The set `C'_j` of perfect collections depends only on `(K, j)` — not
//! on the storage vector, file count, or any other plan input — so two
//! plan builds at different cluster shapes with the same `K` redo
//! byte-identical DFS (or cyclic-orbit) work. The `PlanCache` cannot
//! help: its key includes the storage profile, so a cache miss there
//! still pays full enumeration here. This store memoizes enumeration
//! results behind a deterministic key `(K, j, cap, mode)` shared by
//! every plan build in the process.
//!
//! Determinism: enumeration is a pure function of the key, so
//! first-writer-wins insertion cannot change any artifact byte — a hit
//! returns exactly what a fresh enumeration would. Access is keyed only
//! (no iteration), and the mutex recovers from poisoning by taking the
//! inner value: a panicking enumeration elsewhere must not wedge
//! unrelated plan builds.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};

/// Which enumerator produced the entry. `Full` entries count every
/// completion past the cap (the legacy capped LP); `Seeded` entries
/// carry only a truncation flag (the exact path's growing masters).
/// The two are keyed apart because they cap differently even at equal
/// `cap` values.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CacheMode {
    Full,
    Seeded,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct Key {
    k: u8,
    j: u8,
    cap: usize,
    mode: CacheMode,
}

/// Collections plus the enumerator's count payload (dropped count for
/// `Full`, 0/1 truncation flag for `Seeded`).
type Entry = (Vec<Vec<u32>>, usize);

static CACHE: OnceLock<Mutex<HashMap<Key, Entry>>> = OnceLock::new();
static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

fn lock() -> MutexGuard<'static, HashMap<Key, Entry>> {
    CACHE
        .get_or_init(|| Mutex::new(HashMap::new()))
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
}

/// Return the memoized enumeration for `(k, j, cap, mode)`, running
/// `enumerate` outside the lock on a miss. Concurrent misses on the
/// same key may both enumerate; the first insertion wins and both
/// results are identical by purity.
pub fn get_or_enumerate(
    k: usize,
    j: usize,
    cap: usize,
    mode: CacheMode,
    enumerate: impl FnOnce() -> Entry,
) -> Entry {
    let key = Key {
        k: k as u8,
        j: j as u8,
        cap,
        mode,
    };
    if let Some(hit) = lock().get(&key).cloned() {
        HITS.fetch_add(1, Ordering::Relaxed);
        return hit;
    }
    MISSES.fetch_add(1, Ordering::Relaxed);
    let fresh = enumerate();
    lock().entry(key).or_insert_with(|| fresh.clone());
    fresh
}

/// `(hits, misses)` since process start — monotone counters for bench
/// reporting; not part of any deterministic artifact.
pub fn stats() -> (u64, u64) {
    (HITS.load(Ordering::Relaxed), MISSES.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Keys use a cap no real caller reaches so concurrent test binaries
    // within this process cannot collide with these entries.

    #[test]
    fn keyed_store_memoizes_the_first_result() {
        let a = get_or_enumerate(5, 2, 999_983, CacheMode::Seeded, || (vec![vec![3, 5]], 7));
        // A second call must return the cached value, not this closure's.
        let b = get_or_enumerate(5, 2, 999_983, CacheMode::Seeded, || (vec![vec![9]], 1));
        assert_eq!(a, b);
        assert_eq!(b, (vec![vec![3, 5]], 7));
        let (h, m) = stats();
        assert!(h >= 1 && m >= 1, "hit/miss counters must both have moved");
    }

    #[test]
    fn mode_is_part_of_the_key() {
        let seeded =
            get_or_enumerate(6, 3, 999_979, CacheMode::Seeded, || (vec![vec![1, 2]], 1));
        let full = get_or_enumerate(6, 3, 999_979, CacheMode::Full, || (vec![vec![4, 8]], 2));
        assert_ne!(seeded, full, "Full and Seeded entries must not alias");
    }
}
