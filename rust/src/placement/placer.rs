//! The [`Placer`] trait: pluggable file-placement strategies behind one
//! interface, replacing the old `PlacementStrategy` enum-match that was
//! scattered through the engine.
//!
//! A placer turns a (cluster, job) shape into an [`Allocation`]. The five
//! built-in strategies are trait impls here; third parties can implement
//! [`Placer`] and feed the result straight into
//! [`crate::engine::JobBuilder`]. Placers are pure functions of cluster
//! and job *shape* — never of the data batch — which is what makes their
//! output reusable across batches via [`crate::engine::Plan`].

use super::alloc::Allocation;
use super::{combinatorial, homogeneous, k3, lp_general, memshare};
use crate::error::{HetcdcError, Result};
use crate::model::cluster::ClusterSpec;
use crate::model::job::JobSpec;

/// A placement plus its construction diagnostics: what the placer chose
/// and anything it had to drop to get there. Travels into
/// [`crate::engine::Plan`] so reports and the CLI can surface truncation
/// (e.g. the §V LP's perfect-collection cap) instead of burying it in a
/// comment.
#[derive(Clone, Debug)]
pub struct Placement {
    pub alloc: Allocation,
    /// Perfect collections dropped by an enumeration cap, as
    /// `(subsystem j, dropped count)` — empty for every placer that does
    /// not enumerate (Remark 7 concerns the LP alone). The exact LP path
    /// leaves this empty whenever it certifies.
    pub dropped_collections: Vec<(usize, usize)>,
    /// Deterministic solver work counters — present only for the exact
    /// §V LP path; `None` for every other placer.
    pub lp_stats: Option<lp_general::LpWorkStats>,
}

impl Placement {
    pub fn exact(alloc: Allocation) -> Self {
        Placement {
            alloc,
            dropped_collections: Vec::new(),
            lp_stats: None,
        }
    }
}

/// A file-placement strategy.
pub trait Placer {
    /// Registry name (stable; appears in CLI flags, reports, and
    /// serialized plans).
    fn name(&self) -> &'static str;

    /// Build the §II allocation for this cluster/job shape.
    fn place(&self, cluster: &ClusterSpec, job: &JobSpec) -> Result<Allocation>;

    /// Like [`Placer::place`], but with construction diagnostics. The
    /// default wraps [`Placer::place`] with no diagnostics; placers that
    /// truncate (the §V LP) override it.
    fn place_report(&self, cluster: &ClusterSpec, job: &JobSpec) -> Result<Placement> {
        Ok(Placement::exact(self.place(cluster, job)?))
    }

    /// Name of the [`crate::coding::ShuffleCoder`] that realizes this
    /// placement's coded load (used when the caller does not pick one).
    fn default_coder(&self) -> &'static str {
        "pairing"
    }
}

/// Theorem-1 optimal placement (K=3 only, Figs 5–11).
#[derive(Clone, Copy, Debug, Default)]
pub struct OptimalK3;

impl Placer for OptimalK3 {
    fn name(&self) -> &'static str {
        "optimal-k3"
    }

    fn place(&self, cluster: &ClusterSpec, job: &JobSpec) -> Result<Allocation> {
        let p = cluster.params3(job.n_files)?;
        Ok(k3::optimal_allocation(&p))
    }
}

/// Build-time knobs for registry placers, threaded through from
/// [`crate::engine::JobBuilder`] (and the CLI's `--lp-cap`/`--threads`):
/// the §V LP's Remark-7 enumeration cap, and the worker-thread budget
/// for the parallelizable build stages. Neither knob may change a
/// placement — `threads` is wall-clock only (parallel builds are
/// bit-identical by construction), while `lp_cap` deliberately trades
/// optimality for build time and is surfaced via
/// [`Placement::dropped_collections`] whenever it truncates.
#[derive(Clone, Copy, Debug)]
pub struct PlacerConfig {
    /// Max perfect collections enumerated per subsystem (Remark 7 cap).
    pub lp_cap: usize,
    /// Worker threads for parallel build stages (`<= 1` = serial).
    pub threads: usize,
}

impl Default for PlacerConfig {
    fn default() -> Self {
        PlacerConfig {
            lp_cap: lp_general::DEFAULT_COLLECTION_CAP,
            threads: 1,
        }
    }
}

/// §V LP placement (any K). Exact by default: the solve is certified
/// against the full LP's collapsed dual ([`lp_general::exact_load`]), so
/// the Remark-7 cap costs nothing. `exact: false` keeps the legacy
/// cap-truncated behavior (registry name `"lp-capped"`).
#[derive(Clone, Copy, Debug)]
pub struct LpGeneral {
    /// Max perfect collections enumerated per subsystem (Remark 7 cap) —
    /// the initial seed size on the exact path.
    pub cap: usize,
    /// Worker threads for the enumeration and the simplex pricing scan
    /// (`<= 1` = serial; the solution is identical either way).
    pub threads: usize,
    /// Certify against the collapsed dual and grow past the cap until
    /// exact (default), vs. accept the cap's truncation.
    pub exact: bool,
}

impl Default for LpGeneral {
    fn default() -> Self {
        LpGeneral {
            cap: lp_general::DEFAULT_COLLECTION_CAP,
            threads: 1,
            exact: true,
        }
    }
}

impl Placer for LpGeneral {
    fn name(&self) -> &'static str {
        if self.exact {
            "lp-general"
        } else {
            "lp-capped"
        }
    }

    fn place(&self, cluster: &ClusterSpec, job: &JobSpec) -> Result<Allocation> {
        Ok(self.place_report(cluster, job)?.alloc)
    }

    /// Surfaces the Remark-7 cap: when the enumeration truncates (legacy
    /// path) or the exact path exhausts its growth budget uncertified,
    /// the dropped counts ride along on the placement instead of
    /// vanishing into a comment. The exact path also attaches its
    /// deterministic work counters.
    fn place_report(&self, cluster: &ClusterSpec, job: &JobSpec) -> Result<Placement> {
        let p = cluster.params_k(job.n_files)?;
        let sol = if self.exact {
            lp_general::solve_general_exact_threaded(&p, self.cap, self.threads)?
        } else {
            lp_general::solve_general_threaded(&p, self.cap, self.threads)?
        };
        Ok(Placement {
            alloc: lp_general::allocation_from_solution(&p, &sol),
            dropped_collections: sol.dropped.clone(),
            lp_stats: sol.stats,
        })
    }
}

/// Homogeneous r-redundant placement of [2] (requires equal storage
/// `M_k = r·N/K`; `r` derived from storage).
#[derive(Clone, Copy, Debug, Default)]
pub struct Homogeneous;

impl Placer for Homogeneous {
    fn name(&self) -> &'static str {
        "homogeneous"
    }

    fn place(&self, cluster: &ClusterSpec, job: &JobSpec) -> Result<Allocation> {
        let k = cluster.k();
        let n = job.n_files;
        let storage = cluster.storage();
        let m0 = *storage.first().ok_or_else(|| {
            HetcdcError::InvalidParams("cluster has no nodes".into())
        })?;
        if !storage.iter().all(|&m| m == m0) {
            return Err(HetcdcError::Unsupported {
                strategy: "homogeneous placer",
                reason: "needs equal per-node storage".into(),
            });
        }
        let r = (m0 * k as u64) / n;
        if r * n != m0 * k as u64 || r == 0 {
            return Err(HetcdcError::Unsupported {
                strategy: "homogeneous placer",
                reason: format!("storage {m0} is not r·N/K for any integer r (N={n}, K={k})"),
            });
        }
        if r > k as u64 {
            // M > N: redundancy beyond full replication is meaningless
            // (and would trip symmetric_allocation's assert).
            return Err(HetcdcError::Unsupported {
                strategy: "homogeneous placer",
                reason: format!("storage {m0} exceeds N={n} (r={r} > K={k})"),
            });
        }
        Ok(homogeneous::symmetric_allocation(k, r as usize, n))
    }

    fn default_coder(&self) -> &'static str {
        "multicast"
    }
}

/// Storage-oblivious baseline: provisions every node to the SMALLEST
/// storage and runs the homogeneous memory-sharing scheme — what a
/// heterogeneity-unaware deployment does (the [13] failure mode the
/// paper's introduction cites). Wastes surplus storage.
#[derive(Clone, Copy, Debug, Default)]
pub struct Oblivious;

impl Placer for Oblivious {
    fn name(&self) -> &'static str {
        "oblivious"
    }

    fn place(&self, cluster: &ClusterSpec, job: &JobSpec) -> Result<Allocation> {
        let m_min = *cluster.storage().iter().min().ok_or_else(|| {
            HetcdcError::InvalidParams("cluster has no nodes".into())
        })?;
        let share = memshare::split(cluster.k(), m_min, job.n_files)?;
        Ok(share.allocation())
    }

    fn default_coder(&self) -> &'static str {
        "memshare"
    }
}

/// Combinatorial grid placement for large K
/// ([`crate::placement::combinatorial`]): factor `K = q·r`, lay the nodes
/// out as an r-dimensional grid, store each lattice-point subfile at its
/// transversal. Storage-aware only through the smallest node (capacities
/// are upper bounds, like [`Oblivious`]); its payoff is the matching
/// `combinatorial` coder's gain `r − 1` with **no** perfect-collection
/// enumeration — the large-K regime the §V LP cannot reach.
#[derive(Clone, Copy, Debug, Default)]
pub struct CombinatorialGrid;

impl Placer for CombinatorialGrid {
    fn name(&self) -> &'static str {
        "combinatorial"
    }

    fn place(&self, cluster: &ClusterSpec, job: &JobSpec) -> Result<Allocation> {
        let m_min = *cluster.storage().iter().min().ok_or_else(|| {
            HetcdcError::InvalidParams("cluster has no nodes".into())
        })?;
        let g = combinatorial::choose_grid(cluster.k(), job.n_files, m_min)?;
        Ok(combinatorial::grid_allocation(cluster.k(), job.n_files, &g))
    }

    fn default_coder(&self) -> &'static str {
        "combinatorial"
    }
}

/// Caller-provided allocation (validated against capacities at plan-build
/// time like every other placement).
#[derive(Clone, Debug)]
pub struct Custom(pub Allocation);

impl Placer for Custom {
    fn name(&self) -> &'static str {
        "custom"
    }

    fn place(&self, _cluster: &ClusterSpec, _job: &JobSpec) -> Result<Allocation> {
        Ok(self.0.clone())
    }
}

/// Resolve a registry name to a placer. `"auto"` (and its CLI alias
/// `"optimal"`) picks Theorem 1 for K=3 clusters and the §V LP otherwise.
pub fn placer_by_name(name: &str, cluster: &ClusterSpec) -> Result<Box<dyn Placer>> {
    placer_by_name_cfg(name, cluster, &PlacerConfig::default())
}

/// [`placer_by_name`] with explicit build knobs: the §V LP placer takes
/// its Remark-7 cap and worker-thread budget from `cfg` (other placers
/// have no knobs — their builds are already cheap).
pub fn placer_by_name_cfg(
    name: &str,
    cluster: &ClusterSpec,
    cfg: &PlacerConfig,
) -> Result<Box<dyn Placer>> {
    let lp = |exact: bool| LpGeneral { cap: cfg.lp_cap, threads: cfg.threads, exact };
    match name {
        "optimal-k3" => Ok(Box::new(OptimalK3)),
        "lp-general" | "lp" => Ok(Box::new(lp(true))),
        "lp-capped" => Ok(Box::new(lp(false))),
        "homogeneous" => Ok(Box::new(Homogeneous)),
        "oblivious" => Ok(Box::new(Oblivious)),
        "combinatorial" => Ok(Box::new(CombinatorialGrid)),
        "auto" | "optimal" => {
            if cluster.k() == 3 {
                Ok(Box::new(OptimalK3))
            } else {
                Ok(Box::new(lp(true)))
            }
        }
        other => Err(HetcdcError::UnknownStrategy {
            kind: "placer",
            name: other.to_string(),
        }),
    }
}

/// All built-in placers that need no caller-provided state (for sweeps
/// and property tests).
pub fn builtin_placers() -> Vec<Box<dyn Placer>> {
    vec![
        Box::new(OptimalK3),
        Box::new(LpGeneral::default()),
        Box::new(Homogeneous),
        Box::new(Oblivious),
        Box::new(CombinatorialGrid),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster(storage: &[u64]) -> ClusterSpec {
        let mut c = ClusterSpec::homogeneous(storage.len(), 1, 1000.0);
        for (node, &m) in c.nodes.iter_mut().zip(storage) {
            node.storage = m;
        }
        c
    }

    #[test]
    fn optimal_k3_places_paper_example() {
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let alloc = OptimalK3.place(&c, &job).unwrap();
        alloc.validate(&[6, 7, 7], 12).unwrap();
    }

    #[test]
    fn optimal_k3_rejects_other_k() {
        let c = cluster(&[6, 7, 7, 8]);
        assert!(OptimalK3.place(&c, &JobSpec::terasort(12)).is_err());
    }

    #[test]
    fn homogeneous_rejects_unequal_storage() {
        let c = cluster(&[6, 7, 7]);
        let err = Homogeneous.place(&c, &JobSpec::terasort(12)).unwrap_err();
        assert!(matches!(err, HetcdcError::Unsupported { .. }));
    }

    #[test]
    fn homogeneous_rejects_storage_beyond_n_without_panicking() {
        // M > N would give r > K and trip symmetric_allocation's assert.
        let c = cluster(&[24, 24, 24]);
        let err = Homogeneous.place(&c, &JobSpec::terasort(12)).unwrap_err();
        assert!(matches!(err, HetcdcError::Unsupported { .. }), "{err}");
        // Full replication (r == K) stays supported.
        let c = cluster(&[12, 12, 12]);
        let alloc = Homogeneous.place(&c, &JobSpec::terasort(12)).unwrap();
        assert!(alloc.holders.iter().all(|h| h.count_ones() == 3));
    }

    #[test]
    fn oblivious_empty_cluster_is_typed_error_not_panic() {
        let c = ClusterSpec {
            nodes: vec![],
            latency_ms: 0.0,
            topology: crate::net::Topology::Shared,
            faults: crate::net::FaultSpec::default(),
        };
        let err = Oblivious.place(&c, &JobSpec::terasort(12)).unwrap_err();
        assert!(matches!(err, HetcdcError::InvalidParams(_)));
        let err = Homogeneous.place(&c, &JobSpec::terasort(12)).unwrap_err();
        assert!(matches!(err, HetcdcError::InvalidParams(_)));
    }

    #[test]
    fn combinatorial_places_grids_and_reports_defaults() {
        // K=8 with storage floor 4: q=2, r=4 grid.
        let c = cluster(&[4, 4, 5, 5, 6, 6, 7, 7]);
        let job = JobSpec::terasort(8);
        let alloc = CombinatorialGrid.place(&c, &job).unwrap();
        assert!(alloc.holders.iter().all(|h| h.count_ones() == 4));
        alloc.validate_le(&[4, 4, 5, 5, 6, 6, 7, 7], 8).unwrap();
        assert_eq!(CombinatorialGrid.default_coder(), "combinatorial");
        // Prime K cannot factor: typed Unsupported.
        let c3 = cluster(&[6, 7, 7]);
        let err = CombinatorialGrid.place(&c3, &JobSpec::terasort(12)).unwrap_err();
        assert!(matches!(err, HetcdcError::Unsupported { .. }));
    }

    #[test]
    fn lp_place_report_surfaces_dropped_collections() {
        // Exact default: nothing dropped, certified counters attached.
        let c = cluster(&[3, 4, 5, 6]);
        let job = JobSpec::terasort(8);
        let placement = LpGeneral::default().place_report(&c, &job).unwrap();
        assert!(placement.dropped_collections.is_empty());
        let stats = placement.lp_stats.expect("exact path attaches counters");
        assert!(stats.certified);
        // Legacy capped route: cap of 1 forces truncation at j=2, and the
        // report says so (and carries no exact-path counters).
        let tight = LpGeneral { cap: 1, threads: 1, exact: false };
        let placement = tight.place_report(&c, &job).unwrap();
        assert!(
            placement
                .dropped_collections
                .iter()
                .any(|&(j, d)| j == 2 && d > 0),
            "expected dropped collections at j=2, got {:?}",
            placement.dropped_collections
        );
        assert!(placement.lp_stats.is_none());
        // The exact route outgrows the same starved cap: certified, no
        // drops — the cap only sizes the seed.
        let grown = LpGeneral { cap: 1, threads: 1, exact: true };
        let placement = grown.place_report(&c, &job).unwrap();
        assert!(placement.dropped_collections.is_empty());
        assert!(placement.lp_stats.expect("counters").certified);
        // Non-enumerating placers report no drops via the default impl.
        let p3 = cluster(&[6, 7, 7]);
        let placement = OptimalK3.place_report(&p3, &JobSpec::terasort(12)).unwrap();
        assert!(placement.dropped_collections.is_empty());
        assert!(placement.lp_stats.is_none());
    }

    #[test]
    fn config_threads_lp_cap_through_the_registry() {
        // placer_by_name_cfg hands the Remark-7 cap to the LP placer; on
        // the legacy "lp-capped" route a tight cap shows up as dropped
        // collections in the report, exactly like a hand-built
        // LpGeneral { cap, exact: false } would.
        let c4 = cluster(&[3, 4, 5, 6]);
        let job = JobSpec::terasort(8);
        let tight = PlacerConfig { lp_cap: 1, threads: 2 };
        let placer = placer_by_name_cfg("lp-capped", &c4, &tight).unwrap();
        assert_eq!(placer.name(), "lp-capped");
        let placement = placer.place_report(&c4, &job).unwrap();
        assert!(
            placement.dropped_collections.iter().any(|&(j, d)| j == 2 && d > 0),
            "lp-capped: cap=1 must truncate, got {:?}",
            placement.dropped_collections
        );
        // The exact routes get the same knobs but certify past the cap.
        for name in ["lp-general", "auto"] {
            let placer = placer_by_name_cfg(name, &c4, &tight).unwrap();
            assert_eq!(placer.name(), "lp-general");
            let placement = placer.place_report(&c4, &job).unwrap();
            assert!(
                placement.dropped_collections.is_empty(),
                "{name}: exact path must outgrow cap=1, got {:?}",
                placement.dropped_collections
            );
            assert!(placement.lp_stats.expect("counters").certified, "{name}");
        }
        // The default config is the default cap: nothing dropped at K=4.
        let placer = placer_by_name_cfg("lp-general", &c4, &PlacerConfig::default()).unwrap();
        let placement = placer.place_report(&c4, &job).unwrap();
        assert!(placement.dropped_collections.is_empty());
    }

    #[test]
    fn registry_resolves_names_and_auto() {
        let c3 = cluster(&[6, 7, 7]);
        let c4 = cluster(&[3, 4, 5, 6]);
        assert_eq!(placer_by_name("auto", &c3).unwrap().name(), "optimal-k3");
        assert_eq!(placer_by_name("auto", &c4).unwrap().name(), "lp-general");
        assert_eq!(
            placer_by_name("oblivious", &c3).unwrap().default_coder(),
            "memshare"
        );
        assert_eq!(
            placer_by_name("combinatorial", &c4).unwrap().name(),
            "combinatorial"
        );
        assert_eq!(placer_by_name("lp-capped", &c4).unwrap().name(), "lp-capped");
        assert!(matches!(
            placer_by_name("nope", &c3).unwrap_err(),
            HetcdcError::UnknownStrategy { .. }
        ));
    }
}
