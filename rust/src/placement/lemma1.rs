//! Lemma 1: the achievable load of an arbitrary K=3 allocation and the
//! XOR-pairing counts that realize it.
//!
//! Given subset sizes `S_T`, the load (in subfile units) is
//!
//! ```text
//! L_M = 2(S1 + S2 + S3) + g(S12, S13, S23),
//! g(x) = max(max_i x_i, ceil((x1+x2+x3)/2))
//! ```
//!
//! (the integral form of the paper's absolute-value expression). The
//! pairing counts: node 1 (which stores `S12` and `S13`) sends `alpha`
//! XORs `v_{3,a in S12} ⊕ v_{2,b in S13}`, node 2 sends `beta` over
//! `S12 × S23`, node 3 sends `gamma` over `S13 × S23`, maximizing
//! `alpha + beta + gamma` under the consumption constraints
//! `alpha+beta <= S12`, `alpha+gamma <= S13`, `beta+gamma <= S23`.

use super::alloc::Allocation;

/// Masks of the three pair-subsets, in (S12, S13, S23) order.
pub const PAIR_MASKS: [u32; 3] = [0b011, 0b101, 0b110];

/// Integral `g` function (subfile units).
pub fn g_int(x12: u64, x13: u64, x23: u64) -> u64 {
    let sum = x12 + x13 + x23;
    let max = x12.max(x13).max(x23);
    max.max(sum.div_ceil(2))
}

/// Optimal XOR-pairing counts `(alpha, beta, gamma)` for pair-set sizes.
/// `alpha` pairs (S12, S13) at node 1, `beta` (S12, S23) at node 2,
/// `gamma` (S13, S23) at node 3. Total pairings = `sum − g_int`.
pub fn pairing_counts(x12: u64, x13: u64, x23: u64) -> (u64, u64, u64) {
    // Work on sorted values then un-sort. Pair variables are indexed by
    // the set they DON'T touch: p[0] pairs (x1,x2), etc.
    let mut idx = [0usize, 1, 2];
    let xs = [x12, x13, x23];
    idx.sort_by_key(|&i| xs[i]);
    let (a, b, c) = (xs[idx[0]], xs[idx[1]], xs[idx[2]]); // a <= b <= c
    let mut p = [0u64; 3]; // p[0]: pairs(a,b), p[1]: pairs(a,c), p[2]: pairs(b,c)
    if a + b <= c {
        p[1] = a;
        p[2] = b;
    } else {
        let d = a + b - c;
        p[0] = d / 2;
        let a_rem = a - p[0];
        p[1] = a_rem;
        p[2] = c - a_rem;
    }
    // Map back: pairing that joins sorted-sets (i, j) is the one "opposite"
    // the third sorted set; express as counts per original pair-of-sets.
    // pair (x12, x13) = alpha, (x12, x23) = beta, (x13, x23) = gamma.
    let mut out = [0u64; 3];
    // sorted positions: idx[0] = a's original index, etc.
    let orig = |s: usize| idx[s];
    let assign = |out: &mut [u64; 3], i: usize, j: usize, v: u64| {
        // i, j are original indices in {0:S12, 1:S13, 2:S23}.
        let pair = match (i.min(j), i.max(j)) {
            (0, 1) => 0, // alpha
            (0, 2) => 1, // beta
            (1, 2) => 2, // gamma
            _ => unreachable!(),
        };
        out[pair] += v;
    };
    assign(&mut out, orig(0), orig(1), p[0]);
    assign(&mut out, orig(0), orig(2), p[1]);
    assign(&mut out, orig(1), orig(2), p[2]);
    (out[0], out[1], out[2])
}

/// Subset-size summary for K=3 allocations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Sizes3 {
    pub s1: u64,
    pub s2: u64,
    pub s3: u64,
    pub s12: u64,
    pub s13: u64,
    pub s23: u64,
    pub s123: u64,
}

impl Sizes3 {
    pub fn of(alloc: &Allocation) -> Self {
        assert_eq!(alloc.k, 3, "Sizes3 requires K=3");
        let s = alloc.subset_sizes();
        Sizes3 {
            s1: s[0b001],
            s2: s[0b010],
            s3: s[0b100],
            s12: s[0b011],
            s13: s[0b101],
            s23: s[0b110],
            s123: s[0b111],
        }
    }

    pub fn singles(&self) -> u64 {
        self.s1 + self.s2 + self.s3
    }

    pub fn pairs(&self) -> u64 {
        self.s12 + self.s13 + self.s23
    }
}

/// Lemma 1 achievable load of `alloc`, in subfile units.
pub fn load_units(alloc: &Allocation) -> u64 {
    let s = Sizes3::of(alloc);
    2 * s.singles() + g_int(s.s12, s.s13, s.s23)
}

/// Lemma 1 load in IV-equation units.
pub fn load_equations(alloc: &Allocation) -> f64 {
    alloc.units_to_equations(load_units(alloc))
}

/// Corollary 1 (converse for a FIXED allocation), subfile units, exact
/// when doubled: `2·L_M >= 4 ΣS_k + ΣS_ij`.
pub fn corollary1_lower_bound_doubled(alloc: &Allocation) -> u64 {
    let s = Sizes3::of(alloc);
    4 * s.singles() + s.pairs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn g_examples() {
        assert_eq!(g_int(0, 0, 0), 0);
        assert_eq!(g_int(2, 2, 2), 3); // triangle holds: ceil(6/2)
        assert_eq!(g_int(1, 1, 1), 2); // odd sum: ceil(3/2)
        assert_eq!(g_int(1, 2, 10), 10); // violated: max
        assert_eq!(g_int(0, 0, 5), 5);
        assert_eq!(g_int(3, 4, 5), 6);
    }

    #[test]
    fn pairing_counts_consume_feasibly_and_optimally() {
        for (x12, x13, x23) in [
            (2, 2, 2),
            (1, 2, 10),
            (0, 0, 5),
            (3, 4, 5),
            (1, 1, 1),
            (0, 7, 7),
            (5, 0, 0),
        ] {
            let (a, b, c) = pairing_counts(x12, x13, x23);
            assert!(a + b <= x12, "x12 overconsumed for {x12},{x13},{x23}");
            assert!(a + c <= x13, "x13 overconsumed");
            assert!(b + c <= x23, "x23 overconsumed");
            let total = a + b + c;
            let sum = x12 + x13 + x23;
            assert_eq!(sum - total, g_int(x12, x13, x23), "suboptimal pairing");
        }
    }

    #[test]
    fn prop_pairing_counts_match_g() {
        prop::run("pairing optimal", 2000, |g| {
            let x12 = g.u64_in(0..=40);
            let x13 = g.u64_in(0..=40);
            let x23 = g.u64_in(0..=40);
            let (a, b, c) = pairing_counts(x12, x13, x23);
            if a + b > x12 || a + c > x13 || b + c > x23 {
                return prop::fail(format!("infeasible for ({x12},{x13},{x23})"));
            }
            prop::check(
                x12 + x13 + x23 - (a + b + c) == g_int(x12, x13, x23),
                format!("({x12},{x13},{x23}) -> ({a},{b},{c})"),
            )
        });
    }

    #[test]
    fn sizes_and_load_of_fig2_allocation() {
        // Fig 2 (suboptimal): N=12, node1 files 1-6, node2 files 7-12 + 1,
        // node3 files 2-8. 0-indexed: node1 {0..5}, node2 {6..11, 0}, node3 {1..7}.
        let mut holders = vec![0u32; 12];
        for f in 0..6 {
            holders[f] |= 0b001;
        }
        for f in 6..12 {
            holders[f] |= 0b010;
        }
        holders[0] |= 0b010;
        for f in 1..8 {
            holders[f] |= 0b100;
        }
        let alloc = Allocation::new(3, 1, holders);
        alloc.validate(&[6, 7, 7], 12).unwrap();
        let s = Sizes3::of(&alloc);
        assert_eq!(
            (s.s1, s.s2, s.s3, s.s12, s.s13, s.s23, s.s123),
            (0, 4, 0, 1, 5, 2, 0)
        );
        // L = 2*4 + g(1,5,2) = 8 + 5 = 13, the paper's suboptimal example.
        assert_eq!(load_units(&alloc), 13);
    }

    #[test]
    fn sizes_and_load_of_fig3_allocation() {
        // Fig 3 (optimal): node3 stores {2,4,5,6,7,8,9} (1-indexed) ->
        // 0-indexed {1,3,4,5,6,7,8}.
        let mut holders = vec![0u32; 12];
        for f in 0..6 {
            holders[f] |= 0b001;
        }
        for f in 6..12 {
            holders[f] |= 0b010;
        }
        holders[0] |= 0b010;
        for &f in &[1usize, 3, 4, 5, 6, 7, 8] {
            holders[f] |= 0b100;
        }
        let alloc = Allocation::new(3, 1, holders);
        alloc.validate(&[6, 7, 7], 12).unwrap();
        let s = Sizes3::of(&alloc);
        // S12 = {1}, S13 = {2,4,5,6}, S23 = {7,8,9} (1-indexed);
        // singles: node1-only {3}, node2-only {10,11,12}.
        assert_eq!(
            (s.s1, s.s2, s.s3, s.s12, s.s13, s.s23, s.s123),
            (1, 3, 0, 1, 4, 3, 0)
        );
        // L = 2*4 + g(1,4,3) = 8 + max(4, ceil(8/2)) = 12 = L* (Theorem 1).
        assert_eq!(load_units(&alloc), 12);
    }

    #[test]
    fn prop_lemma1_at_least_corollary1() {
        // For every allocation: 2·L_M >= 4ΣS_k + ΣS_ij, with equality iff
        // the triangle inequality holds (Remark 3).
        prop::run("Lemma1 >= Corollary1", 500, |g| {
            let n_sub = g.usize_in(1..=40);
            let mut holders = Vec::with_capacity(n_sub);
            for _ in 0..n_sub {
                holders.push(g.u64_in(1..=7) as u32);
            }
            let alloc = Allocation::new(3, 1, holders);
            let s = Sizes3::of(&alloc);
            let lhs = 2 * load_units(&alloc);
            let rhs = corollary1_lower_bound_doubled(&alloc);
            let triangle = s.pairs() >= 2 * s.s12.max(s.s13).max(s.s23);
            let even = s.pairs() % 2 == 0;
            if lhs < rhs {
                return prop::fail(format!("violates corollary: {s:?}"));
            }
            if triangle && even && lhs != rhs {
                return prop::fail(format!("should be tight: {s:?} lhs={lhs} rhs={rhs}"));
            }
            Ok(())
        });
    }
}
