//! The paper's optimal K=3 file placements (§III, Figs 5–11).
//!
//! Constructions are done in **doubled units** (subpacketization `sp = 2`,
//! DESIGN.md §8): with `n = 2N`, `mk = 2M_k` every half-integral interval
//! endpoint in the paper becomes an integer subfile index, for *all*
//! integer parameters. The returned [`Allocation`] therefore has `2N`
//! subfiles and its Lemma-1 load in subfile units equals `2·L*` exactly.
//!
//! The paper assumes `M1 <= M2 <= M3`; we sort internally and un-permute
//! the node masks, so callers keep their node order.

use super::alloc::{Allocation, AllocationBuilder};
use crate::theory::load::{classify, Regime};
use crate::theory::params::Params3;

/// Construct the load-optimal allocation for `p` (Theorem 1 achievability).
pub fn optimal_allocation(p: &Params3) -> Allocation {
    let ([m1, m2, m3], perm) = p.sorted();
    let (m1, m2, m3) = ((2 * m1) as usize, (2 * m2) as usize, (2 * m3) as usize);
    let n = (2 * p.n) as usize;
    let m = m1 + m2 + m3;
    // Bit for sorted-node i in the original node order.
    let bit = |i: usize| 1u32 << perm[i];
    let (b1, b2, b3) = (bit(0), bit(1), bit(2));
    let mut b = AllocationBuilder::new(3, 2, n);

    match classify(p) {
        Regime::R1 => {
            // Fig 5: sequential for nodes 1, 2; node 3 takes the tail plus
            // a centered straddle of (M−N)/2 on each side of the 1|2 seam.
            let h = (m - n) / 2;
            b.assign(0, m1, b1);
            b.assign(m1, m1 + m2, b2);
            b.assign(m1 + m2, n, b3);
            b.assign(m1 - h, m1 + h, b3);
        }
        Regime::R4 => {
            // Fig 6: node 3 takes the tail plus a prefix of length M−N.
            b.assign(0, m1, b1);
            b.assign(m1, m1 + m2, b2);
            b.assign(m1 + m2, n, b3);
            b.assign(0, m - n, b3);
        }
        Regime::R2 => {
            // Fig 7: node 2 wraps; node 3 = [e, 2e) plus a straddle of f
            // on each side of M1's right edge, where e = M1+M2−N,
            // f = (M3 − e)/2.
            let e = m1 + m2 - n;
            let f = (m3 - e) / 2;
            b.assign(0, m1, b1);
            b.assign(m1, n, b2);
            b.assign(0, e, b2);
            b.assign(e, 2 * e, b3);
            b.assign(m1 - f, m1 + f, b3);
        }
        Regime::R3 | Regime::R5 => {
            // Figs 8/9: node 2 wraps; node 3 = [e, M−N).
            let e = m1 + m2 - n;
            b.assign(0, m1, b1);
            b.assign(m1, n, b2);
            b.assign(0, e, b2);
            b.assign(e, m - n, b3);
        }
        Regime::R6 | Regime::R7 => {
            // Figs 10/11: M > 2N; all three wrap, S123 = M − 2N.
            let e = m1 + m2 - n;
            b.assign(0, m1, b1);
            b.assign(m1, n, b2);
            b.assign(0, e, b2);
            b.assign(e, n, b3);
            b.assign(0, m - 2 * n, b3);
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::lemma1::{load_units, Sizes3};
    use crate::prop;
    use crate::theory::load::{lstar_half, uncoded_half};

    fn p(m1: u64, m2: u64, m3: u64, n: u64) -> Params3 {
        Params3::new(m1, m2, m3, n).unwrap()
    }

    /// Sizes in sorted-node space (tests use sorted inputs, so identity).
    fn sizes(params: &Params3) -> Sizes3 {
        Sizes3::of(&optimal_allocation(params))
    }

    #[test]
    fn r1_subset_sizes_match_eq12() {
        // (4,5,6,12): doubled h = (M−N)/2 -> subfile units (M−N) = 3·2/2=3.
        let params = p(4, 5, 6, 12);
        let s = sizes(&params);
        let (m1, m2, n, m) = (8, 10, 24, 30);
        let h = (m - n) / 2;
        assert_eq!(s.s1, m1 - h);
        assert_eq!(s.s2, m2 - h);
        assert_eq!(s.s3, n - m1 - m2);
        assert_eq!(s.s12, 0);
        assert_eq!(s.s13, h);
        assert_eq!(s.s23, h);
        assert_eq!(s.s123, 0);
    }

    #[test]
    fn r4_subset_sizes_match_eq15() {
        let params = p(2, 3, 12, 12); // R4
        let s = sizes(&params);
        let (m1, m2, m3, n) = (4u64, 6, 24, 24);
        assert_eq!(s.s1, 0);
        assert_eq!(s.s2, n - m3);
        assert_eq!(s.s3, n - m1 - m2);
        assert_eq!(s.s12, 0);
        assert_eq!(s.s13, m1);
        assert_eq!(s.s23, m2 + m3 - n);
    }

    #[test]
    fn r2_subset_sizes_match_eq18() {
        let params = p(4, 5, 5, 8); // R2 (sorted so masks match sorted space)
        let s = sizes(&params);
        let (m1, m2, m3, n) = (8u64, 10, 10, 16);
        let e = m1 + m2 - n;
        let f = (m3 - e) / 2;
        assert_eq!(s.s1, m1 - 2 * e - f);
        assert_eq!(s.s2, n - m1 - f);
        assert_eq!(s.s3, 0);
        assert_eq!(s.s12, e);
        assert_eq!(s.s13, e + f);
        assert_eq!(s.s23, f);
    }

    #[test]
    fn r3_r5_subset_sizes_match_eq21() {
        for params in [p(8, 8, 8, 12), p(5, 8, 11, 12)] {
            let ([m1, m2, m3], _) = params.sorted();
            let (m1, m2, m3) = (2 * m1, 2 * m2, 2 * m3);
            let n = 2 * params.n;
            let s = sizes(&params);
            assert_eq!(s.s1, 0, "{params}");
            assert_eq!(s.s2, 2 * n - (m1 + m2 + m3), "{params}");
            assert_eq!(s.s3, 0, "{params}");
            assert_eq!(s.s12, m1 + m2 - n, "{params}");
            assert_eq!(s.s13, n - m2, "{params}");
            assert_eq!(s.s23, m2 + m3 - n, "{params}");
        }
    }

    #[test]
    fn r6_r7_subset_sizes_match_eq25() {
        for params in [p(10, 10, 10, 12), p(5, 11, 11, 12)] {
            let ([m1, m2, m3], _) = params.sorted();
            let (m1, m2, m3) = (2 * m1, 2 * m2, 2 * m3);
            let n = 2 * params.n;
            let m = m1 + m2 + m3;
            let s = sizes(&params);
            assert_eq!(s.s123, m - 2 * n, "{params}");
            assert_eq!(s.s12, n - m3, "{params}");
            assert_eq!(s.s13, n - m2, "{params}");
            assert_eq!(s.s23, n - m1, "{params}");
            assert_eq!(s.singles(), 0, "{params}");
        }
    }

    #[test]
    fn paper_example_achieves_12() {
        let params = p(6, 7, 7, 12);
        let alloc = optimal_allocation(&params);
        alloc.validate(&[6, 7, 7], 12).unwrap();
        assert_eq!(load_units(&alloc), lstar_half(&params)); // 24 half-units
        assert_eq!(alloc.units_to_equations(load_units(&alloc)), 12.0);
    }

    #[test]
    fn unsorted_inputs_respect_original_node_capacities() {
        let params = p(11, 5, 11, 12); // node 1 is NOT the smallest
        let alloc = optimal_allocation(&params);
        alloc.validate(&[11, 5, 11], 12).unwrap();
        assert_eq!(load_units(&alloc), lstar_half(&params));
    }

    #[test]
    fn prop_allocation_achieves_lstar_everywhere() {
        // The central achievability test: for EVERY valid (M1,M2,M3,N) the
        // constructed placement is (a) a valid allocation and (b) its
        // Lemma-1 load equals the closed form L* exactly (half-units).
        prop::run("k3 placement achieves L*", 1500, |g| {
            let n = g.u64_in(1..=40);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(params) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let alloc = optimal_allocation(&params);
            if let Err(e) = alloc.validate(&[m1, m2, m3], n) {
                return prop::fail(format!("{params}: invalid allocation: {e}"));
            }
            let got = load_units(&alloc);
            let want = lstar_half(&params);
            prop::check(got == want, format!("{params}: load {got} != L*half {want}"))
        });
    }

    #[test]
    fn prop_allocation_beats_or_ties_uncoded() {
        prop::run("coded <= uncoded", 400, |g| {
            let n = g.u64_in(1..=30);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(params) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let alloc = optimal_allocation(&params);
            prop::check(
                load_units(&alloc) <= uncoded_half(&params),
                format!("{params}"),
            )
        });
    }
}
