//! File allocations and the `S_T` subset algebra of §III.
//!
//! An [`Allocation`] maps every *subfile* to the set of nodes storing it.
//! Subfiles are the paper's files after subpacketization by `sp` (DESIGN.md
//! §8): with `sp = 2` every original file is split in half so that all of
//! Theorem 1's half-integral expressions become integral. Holder sets are
//! node bitmasks (`K <= 32`).

use crate::error::{HetcdcError, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;

pub type NodeMask = u32;

fn invalid(msg: impl Into<String>) -> HetcdcError {
    HetcdcError::InvalidPlacement(msg.into())
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Allocation {
    /// Number of nodes K.
    pub k: usize,
    /// Subpacketization factor: subfiles per original file.
    pub sp: u32,
    /// `holders[f]` = bitmask of nodes storing subfile `f`. Length `sp·N`.
    pub holders: Vec<NodeMask>,
}

impl Allocation {
    pub fn new(k: usize, sp: u32, holders: Vec<NodeMask>) -> Self {
        assert!(k >= 1 && k <= 32);
        Self { k, sp, holders }
    }

    /// Number of subfiles (`sp · N`).
    pub fn n_sub(&self) -> usize {
        self.holders.len()
    }

    /// Number of original files.
    pub fn n_files(&self) -> usize {
        self.n_sub() / self.sp as usize
    }

    pub fn full_mask(&self) -> NodeMask {
        if self.k == 32 {
            u32::MAX
        } else {
            (1u32 << self.k) - 1
        }
    }

    /// Subfiles stored at node `node`.
    pub fn node_count(&self, node: usize) -> u64 {
        let bit = 1u32 << node;
        self.holders.iter().filter(|&&h| h & bit != 0).count() as u64
    }

    /// `S_T` cardinalities: `sizes[mask]` = #subfiles whose holder set is
    /// exactly `mask`. Index 0 (unstored) must be empty for validity.
    pub fn subset_sizes(&self) -> Vec<u64> {
        let mut sizes = vec![0u64; 1 << self.k];
        for &h in &self.holders {
            sizes[h as usize] += 1;
        }
        sizes
    }

    /// Subfiles whose holder set is exactly `mask`, in index order.
    pub fn subfiles_with_mask(&self, mask: NodeMask) -> Vec<usize> {
        self.holders
            .iter()
            .enumerate()
            .filter(|(_, &h)| h == mask)
            .map(|(f, _)| f)
            .collect()
    }

    /// Validate the §II model constraints against per-node capacities
    /// `m` (in original files) and file count `n`.
    pub fn validate(&self, m: &[u64], n: u64) -> Result<()> {
        if m.len() != self.k {
            return Err(invalid(format!(
                "expected {} capacities, got {}",
                self.k,
                m.len()
            )));
        }
        if self.n_sub() as u64 != self.sp as u64 * n {
            return Err(invalid(format!(
                "expected {} subfiles, got {}",
                self.sp as u64 * n,
                self.n_sub()
            )));
        }
        for (f, &h) in self.holders.iter().enumerate() {
            if h == 0 {
                return Err(invalid(format!("subfile {f} stored nowhere")));
            }
            if h & !self.full_mask() != 0 {
                return Err(invalid(format!("subfile {f} has out-of-range holder bits")));
            }
        }
        for (node, &cap) in m.iter().enumerate() {
            let used = self.node_count(node);
            let cap_sub = cap * self.sp as u64;
            if used != cap_sub {
                return Err(invalid(format!(
                    "node {node} stores {used} subfiles, capacity is {cap_sub}"
                )));
            }
        }
        Ok(())
    }

    /// Like [`Self::validate`] but treats capacities as upper bounds
    /// (`<=`), for schemes that deliberately waste storage (e.g. the
    /// storage-oblivious baseline that provisions to the smallest node).
    pub fn validate_le(&self, m: &[u64], n: u64) -> Result<()> {
        if m.len() != self.k {
            return Err(invalid(format!(
                "expected {} capacities, got {}",
                self.k,
                m.len()
            )));
        }
        if self.n_sub() as u64 != self.sp as u64 * n {
            return Err(invalid(format!(
                "expected {} subfiles, got {}",
                self.sp as u64 * n,
                self.n_sub()
            )));
        }
        for (f, &h) in self.holders.iter().enumerate() {
            if h == 0 || h & !self.full_mask() != 0 {
                return Err(invalid(format!("subfile {f} has invalid holder set {h:b}")));
            }
        }
        for (node, &cap) in m.iter().enumerate() {
            let used = self.node_count(node);
            if used > cap * self.sp as u64 {
                return Err(invalid(format!(
                    "node {node} stores {used} subfiles, capacity is {}",
                    cap * self.sp as u64
                )));
            }
        }
        Ok(())
    }

    /// JSON form used inside serialized [`crate::engine::Plan`] artifacts.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("k".into(), Json::Num(self.k as f64));
        m.insert("sp".into(), Json::Num(self.sp as f64));
        m.insert(
            "holders".into(),
            Json::Arr(self.holders.iter().map(|&h| Json::Num(h as f64)).collect()),
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let bad = |f: &str| HetcdcError::Json(format!("allocation: missing or invalid '{f}'"));
        let k = j.get("k").and_then(|v| v.as_usize()).ok_or_else(|| bad("k"))?;
        if !(1..=32).contains(&k) {
            return Err(invalid(format!("k = {k} out of range [1, 32]")));
        }
        let sp = j.get("sp").and_then(|v| v.as_usize()).ok_or_else(|| bad("sp"))? as u32;
        if sp == 0 {
            return Err(invalid("sp must be positive"));
        }
        let holders = j
            .get("holders")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| bad("holders"))?
            .iter()
            .map(|h| {
                h.as_usize()
                    .filter(|&h| h <= u32::MAX as usize)
                    .map(|h| h as u32)
                    .ok_or_else(|| bad("holders"))
            })
            .collect::<Result<Vec<NodeMask>>>()?;
        Ok(Allocation::new(k, sp, holders))
    }

    /// Total uncoded shuffle load in subfile units: every subfile stored at
    /// `r` nodes needs `K − r` deliveries (Q = K function groups).
    pub fn uncoded_units(&self) -> u64 {
        self.holders
            .iter()
            .map(|h| (self.k as u32 - h.count_ones()) as u64)
            .sum()
    }

    /// Load expressed in IV-equation units (units / sp).
    pub fn units_to_equations(&self, units: u64) -> f64 {
        units as f64 / self.sp as f64
    }
}

/// Builder: start from "nothing stored", assign ranges to node sets.
pub struct AllocationBuilder {
    k: usize,
    sp: u32,
    holders: Vec<NodeMask>,
}

impl AllocationBuilder {
    pub fn new(k: usize, sp: u32, n_sub: usize) -> Self {
        Self {
            k,
            sp,
            holders: vec![0; n_sub],
        }
    }

    /// Add nodes in `mask` as holders of subfiles `[lo, hi)`.
    pub fn assign(&mut self, lo: usize, hi: usize, mask: NodeMask) -> &mut Self {
        assert!(hi <= self.holders.len(), "range [{lo},{hi}) out of bounds");
        for f in lo..hi {
            self.holders[f] |= mask;
        }
        self
    }

    pub fn build(self) -> Allocation {
        Allocation::new(self.k, self.sp, self.holders)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Allocation {
        // K=3, sp=1, N=4: file0 at {0}, file1 at {0,1}, file2 at {1,2}, file3 at {0,1,2}.
        Allocation::new(3, 1, vec![0b001, 0b011, 0b110, 0b111])
    }

    #[test]
    fn subset_sizes_count_exact_masks() {
        let a = demo();
        let s = a.subset_sizes();
        assert_eq!(s[0b001], 1);
        assert_eq!(s[0b011], 1);
        assert_eq!(s[0b110], 1);
        assert_eq!(s[0b111], 1);
        assert_eq!(s[0b010], 0);
        assert_eq!(s.iter().sum::<u64>(), 4);
    }

    #[test]
    fn node_counts() {
        let a = demo();
        assert_eq!(a.node_count(0), 3);
        assert_eq!(a.node_count(1), 3);
        assert_eq!(a.node_count(2), 2);
    }

    #[test]
    fn validate_happy_path() {
        let a = demo();
        assert!(a.validate(&[3, 3, 2], 4).is_ok());
    }

    #[test]
    fn validate_rejects_uncovered_file() {
        let a = Allocation::new(3, 1, vec![0b001, 0]);
        assert!(a
            .validate(&[1, 0, 0], 2)
            .unwrap_err()
            .to_string()
            .contains("nowhere"));
    }

    #[test]
    fn validate_rejects_wrong_capacity() {
        let a = demo();
        assert!(a.validate(&[2, 3, 2], 4).is_err());
        assert!(a.validate(&[3, 3, 2], 5).is_err());
    }

    #[test]
    fn uncoded_units_counts_deliveries() {
        let a = demo();
        // file0: 2 deliveries, file1: 1, file2: 1, file3: 0.
        assert_eq!(a.uncoded_units(), 4);
    }

    #[test]
    fn builder_assigns_ranges() {
        let mut b = AllocationBuilder::new(3, 2, 6);
        b.assign(0, 4, 0b001).assign(2, 6, 0b010);
        let a = b.build();
        assert_eq!(a.holders, vec![0b001, 0b001, 0b011, 0b011, 0b010, 0b010]);
        assert_eq!(a.n_files(), 3);
    }

    #[test]
    fn json_roundtrip() {
        let a = demo();
        let back = Allocation::from_json(&a.to_json()).unwrap();
        assert_eq!(a, back);
        assert!(Allocation::from_json(&Json::Obj(Default::default())).is_err());
    }

    #[test]
    fn subfiles_with_mask_in_order() {
        let a = demo();
        assert_eq!(a.subfiles_with_mask(0b011), vec![1]);
        assert_eq!(a.subfiles_with_mask(0b100), Vec::<usize>::new());
    }
}
