//! Homogeneous CDC placement of Li–Maddah-Ali–Avestimehr [2]: symmetric
//! r-redundant placement over all `C(K, r)` subsets. This is the baseline
//! the paper's Remark 2 reduces to, and the structure its §V algorithm
//! reuses inside each j-subsystem.

use super::alloc::{Allocation, AllocationBuilder};

/// Enumerate all size-`r` subsets of `{0..k}` as bitmasks, in
/// lexicographic mask order.
pub fn subsets_of_size(k: usize, r: usize) -> Vec<u32> {
    let mut out = Vec::new();
    for mask in 0u32..(1 << k) {
        if mask.count_ones() as usize == r {
            out.push(mask);
        }
    }
    out
}

/// Binomial coefficient (small arguments).
pub fn binom(n: u64, k: u64) -> u64 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut num = 1u64;
    for i in 0..k {
        num = num * (n - i) / (i + 1);
    }
    num
}

/// Symmetric placement: `n` files spread evenly over all `C(k, r)`
/// r-subsets. Subpacketizes by `C(k, r)` when `n` is not divisible, so the
/// result is always exact: every subset holds `n_sub / C(k,r)` subfiles.
pub fn symmetric_allocation(k: usize, r: usize, n: u64) -> Allocation {
    assert!(r >= 1 && r <= k);
    let masks = subsets_of_size(k, r);
    let c = masks.len() as u64;
    // Subpacketization: smallest sp with c | sp*n.
    let g = gcd(n, c);
    let sp = (c / g) as u32;
    let n_sub = (sp as u64 * n) as usize;
    let per = n_sub / c as usize;
    let mut b = AllocationBuilder::new(k, sp, n_sub);
    for (i, &mask) in masks.iter().enumerate() {
        b.assign(i * per, (i + 1) * per, mask);
    }
    b.build()
}

/// Euclid's gcd (shared across the placement constructions).
pub(crate) fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn binom_values() {
        assert_eq!(binom(3, 2), 3);
        assert_eq!(binom(4, 2), 6);
        assert_eq!(binom(6, 3), 20);
        assert_eq!(binom(5, 0), 1);
        assert_eq!(binom(3, 5), 0);
    }

    #[test]
    fn subsets_enumeration() {
        let s = subsets_of_size(4, 2);
        assert_eq!(s.len(), 6);
        assert!(s.iter().all(|m| m.count_ones() == 2 && *m < 16));
    }

    #[test]
    fn symmetric_allocation_is_balanced() {
        // K=3, r=2, N=12: C(3,2)=3 divides 12 -> sp=1, 4 files per pair.
        let a = symmetric_allocation(3, 2, 12);
        assert_eq!(a.sp, 1);
        let sizes = a.subset_sizes();
        for mask in subsets_of_size(3, 2) {
            assert_eq!(sizes[mask as usize], 4);
        }
        for node in 0..3 {
            assert_eq!(a.node_count(node), 8); // rN/K = 2*12/3 per node
        }
    }

    #[test]
    fn symmetric_allocation_subpacketizes_when_needed() {
        // K=4, r=2, N=9: C=6, gcd(9,6)=3 -> sp=2, 18 subfiles, 3 per pair.
        let a = symmetric_allocation(4, 2, 9);
        assert_eq!(a.sp, 2);
        assert_eq!(a.n_sub(), 18);
        let sizes = a.subset_sizes();
        for mask in subsets_of_size(4, 2) {
            assert_eq!(sizes[mask as usize], 3);
        }
    }

    #[test]
    fn prop_symmetric_allocation_valid() {
        prop::run("symmetric placement valid", 200, |g| {
            let k = g.usize_in(2..=6);
            let r = g.usize_in(1..=k);
            let n = g.u64_in(1..=30);
            let a = symmetric_allocation(k, r, n);
            let mk = r as u64 * n * a.sp as u64 / k as u64;
            // Every node stores the same number of subfiles = r·n_sub/k.
            for node in 0..k {
                if a.node_count(node) * k as u64 != r as u64 * a.n_sub() as u64 {
                    return prop::fail(format!("k={k} r={r} n={n}: unbalanced node {node}"));
                }
            }
            let _ = mk;
            // All subfiles stored at exactly r nodes.
            prop::check(
                a.holders.iter().all(|h| h.count_ones() as usize == r),
                format!("k={k} r={r} n={n}"),
            )
        });
    }
}
