//! Memory-sharing achievability for homogeneous clusters at non-integer
//! computation load (the lower convex envelope of Remark 2 / [2]).
//!
//! For `K` nodes with equal storage `M` and `r = KM/N ∉ Z`, split the file
//! set into two sub-instances: `N_hi = KM − ⌊r⌋N` files at redundancy
//! `⌈r⌉` and the remaining `N_lo` at `⌊r⌋`. Each sub-instance runs [2]'s
//! symmetric placement + multicast; total load equals the envelope
//! `(1−w)·L(⌊r⌋) + w·L(⌈r⌉)` exactly, which matches Theorem 1's `L*` at
//! `M1=M2=M3` (verified in tests).

use super::alloc::Allocation;
use super::homogeneous::{gcd, symmetric_allocation};
use crate::coding::cdc_multicast::plan_homogeneous;
use crate::coding::plan::ShufflePlan;
use crate::error::{HetcdcError, Result};

/// The two-regime split of a homogeneous memory-sharing design.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MemShare {
    pub k: usize,
    pub n: u64,
    pub m_per_node: u64,
    pub r_lo: u64,
    pub r_hi: u64,
    pub n_lo: u64,
    pub n_hi: u64,
}

/// Compute the split. Errors when `KM < N` (cannot cover) or `M > N`.
pub fn split(k: usize, m_per_node: u64, n: u64) -> Result<MemShare> {
    let km = k as u64 * m_per_node;
    if km < n {
        return Err(HetcdcError::InvalidParams(format!(
            "K·M = {km} cannot cover N = {n}"
        )));
    }
    if m_per_node > n {
        return Err(HetcdcError::InvalidParams(format!(
            "M = {m_per_node} exceeds N = {n}"
        )));
    }
    let r_lo = km / n; // floor(r)
    let r_hi = if km % n == 0 { r_lo } else { r_lo + 1 };
    let n_hi = if r_hi == r_lo { 0 } else { km - r_lo * n };
    let n_lo = n - n_hi;
    Ok(MemShare {
        k,
        n,
        m_per_node,
        r_lo,
        r_hi,
        n_lo,
        n_hi,
    })
}

impl MemShare {
    /// Build the combined allocation: sub-instance allocations laid out
    /// side by side (subfile ids offset), at a common subpacketization.
    pub fn allocation(&self) -> Allocation {
        let lo = if self.n_lo > 0 {
            Some(symmetric_allocation(self.k, self.r_lo as usize, self.n_lo))
        } else {
            None
        };
        let hi = if self.n_hi > 0 {
            Some(symmetric_allocation(self.k, self.r_hi as usize, self.n_hi))
        } else {
            None
        };
        // Common subpacketization = lcm of the two.
        let sp_lo = lo.as_ref().map(|a| a.sp).unwrap_or(1);
        let sp_hi = hi.as_ref().map(|a| a.sp).unwrap_or(1);
        let sp = lcm(sp_lo as u64, sp_hi as u64) as u32;
        let mut holders = Vec::new();
        for (alloc, sub_sp) in [(lo, sp_lo), (hi, sp_hi)].into_iter().flat_map(
            |(a, s)| a.map(|a| (a, s)),
        ) {
            let repeat = (sp / sub_sp) as usize;
            for &h in &alloc.holders {
                for _ in 0..repeat {
                    holders.push(h);
                }
            }
        }
        Allocation::new(self.k, sp, holders)
    }

    /// Coded shuffle plan for [`Self::allocation`]: per-subfile redundancy
    /// is either `r_lo` or `r_hi`, each handled by [2]'s multicast over
    /// its own sub-instance. On the round IR the two regimes' rounds are
    /// concatenated: the plan's round sequence is the `r_lo` schedule
    /// followed by the `r_hi` schedule, group structure preserved.
    pub fn plan(&self, alloc: &Allocation) -> ShufflePlan {
        // Split the allocation back into the two r-regular sub-ranges.
        let mut plan = ShufflePlan::new(self.k);
        let mut redundancies = vec![self.r_lo];
        if self.r_hi != self.r_lo {
            redundancies.push(self.r_hi);
        }
        for r in redundancies {
            if r == 0 {
                continue;
            }
            // Collect subfiles with this redundancy into a sub-allocation
            // (preserving global subfile ids via a mapping).
            let ids: Vec<usize> = alloc
                .holders
                .iter()
                .enumerate()
                .filter(|(_, h)| h.count_ones() as u64 == r)
                .map(|(i, _)| i)
                .collect();
            if ids.is_empty() {
                continue;
            }
            let sub_alloc = Allocation::new(
                self.k,
                alloc.sp,
                ids.iter().map(|&i| alloc.holders[i]).collect(),
            );
            let sub_plan = plan_homogeneous(&sub_alloc, r as usize);
            // Remap local subfile ids back to global ids, round by round.
            for mut round in sub_plan.rounds {
                for group in &mut round.groups {
                    for b in &mut group.broadcasts {
                        remap(b, &ids);
                    }
                }
                plan.push_round(round);
            }
        }
        plan
    }

    /// Envelope load in IV units: `(1−w)·L(r_lo) + w·L(r_hi)` with
    /// per-instance `L(r) = N_sub(K−r)/r`.
    pub fn envelope_load(&self) -> f64 {
        let part = |n: u64, r: u64| {
            if n == 0 || r == 0 {
                0.0
            } else {
                n as f64 * (self.k as u64 - r) as f64 / r as f64
            }
        };
        part(self.n_lo, self.r_lo) + part(self.n_hi, self.r_hi)
    }
}

fn lcm(a: u64, b: u64) -> u64 {
    a / gcd(a, b) * b
}

/// Rewrite a broadcast's local subfile ids to global ids in place.
fn remap(b: &mut crate::coding::plan::Broadcast, ids: &[usize]) {
    use crate::coding::plan::Broadcast;
    match b {
        Broadcast::Uncoded { iv, .. } => iv.sub = ids[iv.sub],
        Broadcast::Coded { parts, .. } => {
            for p in parts {
                p.iv.sub = ids[p.iv.sub];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::decoder::verify;
    use crate::prop;
    use crate::theory::load::lstar;
    use crate::theory::params::Params3;

    #[test]
    fn split_integer_r_has_single_regime() {
        let s = split(3, 8, 12).unwrap(); // r = 2 exactly
        assert_eq!((s.r_lo, s.r_hi), (2, 2));
        assert_eq!((s.n_lo, s.n_hi), (12, 0));
        assert_eq!(s.envelope_load(), 6.0);
    }

    #[test]
    fn split_fractional_r() {
        let s = split(3, 6, 12).unwrap(); // r = 1.5
        assert_eq!((s.r_lo, s.r_hi), (1, 2));
        // N_hi = KM − r_lo·N = 18 − 12 = 6; N_lo = 6.
        assert_eq!((s.n_lo, s.n_hi), (6, 6));
        // L = 6·2/1 + 6·1/2 = 15 — matches Theorem 1 for (6,6,6,12).
        assert_eq!(s.envelope_load(), 15.0);
        assert_eq!(lstar(&Params3::new(6, 6, 6, 12).unwrap()), 15.0);
    }

    #[test]
    fn split_rejects_invalid() {
        assert!(split(3, 1, 12).is_err()); // KM < N
        assert!(split(3, 13, 12).is_err()); // M > N
    }

    #[test]
    fn allocation_and_plan_achieve_envelope_and_decode() {
        let s = split(3, 6, 12).unwrap();
        let alloc = s.allocation();
        alloc.validate(&[6, 6, 6], 12).unwrap();
        let plan = s.plan(&alloc);
        let got = plan.load_equations(&alloc);
        assert!(
            (got - s.envelope_load()).abs() < 1e-9,
            "plan load {got} != envelope {}",
            s.envelope_load()
        );
        let report = verify(&alloc, &plan);
        assert!(report.is_complete(), "missing {:?}", report.missing);
    }

    #[test]
    fn prop_memshare_achieves_theorem1_homogeneous() {
        // Constructive proof of Remark 2's envelope: for every homogeneous
        // (M, N) the memory-sharing plan decodes and its load equals L*.
        prop::run("memshare == Theorem 1", 80, |g| {
            let n = g.u64_in(2..=16);
            let m = g.u64_in(1..=n);
            if 3 * m < n {
                return Ok(());
            }
            let s = split(3, m, n).map_err(|e| e.to_string())?;
            let alloc = s.allocation();
            if let Err(e) = alloc.validate(&[m, m, m], n) {
                return prop::fail(format!("m={m} n={n}: {e}"));
            }
            let plan = s.plan(&alloc);
            let got = plan.load_equations(&alloc);
            let want = lstar(&Params3::new(m, m, m, n).unwrap());
            if (got - want).abs() > 1e-9 {
                return prop::fail(format!("m={m} n={n}: load {got} != L* {want}"));
            }
            let report = verify(&alloc, &plan);
            prop::check(report.is_complete(), format!("m={m} n={n}: undecodable"))
        });
    }

    #[test]
    fn prop_memshare_general_k_matches_envelope() {
        prop::run("memshare envelope general K", 60, |g| {
            let k = g.usize_in(2..=5);
            let n = g.u64_in(2..=12);
            let m = g.u64_in(1..=n);
            if (k as u64) * m < n {
                return Ok(());
            }
            let s = split(k, m, n).map_err(|e| e.to_string())?;
            let alloc = s.allocation();
            let plan = s.plan(&alloc);
            let got = plan.load_equations(&alloc);
            if (got - s.envelope_load()).abs() > 1e-9 {
                return prop::fail(format!(
                    "k={k} m={m} n={n}: load {got} != envelope {}",
                    s.envelope_load()
                ));
            }
            let report = verify(&alloc, &plan);
            prop::check(report.is_complete(), format!("k={k} m={m} n={n}"))
        });
    }
}
