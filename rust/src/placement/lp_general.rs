//! §V: the general-K achievability algorithm as a linear program.
//!
//! Variables (paper's Steps 0–14):
//! * `S_T` for every non-empty `T ⊆ [K]` — subfiles stored at exactly the
//!   nodes of `T` (undetermined file allocation);
//! * for each middle subsystem `2 <= j <= K−2`: `x_{jq}` per *perfect
//!   collection* `q` in `C'_j` (K distinct j-subsets covering every node
//!   exactly j times), each saving `K(K−j)(1−1/j)` transmissions per file
//!   (Step 6, extending the homogeneous scheme of [2]);
//! * for `j = K−1`: `x_q` per node `q`, each an XOR equation over the
//!   K−1 pair-sets containing `q`, saving `K−2` (Steps 8–11 — for K=3 this
//!   is exactly Lemma 1's pairing LP, eq. (53)).
//!
//! Constraints: per-subset consumption (`Σ x <= S_T`), file-count and
//! per-node storage equalities (Step 12). Objective: total shuffle load.
//!
//! The enumeration of `C'_j` grows combinatorially (Remark 7); we cap it
//! and report how many collections were dropped — never silently.

use super::alloc::{Allocation, AllocationBuilder};
use super::homogeneous::subsets_of_size;
use crate::lp::{self, Cmp, Lp, Scalar};
use crate::theory::params::ParamsK;

/// Default cap on enumerated perfect collections per subsystem.
pub const DEFAULT_COLLECTION_CAP: usize = 4096;

/// DFS over lexicographic j-subset combinations: extend `chosen` with
/// masks from `masks[start..]`, recording every completed perfect
/// collection. `found` counts **all** completions; `out` keeps only the
/// first `cap` of them (in DFS order), so the caller computes the exact
/// dropped count as `found − out.len()`.
#[allow(clippy::too_many_arguments)]
fn extend_collections(
    masks: &[u32],
    start: usize,
    k: usize,
    j: usize,
    chosen: &mut Vec<u32>,
    degrees: &mut [u32],
    out: &mut Vec<Vec<u32>>,
    found: &mut usize,
    cap: usize,
) {
    if chosen.len() == k {
        if degrees.iter().all(|&d| d == j as u32) {
            *found += 1;
            if out.len() < cap {
                out.push(chosen.clone());
            }
        }
        return;
    }
    if masks.len() - start < k - chosen.len() {
        return;
    }
    for idx in start..masks.len() {
        let m = masks[idx];
        // Prune: adding m must not push any node past degree j.
        let mut ok = true;
        for node in 0..k {
            if m & (1 << node) != 0 && degrees[node] + 1 > j as u32 {
                ok = false;
                break;
            }
        }
        if !ok {
            continue;
        }
        for node in 0..k {
            if m & (1 << node) != 0 {
                degrees[node] += 1;
            }
        }
        chosen.push(m);
        extend_collections(masks, idx + 1, k, j, chosen, degrees, out, found, cap);
        chosen.pop();
        for node in 0..k {
            if m & (1 << node) != 0 {
                degrees[node] -= 1;
            }
        }
    }
}

/// Enumerate `C'_j`: K-element sets of distinct j-subsets of `[K]` where
/// every node appears in exactly j subsets. Returns (collections, dropped)
/// where each collection is a list of node masks.
pub fn perfect_collections(k: usize, j: usize, cap: usize) -> (Vec<Vec<u32>>, usize) {
    let masks = subsets_of_size(k, j);
    let mut out = Vec::new();
    let mut found = 0usize;
    let mut chosen: Vec<u32> = Vec::with_capacity(k);
    let mut degrees = vec![0u32; k];
    extend_collections(
        &masks,
        0,
        k,
        j,
        &mut chosen,
        &mut degrees,
        &mut out,
        &mut found,
        cap,
    );
    let dropped = found - out.len();
    (out, dropped)
}

/// [`perfect_collections`] with the DFS **sharded by first-subset
/// prefix** across up to `threads` scoped workers: shard `i` enumerates
/// every collection whose lexicographically-first member is `masks[i]`
/// (strided over workers), and shards merge back in prefix order. The
/// serial DFS order is exactly the concatenation of the shards in that
/// order, so the returned `(collections, dropped)` pair is **identical**
/// to the serial enumeration for any thread count — including the exact
/// Remark-7 dropped count (each shard counts all of its completions and
/// keeps at most `cap`, which is all the global cap can consume).
pub fn perfect_collections_threaded(
    k: usize,
    j: usize,
    cap: usize,
    threads: usize,
) -> (Vec<Vec<u32>>, usize) {
    let masks = subsets_of_size(k, j);
    let workers = threads.min(masks.len().max(1));
    if workers <= 1 {
        return perfect_collections(k, j, cap);
    }
    let masks = &masks[..];
    let mut shards: Vec<(usize, Vec<Vec<u32>>, usize)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                s.spawn(move || {
                    let mut results = Vec::new();
                    let mut idx0 = w;
                    while idx0 < masks.len() {
                        let mut out = Vec::new();
                        let mut found = 0usize;
                        let mut chosen = vec![masks[idx0]];
                        let mut degrees = vec![0u32; k];
                        for node in 0..k {
                            if masks[idx0] & (1 << node) != 0 {
                                degrees[node] = 1;
                            }
                        }
                        extend_collections(
                            masks,
                            idx0 + 1,
                            k,
                            j,
                            &mut chosen,
                            &mut degrees,
                            &mut out,
                            &mut found,
                            cap,
                        );
                        results.push((idx0, out, found));
                        idx0 += workers;
                    }
                    results
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().expect("perfect-collection enumeration worker"));
        }
        all
    });
    shards.sort_by_key(|&(idx0, _, _)| idx0);
    let mut out = Vec::new();
    let mut found = 0usize;
    for (_, shard_out, shard_found) in shards {
        found += shard_found;
        for coll in shard_out {
            if out.len() < cap {
                out.push(coll);
            }
        }
    }
    let dropped = found - out.len();
    (out, dropped)
}

/// Variable bookkeeping for the general LP.
#[derive(Clone, Debug)]
pub struct GeneralLpModel<S> {
    pub lp: Lp<S>,
    /// Map subset-mask -> S_T variable index.
    pub s_var: Vec<Option<usize>>,
    /// (j, collection masks, variable index) for every coding variable.
    pub x_vars: Vec<(usize, Vec<u32>, usize)>,
    /// Collections dropped by the enumeration cap, per subsystem j.
    pub dropped: Vec<(usize, usize)>,
}

/// Build the §V LP for `p` (Steps 0–13), generic over the scalar field.
pub fn build_lp<S: Scalar>(p: &ParamsK, cap: usize) -> GeneralLpModel<S> {
    build_lp_threaded(p, cap, 1)
}

/// [`build_lp`] with the per-subsystem work parallelized: the `C'_j`
/// enumerations of the middle subsystems run **concurrently** (one
/// scoped task per `j`, each prefix-sharding its own DFS over its share
/// of the thread budget). Model assembly then consumes the results in
/// ascending-`j` order, so variable indices, constraint order, and the
/// dropped-collection report are identical to the serial build.
pub fn build_lp_threaded<S: Scalar>(p: &ParamsK, cap: usize, threads: usize) -> GeneralLpModel<S> {
    let k = p.k();
    let mut lp: Lp<S> = Lp::new();
    let mut s_var: Vec<Option<usize>> = vec![None; 1 << k];

    // S_T variables; objective coefficient = (K − |T|) (uncoded deliveries
    // per subfile; j = K contributes 0).
    for mask in 1u32..(1 << k) {
        let j = mask.count_ones() as usize;
        let cost = S::from_i64((k - j) as i64);
        let v = lp.add_var(format!("S_{mask:b}"), cost);
        s_var[mask as usize] = Some(v);
    }

    let mut x_vars = Vec::new();
    let mut dropped = Vec::new();

    // Middle subsystems 2 <= j <= K−2 (Steps 1–6): enumerate every C'_j
    // up front — concurrently across subsystems when a thread budget is
    // given — then assemble in ascending j.
    let js: Vec<usize> = (2..k.saturating_sub(1)).collect();
    let enumerated: Vec<(usize, (Vec<Vec<u32>>, usize))> = if threads <= 1 {
        js.iter()
            .map(|&j| (j, perfect_collections(k, j, cap)))
            .collect()
    } else {
        // Concurrency stays within the caller's budget: at most `threads`
        // subsystem tasks run at once (strided over `outer` workers), and
        // each divides the remaining budget into its own prefix shards.
        // Results are sorted back to ascending j, so model assembly sees
        // the serial order no matter which worker ran which subsystem.
        let outer = threads.min(js.len().max(1));
        let inner = (threads / outer).max(1);
        let js_ref = &js[..];
        let mut all: Vec<(usize, (Vec<Vec<u32>>, usize))> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..outer)
                .map(|w| {
                    s.spawn(move || {
                        let mut results = Vec::new();
                        let mut idx = w;
                        while idx < js_ref.len() {
                            let j = js_ref[idx];
                            results.push((j, perfect_collections_threaded(k, j, cap, inner)));
                            idx += outer;
                        }
                        results
                    })
                })
                .collect();
            let mut all = Vec::new();
            for h in handles {
                all.extend(h.join().expect("subsystem enumeration worker"));
            }
            all
        });
        all.sort_by_key(|&(j, _)| j);
        all
    };
    for (j, (collections, drop)) in enumerated {
        if drop > 0 {
            dropped.push((j, drop));
        }
        // Saving per file: K (K−j)(j−1)/j.
        let save = S::from_ratio((k * (k - j) * (j - 1)) as i64, j as i64);
        let mut per_subset: Vec<Vec<usize>> = vec![Vec::new(); 1 << k];
        for coll in collections {
            let v = lp.add_var(format!("x_{j}_{}", x_vars.len()), save.neg());
            for &m in &coll {
                per_subset[m as usize].push(v);
            }
            x_vars.push((j, coll, v));
        }
        // Consumption constraints: Σ_q x_jq [T ∈ C_q] − S_T <= 0.
        for mask in subsets_of_size(k, j) {
            let vars = &per_subset[mask as usize];
            if vars.is_empty() {
                continue;
            }
            let mut coeffs: Vec<(usize, S)> =
                vars.iter().map(|&v| (v, S::one())).collect();
            coeffs.push((s_var[mask as usize].unwrap(), S::one().neg()));
            lp.constrain(coeffs, Cmp::Le, S::zero());
        }
    }

    // Subsystem j = K−1 (Steps 8–11): one variable per node; x_q appears
    // in the constraint of every (K−1)-subset containing q; saving K−2.
    if k >= 2 {
        let jm = k - 1;
        let save = S::from_i64((k - 2) as i64);
        let node_vars: Vec<usize> = (0..k)
            .map(|q| lp.add_var(format!("x_{jm}_n{q}"), save.neg()))
            .collect();
        for mask in subsets_of_size(k, jm) {
            let mut coeffs: Vec<(usize, S)> = (0..k)
                .filter(|&q| mask & (1 << q) != 0)
                .map(|q| (node_vars[q], S::one()))
                .collect();
            coeffs.push((s_var[mask as usize].unwrap(), S::one().neg()));
            lp.constrain(coeffs, Cmp::Le, S::zero());
        }
        for (q, &v) in node_vars.iter().enumerate() {
            x_vars.push((jm, vec![1u32 << q], v));
        }
    }

    // Step 12 equalities: total files and per-node storage.
    let all: Vec<(usize, S)> = (1..(1u32 << k))
        .map(|m| (s_var[m as usize].unwrap(), S::one()))
        .collect();
    lp.constrain(all, Cmp::Eq, S::from_i64(p.n as i64));
    for node in 0..k {
        let coeffs: Vec<(usize, S)> = (1..(1u32 << k))
            .filter(|m| m & (1 << node) != 0)
            .map(|m| (s_var[m as usize].unwrap(), S::one()))
            .collect();
        lp.constrain(coeffs, Cmp::Eq, S::from_i64(p.m[node] as i64));
    }

    GeneralLpModel {
        lp,
        s_var,
        x_vars,
        dropped,
    }
}

/// Solved general-K design.
#[derive(Clone, Debug)]
pub struct GeneralSolution {
    /// Predicted shuffle load (IV-equation units).
    pub load: f64,
    /// `S_T` values by mask (length `2^K`).
    pub s_values: Vec<f64>,
    /// Coding variable values: (j, collection masks, value).
    pub x_values: Vec<(usize, Vec<u32>, f64)>,
    pub pivots: usize,
    pub n_vars: usize,
    pub n_constraints: usize,
    /// Collections dropped by the enumeration cap (j, count).
    pub dropped: Vec<(usize, usize)>,
}

/// Run the §V algorithm (f64 simplex).
pub fn solve_general(p: &ParamsK, cap: usize) -> Result<GeneralSolution, lp::LpError> {
    solve_general_threaded(p, cap, 1)
}

/// [`solve_general`] with plan-build parallelism: concurrent per-`j`
/// perfect-collection enumeration ([`build_lp_threaded`]) and sharded
/// simplex pricing ([`lp::solve_with_threads`]). The solution is
/// bit-identical to the serial solve for every thread count.
pub fn solve_general_threaded(
    p: &ParamsK,
    cap: usize,
    threads: usize,
) -> Result<GeneralSolution, lp::LpError> {
    let model = build_lp_threaded::<f64>(p, cap, threads);
    let sol = lp::solve_with_threads(&model.lp, threads)?;
    let k = p.k();
    let mut s_values = vec![0.0; 1 << k];
    for mask in 1u32..(1 << k) {
        s_values[mask as usize] = sol.values[model.s_var[mask as usize].unwrap()];
    }
    let x_values = model
        .x_vars
        .iter()
        .map(|(j, coll, v)| (*j, coll.clone(), sol.values[*v]))
        .collect();
    Ok(GeneralSolution {
        load: sol.objective,
        s_values,
        x_values,
        pivots: sol.pivots,
        n_vars: model.lp.n_vars,
        n_constraints: model.lp.constraints.len(),
        dropped: model.dropped,
    })
}

/// Step 14: realize the LP's `S_T` values as a concrete allocation.
///
/// Values are scaled by `sp = 2` and rounded by largest remainder to hit
/// exactly `2N` subfiles, then per-node storage is repaired by local moves
/// (grow/shrink holder sets) so `validate()` passes. The engine's measured
/// load on the realized allocation may exceed the LP prediction by the
/// rounding slack; benches report both.
pub fn allocation_from_solution(p: &ParamsK, sol: &GeneralSolution) -> Allocation {
    let k = p.k();
    let sp = 2u32;
    let n_sub = (sp as u64 * p.n) as usize;

    // Largest-remainder rounding of 2·S_T to integers summing to 2N.
    let mut counts: Vec<u64> = Vec::with_capacity(1 << k);
    let mut rema: Vec<(usize, f64)> = Vec::new();
    let mut total = 0u64;
    for mask in 0..(1usize << k) {
        let scaled = if mask == 0 { 0.0 } else { sol.s_values[mask] * sp as f64 };
        let fl = scaled.max(0.0).floor() as u64;
        counts.push(fl);
        total += fl;
        rema.push((mask, scaled - fl as f64));
    }
    rema.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut deficit = (n_sub as u64).saturating_sub(total);
    for (mask, _) in rema {
        if deficit == 0 {
            break;
        }
        if mask != 0 {
            counts[mask] += 1;
            deficit -= 1;
        }
    }
    while deficit > 0 {
        counts[1] += 1; // pathological all-integer underflow: pad node 0
        deficit -= 1;
    }

    // Lay subfiles out mask by mask.
    let mut holders: Vec<u32> = Vec::with_capacity(n_sub);
    for (mask, &c) in counts.iter().enumerate() {
        for _ in 0..c {
            holders.push(mask as u32);
        }
    }
    holders.truncate(n_sub);
    while holders.len() < n_sub {
        holders.push(1);
    }

    // Repair per-node storage to exactly sp·M_k.
    let target: Vec<i64> = p.m.iter().map(|&m| (m * sp as u64) as i64).collect();
    let mut excess: Vec<i64> = (0..k)
        .map(|node| {
            holders
                .iter()
                .filter(|&&h| h & (1 << node) != 0)
                .count() as i64
                - target[node]
        })
        .collect();
    // Pass 1: shrink overfull nodes where coverage allows.
    for node in 0..k {
        let mut idx = 0;
        while excess[node] > 0 && idx < holders.len() {
            let h = holders[idx];
            if h & (1 << node) != 0 && h.count_ones() >= 2 {
                holders[idx] = h & !(1 << node);
                excess[node] -= 1;
            }
            idx += 1;
        }
    }
    // Pass 2: grow underfull nodes on subfiles they don't hold.
    for node in 0..k {
        let mut idx = 0;
        while excess[node] < 0 && idx < holders.len() {
            if holders[idx] & (1 << node) == 0 {
                holders[idx] |= 1 << node;
                excess[node] += 1;
            }
            idx += 1;
        }
    }
    // Pass 3: any node still overfull holds only singletons; swap them to
    // an underfull node (keeps coverage).
    for node in 0..k {
        while excess[node] > 0 {
            let under = (0..k).find(|&l| excess[l] < 0);
            let Some(under) = under else { break };
            if let Some(idx) = holders
                .iter()
                .position(|&h| h == 1 << node)
            {
                holders[idx] = 1 << under;
                excess[node] -= 1;
                excess[under] += 1;
            } else {
                break;
            }
        }
    }

    let mut b = AllocationBuilder::new(k, sp, n_sub);
    for (f, &h) in holders.iter().enumerate() {
        b.assign(f, f + 1, if h == 0 { 1 } else { h });
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::theory::load::{lstar, uncoded};
    use crate::theory::params::Params3;

    #[test]
    fn perfect_collections_k4_j2_matches_paper() {
        // §V-B Step 2: exactly three methods for K=4, j=2.
        let (colls, dropped) = perfect_collections(4, 2, 1000);
        assert_eq!(dropped, 0);
        assert_eq!(colls.len(), 3);
        for coll in &colls {
            assert_eq!(coll.len(), 4);
            let mut deg = [0u32; 4];
            for m in coll {
                for node in 0..4 {
                    if m & (1 << node) != 0 {
                        deg[node] += 1;
                    }
                }
            }
            assert_eq!(deg, [2, 2, 2, 2]);
        }
    }

    #[test]
    fn perfect_collections_k5_j2_are_cycle_covers() {
        // 2-regular simple graphs with 5 edges on 5 nodes = 5-cycles: 12.
        let (colls, _) = perfect_collections(5, 2, 10_000);
        assert_eq!(colls.len(), 12);
    }

    #[test]
    fn cap_reports_dropped() {
        let (colls, dropped) = perfect_collections(5, 2, 5);
        assert_eq!(colls.len(), 5);
        assert_eq!(dropped, 7);
    }

    #[test]
    fn threaded_enumeration_is_identical_to_serial() {
        // Prefix sharding must reproduce the serial DFS exactly — the
        // collections, their order, AND the exact dropped count, at every
        // thread count and cap (including caps that truncate mid-shard).
        for (k, j) in [(4usize, 2usize), (5, 2), (5, 3), (6, 2), (6, 3)] {
            for cap in [1usize, 5, 4096] {
                let serial = perfect_collections(k, j, cap);
                for threads in [2usize, 3, 8] {
                    let sharded = perfect_collections_threaded(k, j, cap, threads);
                    assert_eq!(
                        serial, sharded,
                        "K={k} j={j} cap={cap} threads={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn threaded_solve_is_bit_identical_to_serial() {
        // The full threaded build+solve path (concurrent per-j
        // enumeration, sharded pricing) against the serial reference.
        for storage in [vec![6u64, 7, 7], vec![3, 5, 6, 8], vec![3, 4, 5, 6, 7]] {
            let p = ParamsK::new(storage.clone(), 12).unwrap();
            let serial = solve_general(&p, DEFAULT_COLLECTION_CAP).unwrap();
            for threads in [2usize, 8] {
                let t = solve_general_threaded(&p, DEFAULT_COLLECTION_CAP, threads).unwrap();
                assert_eq!(
                    serial.load.to_bits(),
                    t.load.to_bits(),
                    "{storage:?} threads={threads}: load"
                );
                assert_eq!(serial.pivots, t.pivots, "{storage:?} threads={threads}: pivots");
                assert_eq!(
                    serial.s_values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    t.s_values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{storage:?} threads={threads}: S_T values"
                );
                assert_eq!(serial.dropped, t.dropped, "{storage:?} threads={threads}");
            }
        }
    }

    #[test]
    fn k3_lp_reproduces_paper_example() {
        // Remark 5: the K=3 LP equals Theorem 1 — here on (6,7,7,12).
        let p = ParamsK::new(vec![6, 7, 7], 12).unwrap();
        let sol = solve_general(&p, DEFAULT_COLLECTION_CAP).unwrap();
        assert!((sol.load - 12.0).abs() < 1e-6, "LP load {}", sol.load);
    }

    #[test]
    fn k3_lp_equals_theorem1_on_random_params() {
        prop::run("Remark 5: LP == Theorem 1", 60, |g| {
            let n = g.u64_in(1..=16);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(p3) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let pk = ParamsK::new(vec![m1, m2, m3], n).unwrap();
            let sol = solve_general(&pk, DEFAULT_COLLECTION_CAP)
                .map_err(|e| format!("{p3}: {e}"))?;
            prop::check(
                (sol.load - lstar(&p3)).abs() < 1e-6,
                format!("{p3}: LP {} vs L* {}", sol.load, lstar(&p3)),
            )
        });
    }

    #[test]
    fn k4_homogeneous_matches_li_et_al() {
        // K=4, r=2 homogeneous: L = N(K−r)/r = 10·2/2 = 10.
        let p = ParamsK::new(vec![5, 5, 5, 5], 10).unwrap();
        let sol = solve_general(&p, DEFAULT_COLLECTION_CAP).unwrap();
        assert!(
            (sol.load - 10.0).abs() < 1e-6,
            "K=4 r=2 LP load {} != 10",
            sol.load
        );
    }

    #[test]
    fn k4_heterogeneous_beats_uncoded() {
        let p = ParamsK::new(vec![3, 5, 6, 8], 12).unwrap();
        let sol = solve_general(&p, DEFAULT_COLLECTION_CAP).unwrap();
        let unc = (4.0 * 12.0) - 22.0; // KN − M deliveries
        assert!(sol.load < unc, "LP {} >= uncoded {unc}", sol.load);
        assert!(sol.load >= 0.0);
    }

    #[test]
    fn allocation_from_solution_is_valid() {
        let p = ParamsK::new(vec![6, 7, 7], 12).unwrap();
        let sol = solve_general(&p, DEFAULT_COLLECTION_CAP).unwrap();
        let alloc = allocation_from_solution(&p, &sol);
        alloc.validate(&[6, 7, 7], 12).unwrap();
    }

    #[test]
    fn prop_allocation_from_solution_valid_random() {
        prop::run("LP allocation valid", 30, |g| {
            let k = g.usize_in(3..=4);
            let n = g.u64_in(2..=10);
            let m: Vec<u64> = (0..k).map(|_| g.u64_in(1..=n)).collect();
            let Ok(p) = ParamsK::new(m.clone(), n) else {
                return Ok(());
            };
            let sol = solve_general(&p, DEFAULT_COLLECTION_CAP)
                .map_err(|e| format!("{m:?} n={n}: {e}"))?;
            let alloc = allocation_from_solution(&p, &sol);
            alloc
                .validate(&m, n)
                .map_err(|e| format!("{m:?} n={n}: {e}").into())
        });
    }

    #[test]
    fn exact_rational_lp_matches_theorem1_exactly() {
        // The §V LP solved in exact arithmetic: no f64 tolerance at all.
        use crate::lp::{solve, Rat};
        let cases = [(6u64, 7, 7, 12u64), (4, 5, 6, 12), (5, 11, 11, 12), (2, 3, 12, 12)];
        for (m1, m2, m3, n) in cases {
            let pk = ParamsK::new(vec![m1, m2, m3], n).unwrap();
            let p3 = Params3::new(m1, m2, m3, n).unwrap();
            let model = build_lp::<Rat>(&pk, DEFAULT_COLLECTION_CAP);
            let sol = solve(&model.lp).unwrap();
            // L* in exact halves: objective * 2 must equal lstar_half.
            let doubled = sol.objective.mul(&Rat::int(2));
            assert!(
                doubled.is_integer(),
                "({m1},{m2},{m3},{n}): objective {:?} not half-integral",
                sol.objective
            );
            assert_eq!(
                doubled,
                Rat::int(crate::theory::load::lstar_half(&p3) as i128),
                "({m1},{m2},{m3},{n})"
            );
        }
    }

    #[test]
    fn lp_load_lower_bounds_hold_k3() {
        // LP (achievable) must never beat the information-theoretic L*.
        prop::run("LP >= L* - eps", 40, |g| {
            let n = g.u64_in(1..=12);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(p3) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let pk = ParamsK::new(vec![m1, m2, m3], n).unwrap();
            let sol = solve_general(&pk, DEFAULT_COLLECTION_CAP)
                .map_err(|e| format!("{p3}: {e}"))?;
            let _ = uncoded(&p3);
            prop::check(
                sol.load >= lstar(&p3) - 1e-6,
                format!("{p3}: LP {} < L* {}", sol.load, lstar(&p3)),
            )
        });
    }
}
