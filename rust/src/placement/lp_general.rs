//! §V: the general-K achievability algorithm as a linear program.
//!
//! Variables (paper's Steps 0–14):
//! * `S_T` for every non-empty `T ⊆ [K]` — subfiles stored at exactly the
//!   nodes of `T` (undetermined file allocation);
//! * for each middle subsystem `2 <= j <= K−2`: `x_{jq}` per *perfect
//!   collection* `q` in `C'_j` (K distinct j-subsets covering every node
//!   exactly j times), each saving `K(K−j)(1−1/j)` transmissions per file
//!   (Step 6, extending the homogeneous scheme of [2]);
//! * for `j = K−1`: `x_q` per node `q`, each an XOR equation over the
//!   K−1 pair-sets containing `q`, saving `K−2` (Steps 8–11 — for K=3 this
//!   is exactly Lemma 1's pairing LP, eq. (53)).
//!
//! Constraints: per-subset consumption (`Σ x <= S_T`), file-count and
//! per-node storage equalities (Step 12). Objective: total shuffle load.
//!
//! The enumeration of `C'_j` grows combinatorially (Remark 7). The legacy
//! capped path ([`solve_general`]) truncates it and reports how many
//! collections were dropped — never silently. The **exact path**
//! ([`solve_general_exact`]) removes the cap's approximation error
//! entirely without enumerating `C'_j`:
//!
//! 1. solve the full LP's dual collapsed to `K + 2` variables
//!    ([`exact_load`]) — every collection of subsystem `j` prices to the
//!    same constant, so the exponentially many collection cuts fold into
//!    one row per `j` and the optimum equals the uncapped LP's load at
//!    any `K`, in microseconds;
//! 2. solve a *seeded* master over a small collection subset (exhaustive
//!    DFS at `K <= 6`, cyclic shift-orbits beyond — see
//!    [`cyclic_collections`]);
//! 3. the master is a restriction, so its objective upper-bounds and the
//!    collapsed dual lower-bounds the true load: when they meet within
//!    [`OBJ_CERT_EPS`] the placement is **certified exact**. Otherwise
//!    the caps of the dual-tight subsystems double and the master
//!    re-solves, at most [`MAX_EXACT_ROUNDS`] times.
//!
//! Enumeration results are memoized across shapes and plan builds in
//! [`super::collection_cache`] — `C'_j` depends only on `(K, j)`.

use super::alloc::{Allocation, AllocationBuilder};
use super::collection_cache::{self, CacheMode};
use super::homogeneous::subsets_of_size;
use crate::lp::{self, Cmp, Lp, Scalar};
use crate::theory::params::ParamsK;
use crate::util::json::Json;

/// Default cap on enumerated perfect collections per subsystem.
pub const DEFAULT_COLLECTION_CAP: usize = 4096;

/// Per-subsystem seed size for the exact path's first master at `K > 6`
/// (cyclic shift-orbit seeds; the full DFS is intractable there).
pub const SEED_CAP_LARGE_K: usize = 64;

/// Certification gap: the seeded master (an upper bound) is accepted as
/// exact when it comes within this of the collapsed dual (a lower bound).
pub const OBJ_CERT_EPS: f64 = 1e-6;

/// Hard ceiling on master-rebuild rounds in the exact path.
pub const MAX_EXACT_ROUNDS: usize = 32;

/// A subsystem's collection cut is considered binding at the dual optimum
/// when its slack is below this; only binding subsystems can carry primal
/// mass, so only they are grown when a certification gap remains.
const TIGHT_SLACK_EPS: f64 = 1e-7;

/// DFS over lexicographic j-subset combinations: extend `chosen` with
/// masks from `masks[start..]`, recording every completed perfect
/// collection. `found` counts **all** completions; `out` keeps only the
/// first `cap` of them (in DFS order), so the caller computes the exact
/// dropped count as `found − out.len()`. With `early_exit` the DFS
/// aborts once `found` exceeds `cap`: one completion past the cap proves
/// truncation, and cutting on `found` (not on `out` being full) keeps
/// the kept set *and* the flag identical to the exhaustive walk's first
/// `cap` completions at every thread count.
#[allow(clippy::too_many_arguments)]
fn extend_collections(
    masks: &[u32],
    start: usize,
    k: usize,
    j: usize,
    chosen: &mut Vec<u32>,
    degrees: &mut [u32],
    out: &mut Vec<Vec<u32>>,
    found: &mut usize,
    cap: usize,
    early_exit: bool,
) {
    if chosen.len() == k {
        if degrees.iter().all(|&d| d == j as u32) {
            *found += 1;
            if out.len() < cap {
                out.push(chosen.clone());
            }
        }
        return;
    }
    if masks.len() - start < k - chosen.len() {
        return;
    }
    for idx in start..masks.len() {
        if early_exit && *found > cap {
            return;
        }
        let m = masks[idx];
        // Prune: adding m must not push any node past degree j.
        let mut ok = true;
        for node in 0..k {
            if m & (1 << node) != 0 && degrees[node] + 1 > j as u32 {
                ok = false;
                break;
            }
        }
        if !ok {
            continue;
        }
        for node in 0..k {
            if m & (1 << node) != 0 {
                degrees[node] += 1;
            }
        }
        chosen.push(m);
        extend_collections(
            masks, idx + 1, k, j, chosen, degrees, out, found, cap, early_exit,
        );
        chosen.pop();
        for node in 0..k {
            if m & (1 << node) != 0 {
                degrees[node] -= 1;
            }
        }
    }
}

/// Enumerate `C'_j`: K-element sets of distinct j-subsets of `[K]` where
/// every node appears in exactly j subsets. Returns (collections, dropped)
/// where each collection is a list of node masks.
pub fn perfect_collections(k: usize, j: usize, cap: usize) -> (Vec<Vec<u32>>, usize) {
    let masks = subsets_of_size(k, j);
    let mut out = Vec::new();
    let mut found = 0usize;
    let mut chosen: Vec<u32> = Vec::with_capacity(k);
    let mut degrees = vec![0u32; k];
    extend_collections(
        &masks,
        0,
        k,
        j,
        &mut chosen,
        &mut degrees,
        &mut out,
        &mut found,
        cap,
        false,
    );
    let dropped = found - out.len();
    (out, dropped)
}

/// Early-exit variant of [`perfect_collections`] for seeding the exact
/// path: the DFS aborts one completion past `cap`, so the returned flag
/// is exactly "`C'_j` has more than `cap` members" while the work stays
/// proportional to `cap` instead of `|C'_j|`. The kept collections are
/// the same first-`cap` DFS prefix the exhaustive walk keeps. Unlike
/// [`perfect_collections`] it cannot say how *many* were dropped — the
/// exact path never needs that (certified solutions drop nothing;
/// uncertified ones report the flag).
pub fn perfect_collections_capped(k: usize, j: usize, cap: usize) -> (Vec<Vec<u32>>, bool) {
    let masks = subsets_of_size(k, j);
    let mut out = Vec::new();
    let mut found = 0usize;
    let mut chosen: Vec<u32> = Vec::with_capacity(k);
    let mut degrees = vec![0u32; k];
    extend_collections(
        &masks,
        0,
        k,
        j,
        &mut chosen,
        &mut degrees,
        &mut out,
        &mut found,
        cap,
        true,
    );
    (out, found > cap)
}

/// [`perfect_collections`] with the DFS **sharded by first-subset
/// prefix** across up to `threads` scoped workers: shard `i` enumerates
/// every collection whose lexicographically-first member is `masks[i]`
/// (strided over workers), and shards merge back in prefix order. The
/// serial DFS order is exactly the concatenation of the shards in that
/// order, so the returned `(collections, dropped)` pair is **identical**
/// to the serial enumeration for any thread count — including the exact
/// Remark-7 dropped count (each shard counts all of its completions and
/// keeps at most `cap`, which is all the global cap can consume).
pub fn perfect_collections_threaded(
    k: usize,
    j: usize,
    cap: usize,
    threads: usize,
) -> (Vec<Vec<u32>>, usize) {
    let masks = subsets_of_size(k, j);
    let workers = threads.min(masks.len().max(1));
    if workers <= 1 {
        return perfect_collections(k, j, cap);
    }
    let masks = &masks[..];
    let mut shards: Vec<(usize, Vec<Vec<u32>>, usize)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                s.spawn(move || {
                    let mut results = Vec::new();
                    let mut idx0 = w;
                    while idx0 < masks.len() {
                        let mut out = Vec::new();
                        let mut found = 0usize;
                        let mut chosen = vec![masks[idx0]];
                        let mut degrees = vec![0u32; k];
                        for node in 0..k {
                            if masks[idx0] & (1 << node) != 0 {
                                degrees[node] = 1;
                            }
                        }
                        extend_collections(
                            masks,
                            idx0 + 1,
                            k,
                            j,
                            &mut chosen,
                            &mut degrees,
                            &mut out,
                            &mut found,
                            cap,
                            false,
                        );
                        results.push((idx0, out, found));
                        idx0 += workers;
                    }
                    results
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().expect("perfect-collection enumeration worker"));
        }
        all
    });
    shards.sort_by_key(|&(idx0, _, _)| idx0);
    let mut out = Vec::new();
    let mut found = 0usize;
    for (_, shard_out, shard_found) in shards {
        found += shard_found;
        for coll in shard_out {
            if out.len() < cap {
                out.push(coll);
            }
        }
    }
    let dropped = found - out.len();
    (out, dropped)
}

/// Constructive large-K seeding: the K cyclic shifts of an **aperiodic**
/// j-subset of `Z_K` are K distinct j-subsets covering every node exactly
/// j times — a perfect collection, with no search. Enumerates canonical
/// orbit representatives (masks containing node 0, lexicographically
/// minimal among their K rotations) in ascending mask order, up to `cap`
/// orbits; the flag reports whether more exist. The lexicographic DFS
/// behind [`perfect_collections`] cannot even reach its *first*
/// completion at `K >= 12` for middle j in reasonable time, while the
/// cyclic family builds in one `O(2^(K−1))` mask scan and certifies
/// against the collapsed dual on every validated shape (see
/// `exact_certifies_*` tests and DESIGN.md).
pub fn cyclic_collections(k: usize, j: usize, cap: usize) -> (Vec<Vec<u32>>, bool) {
    let full: u32 = (1u32 << k) - 1;
    let mut out: Vec<Vec<u32>> = Vec::new();
    for m in 0u32..(1u32 << (k - 1)) {
        let mm = (m << 1) | 1; // always contains node 0
        if mm.count_ones() as usize != j {
            continue;
        }
        let rots: Vec<u32> = (0..k)
            .map(|r| ((mm >> r) | (mm << (k - r))) & full)
            .collect();
        if rots.iter().any(|&rot| rot < mm) {
            continue; // not the canonical rotation representative
        }
        let mut orbit = rots;
        orbit.sort_unstable();
        orbit.dedup();
        if orbit.len() != k {
            continue; // periodic subset: rotations collide
        }
        if out.len() == cap {
            return (out, true); // one more orbit proves truncation
        }
        out.push(orbit);
    }
    (out, false)
}

/// Seed cap for the exact path's first master: the caller's full `cap`
/// at `K <= 6` (the DFS is cheap and exhaustive there), bounded by
/// [`SEED_CAP_LARGE_K`] per subsystem beyond.
pub fn seed_cap_for(k: usize, cap: usize) -> usize {
    if k <= 6 {
        cap
    } else {
        cap.min(SEED_CAP_LARGE_K)
    }
}

/// Seed collections for one subsystem of the exact path's master:
/// exhaustive early-exit DFS at `K <= 6` (where an un-hit cap proves the
/// master *is* the full §V LP), cyclic shift-orbits beyond.
fn seed_collections(k: usize, j: usize, cap: usize) -> (Vec<Vec<u32>>, bool) {
    if k <= 6 {
        perfect_collections_capped(k, j, cap)
    } else {
        cyclic_collections(k, j, cap)
    }
}

/// Memoized full enumeration (legacy capped path). The cache key is
/// `(K, j, cap)` — enumeration is independent of storage and file count,
/// so every same-K plan build in the process shares one DFS.
fn cached_full(k: usize, j: usize, cap: usize, threads: usize) -> (Vec<Vec<u32>>, usize) {
    collection_cache::get_or_enumerate(k, j, cap, CacheMode::Full, || {
        if threads <= 1 {
            perfect_collections(k, j, cap)
        } else {
            perfect_collections_threaded(k, j, cap, threads)
        }
    })
}

/// Memoized seed enumeration (exact path); the payload's count slot
/// carries the truncation flag as 0/1.
fn cached_seed(k: usize, j: usize, cap: usize) -> (Vec<Vec<u32>>, bool) {
    let (colls, flag) = collection_cache::get_or_enumerate(k, j, cap, CacheMode::Seeded, || {
        let (colls, hit) = seed_collections(k, j, cap);
        (colls, usize::from(hit))
    });
    (colls, flag > 0)
}

/// Variable bookkeeping for the general LP.
#[derive(Clone, Debug)]
pub struct GeneralLpModel<S> {
    pub lp: Lp<S>,
    /// Map subset-mask -> S_T variable index.
    pub s_var: Vec<Option<usize>>,
    /// (j, collection masks, variable index) for every coding variable.
    pub x_vars: Vec<(usize, Vec<u32>, usize)>,
    /// Collections dropped by the enumeration cap, per subsystem j.
    /// Full builds report exact counts; seeded builds report a 0/1
    /// truncation flag per subsystem.
    pub dropped: Vec<(usize, usize)>,
}

/// Assemble the §V LP from pre-enumerated middle-subsystem collections
/// (ascending j, each with its dropped count/flag). Shared by the full
/// and seeded builds so the exact path's variable indices and constraint
/// order coincide with the exhaustive build's whenever the collection
/// lists do — which is what makes the `K <= 6` exact path bit-identical
/// to the uncapped solve.
fn assemble_lp<S: Scalar>(
    p: &ParamsK,
    enumerated: Vec<(usize, Vec<Vec<u32>>, usize)>,
) -> GeneralLpModel<S> {
    let k = p.k();
    let mut lp: Lp<S> = Lp::new();
    let mut s_var: Vec<Option<usize>> = vec![None; 1 << k];

    // S_T variables; objective coefficient = (K − |T|) (uncoded deliveries
    // per subfile; j = K contributes 0).
    for mask in 1u32..(1 << k) {
        let j = mask.count_ones() as usize;
        let cost = S::from_i64((k - j) as i64);
        let v = lp.add_var(format!("S_{mask:b}"), cost);
        s_var[mask as usize] = Some(v);
    }

    let mut x_vars = Vec::new();
    let mut dropped = Vec::new();

    // Middle subsystems 2 <= j <= K−2 (Steps 1–6).
    for (j, collections, drop) in enumerated {
        if drop > 0 {
            dropped.push((j, drop));
        }
        // Saving per file: K (K−j)(j−1)/j.
        let save = S::from_ratio((k * (k - j) * (j - 1)) as i64, j as i64);
        let mut per_subset: Vec<Vec<usize>> = vec![Vec::new(); 1 << k];
        for coll in collections {
            let v = lp.add_var(format!("x_{j}_{}", x_vars.len()), save.neg());
            for &m in &coll {
                per_subset[m as usize].push(v);
            }
            x_vars.push((j, coll, v));
        }
        // Consumption constraints: Σ_q x_jq [T ∈ C_q] − S_T <= 0.
        for mask in subsets_of_size(k, j) {
            let vars = &per_subset[mask as usize];
            if vars.is_empty() {
                continue;
            }
            let mut coeffs: Vec<(usize, S)> = vars.iter().map(|&v| (v, S::one())).collect();
            coeffs.push((s_var[mask as usize].unwrap(), S::one().neg()));
            lp.constrain(coeffs, Cmp::Le, S::zero());
        }
    }

    // Subsystem j = K−1 (Steps 8–11): one variable per node; x_q appears
    // in the constraint of every (K−1)-subset containing q; saving K−2.
    if k >= 2 {
        let jm = k - 1;
        let save = S::from_i64((k - 2) as i64);
        let node_vars: Vec<usize> = (0..k)
            .map(|q| lp.add_var(format!("x_{jm}_n{q}"), save.neg()))
            .collect();
        for mask in subsets_of_size(k, jm) {
            let mut coeffs: Vec<(usize, S)> = (0..k)
                .filter(|&q| mask & (1 << q) != 0)
                .map(|q| (node_vars[q], S::one()))
                .collect();
            coeffs.push((s_var[mask as usize].unwrap(), S::one().neg()));
            lp.constrain(coeffs, Cmp::Le, S::zero());
        }
        for (q, &v) in node_vars.iter().enumerate() {
            x_vars.push((jm, vec![1u32 << q], v));
        }
    }

    // Step 12 equalities: total files and per-node storage.
    let all: Vec<(usize, S)> = (1..(1u32 << k))
        .map(|m| (s_var[m as usize].unwrap(), S::one()))
        .collect();
    lp.constrain(all, Cmp::Eq, S::from_i64(p.n as i64));
    for node in 0..k {
        let coeffs: Vec<(usize, S)> = (1..(1u32 << k))
            .filter(|m| m & (1 << node) != 0)
            .map(|m| (s_var[m as usize].unwrap(), S::one()))
            .collect();
        lp.constrain(coeffs, Cmp::Eq, S::from_i64(p.m[node] as i64));
    }

    GeneralLpModel {
        lp,
        s_var,
        x_vars,
        dropped,
    }
}

/// Build the §V LP for `p` (Steps 0–13), generic over the scalar field.
pub fn build_lp<S: Scalar>(p: &ParamsK, cap: usize) -> GeneralLpModel<S> {
    build_lp_threaded(p, cap, 1)
}

/// [`build_lp`] with the per-subsystem work parallelized: the `C'_j`
/// enumerations of the middle subsystems run **concurrently** (one
/// scoped task per `j`, each prefix-sharding its own DFS over its share
/// of the thread budget) and land in the cross-shape collection cache.
/// Model assembly then consumes the results in ascending-`j` order, so
/// variable indices, constraint order, and the dropped-collection report
/// are identical to the serial build.
pub fn build_lp_threaded<S: Scalar>(p: &ParamsK, cap: usize, threads: usize) -> GeneralLpModel<S> {
    let k = p.k();
    let js: Vec<usize> = (2..k.saturating_sub(1)).collect();
    let enumerated: Vec<(usize, Vec<Vec<u32>>, usize)> = if threads <= 1 {
        js.iter()
            .map(|&j| {
                let (colls, drop) = cached_full(k, j, cap, 1);
                (j, colls, drop)
            })
            .collect()
    } else {
        // Concurrency stays within the caller's budget: at most `threads`
        // subsystem tasks run at once (strided over `outer` workers), and
        // each divides the remaining budget into its own prefix shards.
        // Results are sorted back to ascending j, so model assembly sees
        // the serial order no matter which worker ran which subsystem.
        let outer = threads.min(js.len().max(1));
        let inner = (threads / outer).max(1);
        let js_ref = &js[..];
        let mut all: Vec<(usize, Vec<Vec<u32>>, usize)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..outer)
                .map(|w| {
                    s.spawn(move || {
                        let mut results = Vec::new();
                        let mut idx = w;
                        while idx < js_ref.len() {
                            let j = js_ref[idx];
                            let (colls, drop) = cached_full(k, j, cap, inner);
                            results.push((j, colls, drop));
                            idx += outer;
                        }
                        results
                    })
                })
                .collect();
            let mut all = Vec::new();
            for h in handles {
                all.extend(h.join().expect("subsystem enumeration worker"));
            }
            all
        });
        all.sort_by_key(|&(j, _, _)| j);
        all
    };
    assemble_lp(p, enumerated)
}

/// Build a seeded master for the exact path: per-subsystem caps indexed
/// by `j` (entries outside `2..=K−2` are ignored), seeds from the
/// collection cache. Dropped entries are 0/1 truncation flags.
fn build_lp_seeded<S: Scalar>(p: &ParamsK, caps: &[usize]) -> GeneralLpModel<S> {
    let k = p.k();
    let enumerated: Vec<(usize, Vec<Vec<u32>>, usize)> = (2..k.saturating_sub(1))
        .map(|j| {
            let (colls, hit) = cached_seed(k, j, caps[j]);
            (j, colls, usize::from(hit))
        })
        .collect();
    assemble_lp(p, enumerated)
}

/// Deterministic work counters for the exact LP path. Every field is a
/// pure function of the problem instance — byte-identical across thread
/// counts and collection-cache state — so they may appear in plan
/// artifacts. (Raw DFS branch-node counts are deliberately absent: they
/// vary with sharding and cache warmth.)
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LpWorkStats {
    /// Simplex pivots across all master rounds (excludes the tiny dual).
    pub pivots: u64,
    /// Scalar slots touched applying eta vectors — the revised simplex's
    /// actual factorization work (see [`lp::Solution::eta_applications`]).
    pub eta_applications: u64,
    /// Counterfactual cells a dense-tableau per-pivot rewrite would have
    /// touched over the same pivot walk (`pivots × rows × cols`).
    pub dense_cells: u64,
    /// Eta-file refactorizations across all master rounds.
    pub reinversions: u64,
    /// Master build/solve rounds taken (1 = certified immediately).
    pub exact_rounds: u64,
    /// Collection columns in the final master — the enumeration actually
    /// paid for, vs. the `|C'_j|` an exhaustive build would enumerate.
    pub enumerated_collections: u64,
    /// Subsystem cap-doubling events across all growth rounds.
    pub grown_subsystems: u64,
    /// The collapsed dual's optimum — the full (uncapped) §V LP load.
    pub z_exact: f64,
    /// True when the final master's objective met `z_exact` within
    /// [`OBJ_CERT_EPS`], or (at `K <= 6` only) the seed enumeration
    /// provably covered all of `C'_j`.
    pub certified: bool,
}

impl LpWorkStats {
    /// The `lp_solver` object of plan and bench artifacts. Counters are
    /// exact in f64 (they stay far below 2^53); key order is fixed by
    /// the artifact's BTreeMap serialization.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("pivots".into(), Json::Num(self.pivots as f64));
        m.insert("eta_applications".into(), Json::Num(self.eta_applications as f64));
        m.insert("dense_cells".into(), Json::Num(self.dense_cells as f64));
        m.insert("reinversions".into(), Json::Num(self.reinversions as f64));
        m.insert("exact_rounds".into(), Json::Num(self.exact_rounds as f64));
        m.insert(
            "enumerated_collections".into(),
            Json::Num(self.enumerated_collections as f64),
        );
        m.insert("grown_subsystems".into(), Json::Num(self.grown_subsystems as f64));
        m.insert("z_exact".into(), Json::Num(self.z_exact));
        m.insert("certified".into(), Json::Bool(self.certified));
        Json::Obj(m)
    }
}

/// Solved general-K design.
#[derive(Clone, Debug)]
pub struct GeneralSolution {
    /// Predicted shuffle load (IV-equation units).
    pub load: f64,
    /// `S_T` values by mask (length `2^K`).
    pub s_values: Vec<f64>,
    /// Coding variable values: (j, collection masks, value).
    pub x_values: Vec<(usize, Vec<u32>, f64)>,
    pub pivots: usize,
    pub n_vars: usize,
    pub n_constraints: usize,
    /// Collections dropped by the enumeration cap (j, count). The exact
    /// path reports an empty list when certified and per-subsystem 0/1
    /// truncation flags when it exhausted its growth budget uncertified.
    pub dropped: Vec<(usize, usize)>,
    /// Work counters — present on the exact path, `None` on the legacy
    /// capped path.
    pub stats: Option<LpWorkStats>,
}

/// Read a [`GeneralSolution`] out of a solved model (no counters).
fn extract_solution(
    p: &ParamsK,
    model: &GeneralLpModel<f64>,
    sol: &lp::Solution<f64>,
) -> GeneralSolution {
    let k = p.k();
    let mut s_values = vec![0.0; 1 << k];
    for mask in 1usize..(1 << k) {
        if let Some(v) = model.s_var[mask] {
            s_values[mask] = sol.values[v];
        }
    }
    let x_values = model
        .x_vars
        .iter()
        .map(|(j, coll, v)| (*j, coll.clone(), sol.values[*v]))
        .collect();
    GeneralSolution {
        load: sol.objective,
        s_values,
        x_values,
        pivots: sol.pivots,
        n_vars: model.lp.n_vars,
        n_constraints: model.lp.constraints.len(),
        dropped: model.dropped.clone(),
        stats: None,
    }
}

/// Run the §V algorithm (f64 simplex) on the cap-truncated LP.
pub fn solve_general(p: &ParamsK, cap: usize) -> Result<GeneralSolution, lp::LpError> {
    solve_general_threaded(p, cap, 1)
}

/// [`solve_general`] with plan-build parallelism: concurrent per-`j`
/// perfect-collection enumeration ([`build_lp_threaded`]) and sharded
/// simplex pricing ([`lp::solve_with_threads`]). The solution is
/// bit-identical to the serial solve for every thread count.
pub fn solve_general_threaded(
    p: &ParamsK,
    cap: usize,
    threads: usize,
) -> Result<GeneralSolution, lp::LpError> {
    let model = build_lp_threaded::<f64>(p, cap, threads);
    let sol = lp::solve_with_threads(&model.lp, threads)?;
    Ok(extract_solution(p, &model, &sol))
}

/// Build the full §V LP's dual collapsed to `K + 2` decision variables
/// (`σ` for the file-count row, `π_i` per storage row), plus epigraph
/// helpers. With the consumption duals saturated, every perfect
/// collection of subsystem `j` prices to the same constant
/// `K(K−j) − Kσ − jΣπ` — collections are balanced — so the exponentially
/// many collection cuts collapse to one row per `j`:
///
/// ```text
/// max  Nσ + Σ_i M_i π_i
/// s.t. sum-of-s-largest(π) <= (K−s) − σ     for s = 1..K   [S_T >= 0]
///      Kσ + jΣπ <= K(K−j) − save_j          for middle j   [x_jq cuts]
///      (K−1)σ + (K−2)Σπ + π_q <= 1          for each q     [j=K−1 cuts]
/// ```
///
/// `sum-of-s-largest(π) <= c` is the epigraph `∃λ: sλ + Σ_i ρ_i <= c`,
/// `ρ_i >= π_i − λ`. Free variables are difference-of-nonnegative pairs.
/// Returns the minimization LP (objective negated), `σ`'s pair, and the
/// `π` pairs.
#[allow(clippy::type_complexity)]
fn exact_load_lp(p: &ParamsK) -> (Lp<f64>, (usize, usize), Vec<(usize, usize)>) {
    fn free(lp: &mut Lp<f64>, name: &str) -> (usize, usize) {
        (
            lp.add_var(format!("{name}+"), 0.0),
            lp.add_var(format!("{name}-"), 0.0),
        )
    }
    fn add_terms(coeffs: &mut Vec<(usize, f64)>, var: (usize, usize), c: f64) {
        coeffs.push((var.0, c));
        coeffs.push((var.1, -c));
    }

    let k = p.k();
    let mut lp: Lp<f64> = Lp::new();
    let sigma = free(&mut lp, "sigma");
    let pi: Vec<(usize, usize)> = (0..k).map(|i| free(&mut lp, &format!("pi{i}"))).collect();
    // Maximize Nσ + Σ M_i π_i == minimize the negation.
    lp.set_cost(sigma.0, -(p.n as f64));
    lp.set_cost(sigma.1, p.n as f64);
    for i in 0..k {
        lp.set_cost(pi[i].0, -(p.m[i] as f64));
        lp.set_cost(pi[i].1, p.m[i] as f64);
    }

    // S_T >= 0 for every size s: sum-of-s-largest(π) + σ <= K − s.
    for s in 1..=k {
        let lam = free(&mut lp, &format!("lam{s}"));
        let rho: Vec<usize> = (0..k)
            .map(|i| lp.add_var(format!("rho{s}_{i}"), 0.0))
            .collect();
        for i in 0..k {
            let mut coeffs = Vec::new();
            add_terms(&mut coeffs, pi[i], 1.0);
            add_terms(&mut coeffs, lam, -1.0);
            coeffs.push((rho[i], -1.0));
            lp.constrain(coeffs, Cmp::Le, 0.0);
        }
        let mut coeffs = Vec::new();
        add_terms(&mut coeffs, lam, s as f64);
        for &r in &rho {
            coeffs.push((r, 1.0));
        }
        add_terms(&mut coeffs, sigma, 1.0);
        lp.constrain(coeffs, Cmp::Le, (k - s) as f64);
    }

    // Middle-subsystem collection cuts (one per j).
    for j in 2..k.saturating_sub(1) {
        let save = (k * (k - j) * (j - 1)) as f64 / j as f64;
        let mut coeffs = Vec::new();
        add_terms(&mut coeffs, sigma, k as f64);
        for i in 0..k {
            add_terms(&mut coeffs, pi[i], j as f64);
        }
        lp.constrain(coeffs, Cmp::Le, (k * (k - j)) as f64 - save);
    }

    // j = K−1 node-variable cuts.
    if k >= 2 {
        for q in 0..k {
            let mut coeffs = Vec::new();
            add_terms(&mut coeffs, sigma, (k - 1) as f64);
            for i in 0..k {
                let c = (k - 2) as f64 + if i == q { 1.0 } else { 0.0 };
                add_terms(&mut coeffs, pi[i], c);
            }
            lp.constrain(coeffs, Cmp::Le, 1.0);
        }
    }
    (lp, sigma, pi)
}

/// Exact load of the **full** (uncapped) §V LP via the collapsed dual —
/// `O(K²)` variables regardless of `K`, solved serially in microseconds.
/// Returns `(load, σ*, π*)`; the multipliers drive the exact path's
/// growth heuristic (only subsystems whose cut binds at the dual optimum
/// can carry primal mass).
pub fn exact_load(p: &ParamsK) -> Result<(f64, f64, Vec<f64>), lp::LpError> {
    let (lp, sigma, pi) = exact_load_lp(p);
    let sol = lp::solve(&lp)?;
    let val = |fv: (usize, usize)| sol.values[fv.0] - sol.values[fv.1];
    Ok((
        -sol.objective,
        val(sigma),
        pi.iter().map(|&fv| val(fv)).collect(),
    ))
}

/// Exact §V placement without enumerating `C'_j`: seeded master +
/// collapsed-dual certificate + lazy growth of the binding subsystems
/// until the primal/dual gap closes (see the module docs). `cap` bounds
/// the *initial* per-subsystem seed via [`seed_cap_for`]; growth may
/// exceed it.
pub fn solve_general_exact(p: &ParamsK, cap: usize) -> Result<GeneralSolution, lp::LpError> {
    solve_general_exact_threaded(p, cap, 1)
}

/// [`solve_general_exact`] with sharded simplex pricing. All counters
/// and solution bytes are thread-invariant: the tiny dual solves
/// serially, seeding is deterministic, and the sharded pricing walks the
/// same pivot sequence as the serial scan.
pub fn solve_general_exact_threaded(
    p: &ParamsK,
    cap: usize,
    threads: usize,
) -> Result<GeneralSolution, lp::LpError> {
    exact_inner(p, seed_cap_for(p.k(), cap), threads)
}

fn exact_inner(p: &ParamsK, seed: usize, threads: usize) -> Result<GeneralSolution, lp::LpError> {
    let k = p.k();
    let (z_exact, sigma, pi) = exact_load(p)?;
    let p_sum: f64 = pi.iter().sum();

    // Subsystems whose collection cut binds at the dual optimum are the
    // only ones worth growing when a certification gap remains.
    let mut tight = vec![false; k.max(1)];
    let mut caps = vec![0usize; k.max(1)];
    for j in 2..k.saturating_sub(1) {
        let save = (k * (k - j) * (j - 1)) as f64 / j as f64;
        let slack = (k * (k - j)) as f64 - k as f64 * sigma - j as f64 * p_sum - save;
        tight[j] = slack < TIGHT_SLACK_EPS;
        caps[j] = seed.max(1);
    }

    let mut stats = LpWorkStats {
        pivots: 0,
        eta_applications: 0,
        dense_cells: 0,
        reinversions: 0,
        exact_rounds: 0,
        enumerated_collections: 0,
        grown_subsystems: 0,
        z_exact,
        certified: false,
    };
    loop {
        stats.exact_rounds += 1;
        let model = build_lp_seeded::<f64>(p, &caps);
        let sol = lp::solve_with_threads(&model.lp, threads)?;
        stats.pivots += sol.pivots as u64;
        stats.eta_applications += sol.eta_applications;
        stats.dense_cells += sol.dense_cells;
        stats.reinversions += sol.reinversions as u64;
        let truncated = !model.dropped.is_empty();
        // The objective-gap arm is the workhorse. The exhaustion arm is
        // only sound at K <= 6, where the seed enumerator is the full
        // DFS over C'_j: beyond that the cyclic family is a strict
        // subset, so an un-truncated master may still omit columns.
        let certified = sol.objective <= z_exact + OBJ_CERT_EPS || (k <= 6 && !truncated);
        let mut grew = false;
        if !certified && (stats.exact_rounds as usize) < MAX_EXACT_ROUNDS {
            let any_tight_truncated = model.dropped.iter().any(|&(j, _)| tight[j]);
            for &(j, _) in &model.dropped {
                if tight[j] || !any_tight_truncated {
                    caps[j] = caps[j].saturating_mul(2);
                    stats.grown_subsystems += 1;
                    grew = true;
                }
            }
        }
        if certified || !grew {
            stats.enumerated_collections = model.x_vars.len() as u64;
            stats.certified = certified;
            let mut out = extract_solution(p, &model, &sol);
            out.pivots = stats.pivots as usize;
            // Certified means the cap cost nothing: nothing the full LP
            // needed was dropped. Uncertified exits keep the flags.
            if certified {
                out.dropped.clear();
            }
            out.stats = Some(stats);
            return Ok(out);
        }
    }
}

/// Step 14: realize the LP's `S_T` values as a concrete allocation.
///
/// Values are scaled by `sp = 2` and rounded by largest remainder to hit
/// exactly `2N` subfiles, then per-node storage is repaired by local moves
/// (grow/shrink holder sets) so `validate()` passes. The engine's measured
/// load on the realized allocation may exceed the LP prediction by the
/// rounding slack; benches report both.
pub fn allocation_from_solution(p: &ParamsK, sol: &GeneralSolution) -> Allocation {
    let k = p.k();
    let sp = 2u32;
    let n_sub = (sp as u64 * p.n) as usize;

    // Largest-remainder rounding of 2·S_T to integers summing to 2N.
    let mut counts: Vec<u64> = Vec::with_capacity(1 << k);
    let mut rema: Vec<(usize, f64)> = Vec::new();
    let mut total = 0u64;
    for mask in 0..(1usize << k) {
        let scaled = if mask == 0 { 0.0 } else { sol.s_values[mask] * sp as f64 };
        let fl = scaled.max(0.0).floor() as u64;
        counts.push(fl);
        total += fl;
        rema.push((mask, scaled - fl as f64));
    }
    rema.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut deficit = (n_sub as u64).saturating_sub(total);
    for (mask, _) in rema {
        if deficit == 0 {
            break;
        }
        if mask != 0 {
            counts[mask] += 1;
            deficit -= 1;
        }
    }
    while deficit > 0 {
        counts[1] += 1; // pathological all-integer underflow: pad node 0
        deficit -= 1;
    }

    // Lay subfiles out mask by mask.
    let mut holders: Vec<u32> = Vec::with_capacity(n_sub);
    for (mask, &c) in counts.iter().enumerate() {
        for _ in 0..c {
            holders.push(mask as u32);
        }
    }
    holders.truncate(n_sub);
    while holders.len() < n_sub {
        holders.push(1);
    }

    // Repair per-node storage to exactly sp·M_k.
    let target: Vec<i64> = p.m.iter().map(|&m| (m * sp as u64) as i64).collect();
    let mut excess: Vec<i64> = (0..k)
        .map(|node| {
            holders
                .iter()
                .filter(|&&h| h & (1 << node) != 0)
                .count() as i64
                - target[node]
        })
        .collect();
    // Pass 1: shrink overfull nodes where coverage allows.
    for node in 0..k {
        let mut idx = 0;
        while excess[node] > 0 && idx < holders.len() {
            let h = holders[idx];
            if h & (1 << node) != 0 && h.count_ones() >= 2 {
                holders[idx] = h & !(1 << node);
                excess[node] -= 1;
            }
            idx += 1;
        }
    }
    // Pass 2: grow underfull nodes on subfiles they don't hold.
    for node in 0..k {
        let mut idx = 0;
        while excess[node] < 0 && idx < holders.len() {
            if holders[idx] & (1 << node) == 0 {
                holders[idx] |= 1 << node;
                excess[node] += 1;
            }
            idx += 1;
        }
    }
    // Pass 3: any node still overfull holds only singletons; swap them to
    // an underfull node (keeps coverage).
    for node in 0..k {
        while excess[node] > 0 {
            let under = (0..k).find(|&l| excess[l] < 0);
            let Some(under) = under else { break };
            if let Some(idx) = holders
                .iter()
                .position(|&h| h == 1 << node)
            {
                holders[idx] = 1 << under;
                excess[node] -= 1;
                excess[under] += 1;
            } else {
                break;
            }
        }
    }

    let mut b = AllocationBuilder::new(k, sp, n_sub);
    for (f, &h) in holders.iter().enumerate() {
        b.assign(f, f + 1, if h == 0 { 1 } else { h });
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::theory::load::{lstar, uncoded};
    use crate::theory::params::Params3;

    #[test]
    fn perfect_collections_k4_j2_matches_paper() {
        // §V-B Step 2: exactly three methods for K=4, j=2.
        let (colls, dropped) = perfect_collections(4, 2, 1000);
        assert_eq!(dropped, 0);
        assert_eq!(colls.len(), 3);
        for coll in &colls {
            assert_eq!(coll.len(), 4);
            let mut deg = [0u32; 4];
            for m in coll {
                for node in 0..4 {
                    if m & (1 << node) != 0 {
                        deg[node] += 1;
                    }
                }
            }
            assert_eq!(deg, [2, 2, 2, 2]);
        }
    }

    #[test]
    fn perfect_collections_k5_j2_are_cycle_covers() {
        // 2-regular simple graphs with 5 edges on 5 nodes = 5-cycles: 12.
        let (colls, _) = perfect_collections(5, 2, 10_000);
        assert_eq!(colls.len(), 12);
    }

    #[test]
    fn cap_reports_dropped() {
        let (colls, dropped) = perfect_collections(5, 2, 5);
        assert_eq!(colls.len(), 5);
        assert_eq!(dropped, 7);
    }

    #[test]
    fn capped_enumeration_flags_truncation_exactly() {
        // The early-exit DFS must keep the same first-`cap` prefix as the
        // exhaustive walk and flag truncation iff |C'_j| > cap — at the
        // boundary too (cap == |C'_j| must NOT flag).
        for (k, j, n_colls) in [(4usize, 2usize, 3usize), (5, 2, 12), (6, 2, 70)] {
            for cap in [1usize, 2, n_colls - 1, n_colls, n_colls + 1, 4096] {
                let (full, _) = perfect_collections(k, j, usize::MAX);
                let (kept, hit) = perfect_collections_capped(k, j, cap);
                assert_eq!(kept.len(), cap.min(n_colls), "K={k} j={j} cap={cap}");
                assert_eq!(kept[..], full[..kept.len()], "K={k} j={j} cap={cap}: prefix");
                assert_eq!(hit, n_colls > cap, "K={k} j={j} cap={cap}: flag");
            }
        }
    }

    #[test]
    fn threaded_enumeration_is_identical_to_serial() {
        // Prefix sharding must reproduce the serial DFS exactly — the
        // collections, their order, AND the exact dropped count, at every
        // thread count and cap (including caps that truncate mid-shard).
        for (k, j) in [(4usize, 2usize), (5, 2), (5, 3), (6, 2), (6, 3)] {
            for cap in [1usize, 5, 4096] {
                let serial = perfect_collections(k, j, cap);
                for threads in [2usize, 3, 8] {
                    let sharded = perfect_collections_threaded(k, j, cap, threads);
                    assert_eq!(
                        serial, sharded,
                        "K={k} j={j} cap={cap} threads={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn cyclic_collections_are_perfect_and_canonical() {
        for (k, j) in [(8usize, 2usize), (8, 3), (8, 5), (12, 5), (16, 7)] {
            let (colls, truncated) = cyclic_collections(k, j, 64);
            assert!(!colls.is_empty(), "K={k} j={j}: no cyclic orbits");
            for coll in &colls {
                assert_eq!(coll.len(), k, "K={k} j={j}: orbit size");
                let mut deg = vec![0u32; k];
                let mut sorted = coll.clone();
                sorted.dedup();
                assert_eq!(sorted.len(), k, "K={k} j={j}: duplicate masks");
                for &m in coll {
                    assert_eq!(m.count_ones() as usize, j, "K={k} j={j}: subset size");
                    for node in 0..k {
                        if m & (1 << node) != 0 {
                            deg[node] += 1;
                        }
                    }
                }
                assert!(
                    deg.iter().all(|&d| d == j as u32),
                    "K={k} j={j}: not perfect: {deg:?}"
                );
            }
            // Truncation flag: asking for one fewer must flag.
            if !truncated && colls.len() > 1 {
                let (fewer, hit) = cyclic_collections(k, j, colls.len() - 1);
                assert_eq!(fewer.len(), colls.len() - 1);
                assert!(hit, "K={k} j={j}: truncation unflagged");
                assert_eq!(fewer[..], colls[..fewer.len()], "K={k} j={j}: prefix");
            }
        }
    }

    #[test]
    fn threaded_solve_is_bit_identical_to_serial() {
        // The full threaded build+solve path (concurrent per-j
        // enumeration through the collection cache, sharded pricing)
        // against the serial reference.
        for storage in [vec![6u64, 7, 7], vec![3, 5, 6, 8], vec![3, 4, 5, 6, 7]] {
            let p = ParamsK::new(storage.clone(), 12).unwrap();
            let serial = solve_general(&p, DEFAULT_COLLECTION_CAP).unwrap();
            for threads in [2usize, 8] {
                let t = solve_general_threaded(&p, DEFAULT_COLLECTION_CAP, threads).unwrap();
                assert_eq!(
                    serial.load.to_bits(),
                    t.load.to_bits(),
                    "{storage:?} threads={threads}: load"
                );
                assert_eq!(serial.pivots, t.pivots, "{storage:?} threads={threads}: pivots");
                assert_eq!(
                    serial.s_values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    t.s_values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{storage:?} threads={threads}: S_T values"
                );
                assert_eq!(serial.dropped, t.dropped, "{storage:?} threads={threads}");
            }
        }
    }

    #[test]
    fn repeated_builds_hit_the_collection_cache() {
        let p = ParamsK::new(vec![3, 4, 5, 6, 7], 10).unwrap();
        let first = build_lp::<f64>(&p, DEFAULT_COLLECTION_CAP);
        let (hits_before, _) = collection_cache::stats();
        let second = build_lp::<f64>(&p, DEFAULT_COLLECTION_CAP);
        let (hits_after, _) = collection_cache::stats();
        assert_eq!(first.x_vars, second.x_vars);
        assert_eq!(first.dropped, second.dropped);
        // K=5 has middle subsystems j ∈ {2, 3}: both must hit now.
        // (Counters are global and monotone; concurrent tests only add.)
        assert!(
            hits_after >= hits_before + 2,
            "cache hits {hits_before} -> {hits_after}"
        );
    }

    #[test]
    fn k3_lp_reproduces_paper_example() {
        // Remark 5: the K=3 LP equals Theorem 1 — here on (6,7,7,12).
        let p = ParamsK::new(vec![6, 7, 7], 12).unwrap();
        let sol = solve_general(&p, DEFAULT_COLLECTION_CAP).unwrap();
        assert!((sol.load - 12.0).abs() < 1e-6, "LP load {}", sol.load);
    }

    #[test]
    fn k3_lp_equals_theorem1_on_random_params() {
        prop::run("Remark 5: LP == Theorem 1", 60, |g| {
            let n = g.u64_in(1..=16);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(p3) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let pk = ParamsK::new(vec![m1, m2, m3], n).unwrap();
            let sol = solve_general(&pk, DEFAULT_COLLECTION_CAP)
                .map_err(|e| format!("{p3}: {e}"))?;
            prop::check(
                (sol.load - lstar(&p3)).abs() < 1e-6,
                format!("{p3}: LP {} vs L* {}", sol.load, lstar(&p3)),
            )
        });
    }

    #[test]
    fn k4_homogeneous_matches_li_et_al() {
        // K=4, r=2 homogeneous: L = N(K−r)/r = 10·2/2 = 10.
        let p = ParamsK::new(vec![5, 5, 5, 5], 10).unwrap();
        let sol = solve_general(&p, DEFAULT_COLLECTION_CAP).unwrap();
        assert!(
            (sol.load - 10.0).abs() < 1e-6,
            "K=4 r=2 LP load {} != 10",
            sol.load
        );
    }

    #[test]
    fn k4_heterogeneous_beats_uncoded() {
        let p = ParamsK::new(vec![3, 5, 6, 8], 12).unwrap();
        let sol = solve_general(&p, DEFAULT_COLLECTION_CAP).unwrap();
        let unc = (4.0 * 12.0) - 22.0; // KN − M deliveries
        assert!(sol.load < unc, "LP {} >= uncoded {unc}", sol.load);
        assert!(sol.load >= 0.0);
    }

    #[test]
    fn exact_load_matches_lp_optimum() {
        // The collapsed dual must equal the uncapped primal LP's load.
        prop::run("tiny dual == full LP", 20, |g| {
            let k = g.usize_in(3..=5);
            let n = g.u64_in(2..=10);
            let m: Vec<u64> = (0..k).map(|_| g.u64_in(1..=n)).collect();
            let Ok(p) = ParamsK::new(m.clone(), n) else {
                return Ok(());
            };
            let sol = solve_general(&p, DEFAULT_COLLECTION_CAP)
                .map_err(|e| format!("{m:?} n={n}: {e}"))?;
            let (z, _, _) = exact_load(&p).map_err(|e| format!("{m:?} n={n}: dual {e}"))?;
            prop::check(
                (sol.load - z).abs() < 1e-6,
                format!("{m:?} n={n}: primal {} vs dual {z}", sol.load),
            )
        });
    }

    #[test]
    fn exact_path_reproduces_exhaustive_bit_for_bit() {
        // At K <= 6 the exact path's first master IS the full §V LP
        // (full-cap DFS seed), so load, S_T values, and the pivot walk
        // must match the uncapped legacy solve exactly — and certify in
        // one round having dropped nothing.
        let shapes: [(&[u64], u64); 4] = [
            (&[6, 7, 7], 12),
            (&[3, 5, 6, 8], 12),
            (&[3, 4, 5, 6, 7], 10),
            (&[4, 4, 6, 6, 8, 8], 12),
        ];
        for (storage, n) in shapes {
            let p = ParamsK::new(storage.to_vec(), n).unwrap();
            let exhaustive = solve_general(&p, DEFAULT_COLLECTION_CAP).unwrap();
            let exact = solve_general_exact(&p, DEFAULT_COLLECTION_CAP).unwrap();
            assert_eq!(
                exhaustive.load.to_bits(),
                exact.load.to_bits(),
                "{storage:?}: load"
            );
            assert_eq!(
                exhaustive.s_values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                exact.s_values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{storage:?}: S_T values"
            );
            assert_eq!(exhaustive.pivots, exact.pivots, "{storage:?}: pivots");
            let stats = exact.stats.expect("exact path carries stats");
            assert!(stats.certified, "{storage:?}: uncertified");
            assert_eq!(stats.exact_rounds, 1, "{storage:?}: extra rounds");
            assert!(exact.dropped.is_empty(), "{storage:?}: dropped {:?}", exact.dropped);
            assert!(
                stats.eta_applications < stats.dense_cells,
                "{storage:?}: factorized work {} not below dense counterfactual {}",
                stats.eta_applications,
                stats.dense_cells
            );
        }
    }

    #[test]
    fn prop_exact_path_matches_exhaustive_random() {
        prop::run("exact == exhaustive (K<=5)", 30, |g| {
            let k = g.usize_in(3..=5);
            let n = g.u64_in(2..=10);
            let m: Vec<u64> = (0..k).map(|_| g.u64_in(1..=n)).collect();
            let Ok(p) = ParamsK::new(m.clone(), n) else {
                return Ok(());
            };
            let exhaustive = solve_general(&p, DEFAULT_COLLECTION_CAP)
                .map_err(|e| format!("{m:?} n={n}: {e}"))?;
            let exact = solve_general_exact(&p, DEFAULT_COLLECTION_CAP)
                .map_err(|e| format!("{m:?} n={n}: exact {e}"))?;
            let certified = exact.stats.map(|s| s.certified).unwrap_or(false);
            prop::check(
                exhaustive.load.to_bits() == exact.load.to_bits() && certified,
                format!(
                    "{m:?} n={n}: exhaustive {} vs exact {} certified={certified}",
                    exhaustive.load, exact.load
                ),
            )
        });
    }

    #[test]
    fn tiny_seed_growth_converges() {
        // Starting from a deliberately starved seed (2 collections per
        // subsystem at K=5, where |C'_2| = 12), cap doubling must close
        // the gap and certify against the collapsed dual.
        let p = ParamsK::new(vec![3, 4, 5, 6, 7], 10).unwrap();
        let reference = solve_general(&p, DEFAULT_COLLECTION_CAP).unwrap();
        let exact = solve_general_exact(&p, 2).unwrap();
        let stats = exact.stats.expect("exact path carries stats");
        assert!(stats.certified, "starved seed never certified");
        assert!(exact.dropped.is_empty());
        assert!(
            (exact.load - reference.load).abs() < 1e-7,
            "grown load {} vs reference {}",
            exact.load,
            reference.load
        );
        assert!(
            stats.exact_rounds > 1 && stats.grown_subsystems > 0,
            "seed 2 certified without growing (rounds {}, grown {})",
            stats.exact_rounds,
            stats.grown_subsystems
        );
    }

    #[test]
    fn exact_certifies_k8_with_cyclic_seeds() {
        // K=8 is beyond the DFS regime: the master seeds from cyclic
        // shift-orbits and must still meet the collapsed dual. This is
        // the heterogeneous bench shape.
        let p = ParamsK::new(vec![4, 4, 5, 5, 6, 6, 7, 7], 8).unwrap();
        let sol = solve_general_exact(&p, DEFAULT_COLLECTION_CAP).unwrap();
        let stats = sol.stats.expect("exact path carries stats");
        assert!(stats.certified, "K=8 cyclic seeds failed to certify");
        assert!(sol.dropped.is_empty());
        // Master is a restriction: load ∈ [z_exact − eps, z_exact + eps].
        assert!(
            (sol.load - stats.z_exact).abs() <= OBJ_CERT_EPS,
            "load {} vs z_exact {}",
            sol.load,
            stats.z_exact
        );
        assert!(stats.enumerated_collections > 0);
        assert!(
            stats.eta_applications < stats.dense_cells,
            "factorized work {} not below dense counterfactual {}",
            stats.eta_applications,
            stats.dense_cells
        );
    }

    #[test]
    fn exact_solve_is_bit_identical_across_threads() {
        // Exact-path artifacts (values AND counters) may not move with
        // the thread count: the tiny dual is serial, seeding is pure,
        // and sharded pricing replays the serial pivot walk.
        for storage in [vec![3u64, 4, 5, 6, 7], vec![4, 4, 5, 5, 6, 6, 7, 7]] {
            let n = if storage.len() == 5 { 10 } else { 8 };
            let p = ParamsK::new(storage.clone(), n).unwrap();
            let serial = solve_general_exact(&p, DEFAULT_COLLECTION_CAP).unwrap();
            for threads in [2usize, 8] {
                let t = solve_general_exact_threaded(&p, DEFAULT_COLLECTION_CAP, threads)
                    .unwrap();
                assert_eq!(
                    serial.load.to_bits(),
                    t.load.to_bits(),
                    "{storage:?} threads={threads}: load"
                );
                assert_eq!(
                    serial.s_values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    t.s_values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{storage:?} threads={threads}: S_T values"
                );
                assert_eq!(serial.stats, t.stats, "{storage:?} threads={threads}: stats");
            }
        }
    }

    #[test]
    fn allocation_from_solution_is_valid() {
        let p = ParamsK::new(vec![6, 7, 7], 12).unwrap();
        let sol = solve_general(&p, DEFAULT_COLLECTION_CAP).unwrap();
        let alloc = allocation_from_solution(&p, &sol);
        alloc.validate(&[6, 7, 7], 12).unwrap();
    }

    #[test]
    fn prop_allocation_from_solution_valid_random() {
        prop::run("LP allocation valid", 30, |g| {
            let k = g.usize_in(3..=4);
            let n = g.u64_in(2..=10);
            let m: Vec<u64> = (0..k).map(|_| g.u64_in(1..=n)).collect();
            let Ok(p) = ParamsK::new(m.clone(), n) else {
                return Ok(());
            };
            let sol = solve_general(&p, DEFAULT_COLLECTION_CAP)
                .map_err(|e| format!("{m:?} n={n}: {e}"))?;
            let alloc = allocation_from_solution(&p, &sol);
            alloc
                .validate(&m, n)
                .map_err(|e| format!("{m:?} n={n}: {e}").into())
        });
    }

    #[test]
    fn exact_rational_lp_matches_theorem1_exactly() {
        // The §V LP solved in exact arithmetic: no f64 tolerance at all.
        use crate::lp::{solve, Rat};
        let cases = [(6u64, 7, 7, 12u64), (4, 5, 6, 12), (5, 11, 11, 12), (2, 3, 12, 12)];
        for (m1, m2, m3, n) in cases {
            let pk = ParamsK::new(vec![m1, m2, m3], n).unwrap();
            let p3 = Params3::new(m1, m2, m3, n).unwrap();
            let model = build_lp::<Rat>(&pk, DEFAULT_COLLECTION_CAP);
            let sol = solve(&model.lp).unwrap();
            // L* in exact halves: objective * 2 must equal lstar_half.
            let doubled = sol.objective.mul(&Rat::int(2));
            assert!(
                doubled.is_integer(),
                "({m1},{m2},{m3},{n}): objective {:?} not half-integral",
                sol.objective
            );
            assert_eq!(
                doubled,
                Rat::int(crate::theory::load::lstar_half(&p3) as i128),
                "({m1},{m2},{m3},{n})"
            );
        }
    }

    #[test]
    fn lp_load_lower_bounds_hold_k3() {
        // LP (achievable) must never beat the information-theoretic L*.
        prop::run("LP >= L* - eps", 40, |g| {
            let n = g.u64_in(1..=12);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(p3) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let pk = ParamsK::new(vec![m1, m2, m3], n).unwrap();
            let sol = solve_general(&pk, DEFAULT_COLLECTION_CAP)
                .map_err(|e| format!("{p3}: {e}"))?;
            let _ = uncoded(&p3);
            prop::check(
                sol.load >= lstar(&p3) - 1e-6,
                format!("{p3}: LP {} < L* {}", sol.load, lstar(&p3)),
            )
        });
    }
}
