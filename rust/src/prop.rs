//! Mini property-based testing harness (no `proptest` in the offline build).
//!
//! A property is a closure over a seeded [`Xoshiro256`]; the runner executes
//! `cases` independent cases and, on failure, re-reports the failing seed so
//! the case reproduces exactly (`PropError` carries it). A lightweight
//! shrinking pass retries the property on "smaller" derived seeds to bias
//! reports toward simple cases.
//!
//! ```no_run
//! use hetcdc::prop::{self, Gen};
//! prop::run("xor involution", 64, |g| {
//!     let a = g.u64_in(0..=u64::MAX);
//!     let b = g.u64_in(0..=u64::MAX);
//!     prop::check((a ^ b) ^ b == a, format!("a={a} b={b}"))
//! });
//! ```

use crate::util::rng::Xoshiro256;
use std::ops::RangeInclusive;

/// Generator facade over the PRNG with convenience draws.
pub struct Gen {
    rng: Xoshiro256,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::seed_from_u64(seed),
            seed,
        }
    }

    pub fn u64_in(&mut self, range: RangeInclusive<u64>) -> u64 {
        let (lo, hi) = (*range.start(), *range.end());
        if lo == 0 && hi == u64::MAX {
            return self.rng.next_u64();
        }
        lo + self.rng.gen_range(hi - lo + 1)
    }

    pub fn usize_in(&mut self, range: RangeInclusive<usize>) -> usize {
        self.u64_in(*range.start() as u64..=*range.end() as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.f64_unit()
    }

    pub fn vec_u64(&mut self, len: RangeInclusive<usize>, each: RangeInclusive<u64>) -> Vec<u64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.u64_in(each.clone())).collect()
    }

    /// Pick uniformly from a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.usize_in(0..=xs.len() - 1)]
    }

    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }
}

/// Failure of one property case: a human-readable description of the
/// counterexample. Distinct from [`crate::error::HetcdcError`] — this is
/// test-harness reporting, not an API error — but typed so no public
/// signature carries a bare `String` error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CaseFail(pub String);

impl std::fmt::Display for CaseFail {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl From<String> for CaseFail {
    fn from(s: String) -> Self {
        CaseFail(s)
    }
}

impl From<&str> for CaseFail {
    fn from(s: &str) -> Self {
        CaseFail(s.to_string())
    }
}

impl From<crate::error::HetcdcError> for CaseFail {
    fn from(e: crate::error::HetcdcError) -> Self {
        CaseFail(e.to_string())
    }
}

/// Property outcome: `Ok(())` passes; `Err(fail)` carries the
/// counterexample description.
pub type PropResult = Result<(), CaseFail>;

/// Convenience: boolean condition with a message on failure.
pub fn check(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(CaseFail(msg.into()))
    }
}

/// Convenience: fail a case with a message (for early returns inside
/// property closures).
pub fn fail(msg: impl Into<String>) -> PropResult {
    Err(CaseFail(msg.into()))
}

/// Run `cases` cases of `prop`. Panics (failing the enclosing `#[test]`)
/// with the seed and message of the simplest failure found.
pub fn run<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let base = env_seed().unwrap_or(0xC0FFEE);
    let mut failure: Option<(u64, CaseFail)> = None;
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9));
        let mut gen = Gen::new(seed);
        if let Err(msg) = prop(&mut gen) {
            failure = Some((seed, msg));
            break;
        }
    }
    if let Some((seed, msg)) = failure {
        // Shrink pass: probe nearby "simpler" seeds (smaller draws tend to
        // follow smaller seeds through our generators' first draws).
        let mut simplest = (seed, msg);
        for probe in [1u64, 2, 3, 5, 8, 13, 21, 42] {
            let mut gen = Gen::new(probe);
            if let Err(m) = prop(&mut gen) {
                simplest = (probe, m);
                break;
            }
        }
        panic!(
            "property '{name}' failed (reproduce with HETCDC_PROP_SEED={}): {}",
            simplest.0, simplest.1
        );
    }
}

fn env_seed() -> Option<u64> {
    std::env::var("HETCDC_PROP_SEED").ok()?.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        run("count", 32, |_g| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 32);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        run("fails", 8, |g| {
            let x = g.u64_in(0..=100);
            check(x > 1000, format!("x={x}"))
        });
    }

    #[test]
    fn generators_respect_ranges() {
        run("ranges", 64, |g| {
            let a = g.u64_in(5..=9);
            let v = g.vec_u64(0..=4, 1..=3);
            check(
                (5..=9).contains(&a) && v.len() <= 4 && v.iter().all(|x| (1..=3).contains(x)),
                format!("a={a} v={v:?}"),
            )
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut first = Vec::new();
        let mut g1 = Gen::new(99);
        for _ in 0..10 {
            first.push(g1.u64_in(0..=u64::MAX));
        }
        let mut g2 = Gen::new(99);
        for v in &first {
            assert_eq!(*v, g2.u64_in(0..=u64::MAX));
        }
    }
}
