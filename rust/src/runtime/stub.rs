//! Dependency-free stand-in for the PJRT runtime, compiled when the `xla`
//! feature is off. Presents the same surface as the real
//! [`super::client`] so `XlaBackend`, the CLI, examples, and benches all
//! compile unchanged; every execution entry point returns
//! [`HetcdcError::RuntimeUnavailable`], and `Runtime::load` fails up
//! front so callers fall back to the native backend cleanly.

use super::manifest::ArtifactManifest;
use crate::error::{HetcdcError, Result};
use std::path::{Path, PathBuf};

fn unavailable() -> HetcdcError {
    HetcdcError::RuntimeUnavailable(
        "built without the `xla` cargo feature (PJRT artifacts cannot be executed); \
         use the native backend, or rebuild with `--features xla` and the vendored \
         xla crate (see DESIGN.md)"
            .into(),
    )
}

/// Placeholder for `xla::Literal` in signatures.
#[derive(Clone, Debug)]
pub struct Literal;

/// Stub PJRT runtime: same shape as the real one, never loads.
pub struct Runtime {
    pub manifest: ArtifactManifest,
    /// Executions performed (metrics).
    pub exec_count: u64,
}

impl Runtime {
    /// Always fails: the PJRT client is not compiled in.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let _ = dir.as_ref();
        Err(unavailable())
    }

    /// Default artifact directory: `$HETCDC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("HETCDC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn precompile(&mut self, _names: &[&str]) -> Result<()> {
        Err(unavailable())
    }

    pub fn lit_f32(_data: &[f32], _shape: &[usize]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn lit_i32(_data: &[i32], _shape: &[usize]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn execute(&mut self, _name: &str, _inputs: &[Literal]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn execute_to_f32(&mut self, _name: &str, _inputs: &[Literal]) -> Result<Vec<f32>> {
        Err(unavailable())
    }

    pub fn execute_to_i32(&mut self, _name: &str, _inputs: &[Literal]) -> Result<Vec<i32>> {
        Err(unavailable())
    }

    /// Expected input shapes of an artifact (from the manifest).
    pub fn input_shapes(&self, name: &str) -> Option<&[Vec<usize>]> {
        self.manifest.artifacts.get(name).map(|(_, s)| s.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_with_runtime_unavailable() {
        let err = Runtime::load("artifacts").unwrap_err();
        assert!(matches!(err, HetcdcError::RuntimeUnavailable(_)));
        assert!(err.to_string().contains("xla"));
    }
}
