//! PJRT client wrapper with a compile cache.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax
//! >= 0.5 serializes protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see DESIGN.md and
//! /opt/xla-example/README.md). Executables are compiled once per artifact
//! and cached for the life of the runtime — compilation is off the hot
//! path, execution is on it.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    /// ModelConfig fields baked into the artifacts.
    pub vocab: usize,
    pub q: usize,
    pub t: usize,
    pub map_batch: usize,
    pub keys_per_file: usize,
    pub reduce_batch: usize,
    /// name -> (file, input shapes)
    pub artifacts: HashMap<String, (String, Vec<Vec<usize>>)>,
}

impl ArtifactManifest {
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let cfg = j.get("config").ok_or_else(|| anyhow!("manifest: no config"))?;
        let get = |k: &str| -> Result<usize> {
            cfg.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest config missing '{k}'"))
        };
        let mut artifacts = HashMap::new();
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("manifest: no artifacts"))?;
        for (name, entry) in arts {
            let file = entry
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("artifact {name}: no file"))?
                .to_string();
            let inputs = entry
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| anyhow!("artifact {name}: no inputs"))?
                .iter()
                .map(|inp| {
                    inp.get("shape")
                        .and_then(|s| s.as_arr())
                        .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
                        .ok_or_else(|| anyhow!("artifact {name}: bad shape"))
                })
                .collect::<Result<Vec<Vec<usize>>>>()?;
            artifacts.insert(name.clone(), (file, inputs));
        }
        Ok(ArtifactManifest {
            vocab: get("vocab")?,
            q: get("q")?,
            t: get("t")?,
            map_batch: get("map_batch")?,
            keys_per_file: get("keys_per_file")?,
            reduce_batch: get("reduce_batch")?,
            artifacts,
        })
    }
}

/// PJRT CPU runtime: compile-once, execute-many.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: ArtifactManifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions performed (metrics).
    pub exec_count: u64,
}

impl Runtime {
    /// Load the artifact directory (must contain `manifest.json`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let manifest = ArtifactManifest::parse(&manifest_text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            exes: HashMap::new(),
            exec_count: 0,
        })
    }

    /// Default artifact directory: `$HETCDC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("HETCDC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Compile (or fetch cached) an artifact by name.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let (file, _) = self
                .manifest
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
                .clone();
            let path = self.dir.join(&file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.exes.insert(name.to_string(), exe);
        }
        Ok(self.exes.get(name).unwrap())
    }

    /// Warm the compile cache for a set of artifacts.
    pub fn precompile(&mut self, names: &[&str]) -> Result<()> {
        for name in names {
            self.executable(name)?;
        }
        Ok(())
    }

    fn lit_2d<T: xla::ArrayElement + xla::NativeType>(
        data: &[T],
        shape: &[usize],
    ) -> Result<xla::Literal> {
        let expect: usize = shape.iter().product();
        if data.len() != expect {
            return Err(anyhow!(
                "literal data {} != shape {:?} product {expect}",
                data.len(),
                shape
            ));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
    }

    pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
        Self::lit_2d(data, shape)
    }

    pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
        Self::lit_2d(data, shape)
    }

    /// Execute artifact `name`; returns the single tuple element as a
    /// literal (aot.py lowers everything with `return_tuple=True`).
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        self.exec_count += 1;
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        result
            .to_tuple1()
            .map_err(|e| anyhow!("untupling {name} result: {e:?}"))
    }

    pub fn execute_to_f32(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        self.execute(name, inputs)?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("f32 result of {name}: {e:?}"))
    }

    pub fn execute_to_i32(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<i32>> {
        self.execute(name, inputs)?
            .to_vec::<i32>()
            .map_err(|e| anyhow!("i32 result of {name}: {e:?}"))
    }

    /// Expected input shapes of an artifact (from the manifest).
    pub fn input_shapes(&self, name: &str) -> Option<&[Vec<usize>]> {
        self.manifest.artifacts.get(name).map(|(_, s)| s.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{
          "artifacts": {
            "map_project": {"file": "map_project.hlo.txt",
              "inputs": [{"dtype": "float32", "shape": [96, 256]},
                         {"dtype": "float32", "shape": [256, 16]}]}
          },
          "config": {"vocab": 256, "q": 3, "t": 32, "map_batch": 16,
                     "keys_per_file": 512, "reduce_batch": 16,
                     "xor_rows": 8, "xor_cols": 128}
        }"#;
        let m = ArtifactManifest::parse(text).unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.q, 3);
        let (file, shapes) = &m.artifacts["map_project"];
        assert_eq!(file, "map_project.hlo.txt");
        assert_eq!(shapes[0], vec![96, 256]);
        assert_eq!(shapes[1], vec![256, 16]);
    }

    #[test]
    fn manifest_rejects_missing_fields() {
        assert!(ArtifactManifest::parse("{}").is_err());
        assert!(ArtifactManifest::parse(r#"{"config": {}, "artifacts": {}}"#).is_err());
    }

    // Live PJRT tests are in rust/tests/runtime_integration.rs (they need
    // `make artifacts` to have run).
}
