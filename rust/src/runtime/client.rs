//! PJRT client wrapper with a compile cache (`xla` feature only).
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax
//! >= 0.5 serializes protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see DESIGN.md and
//! /opt/xla-example/README.md). Executables are compiled once per artifact
//! and cached for the life of the runtime — compilation is off the hot
//! path, execution is on it.

use super::manifest::ArtifactManifest;
use crate::error::{HetcdcError, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

fn rt_err(msg: impl std::fmt::Display) -> HetcdcError {
    HetcdcError::RuntimeUnavailable(msg.to_string())
}

fn exec_err(msg: impl std::fmt::Display) -> HetcdcError {
    HetcdcError::Backend(msg.to_string())
}

/// PJRT CPU runtime: compile-once, execute-many.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: ArtifactManifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions performed (metrics).
    pub exec_count: u64,
}

impl Runtime {
    /// Load the artifact directory (must contain `manifest.json`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            rt_err(format!(
                "reading {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let manifest = ArtifactManifest::parse(&manifest_text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| rt_err(format!("PJRT cpu client: {e:?}")))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            exes: HashMap::new(),
            exec_count: 0,
        })
    }

    /// Default artifact directory: `$HETCDC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("HETCDC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Compile (or fetch cached) an artifact by name.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let (file, _) = self
                .manifest
                .artifacts
                .get(name)
                .ok_or_else(|| exec_err(format!("unknown artifact '{name}'")))?
                .clone();
            let path = self.dir.join(&file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| exec_err("non-utf8 path"))?,
            )
            .map_err(|e| exec_err(format!("parsing {}: {e:?}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| exec_err(format!("compiling {name}: {e:?}")))?;
            self.exes.insert(name.to_string(), exe);
        }
        Ok(self.exes.get(name).unwrap())
    }

    /// Warm the compile cache for a set of artifacts.
    pub fn precompile(&mut self, names: &[&str]) -> Result<()> {
        for name in names {
            self.executable(name)?;
        }
        Ok(())
    }

    fn lit_2d<T: xla::ArrayElement + xla::NativeType>(
        data: &[T],
        shape: &[usize],
    ) -> Result<xla::Literal> {
        let expect: usize = shape.iter().product();
        if data.len() != expect {
            return Err(exec_err(format!(
                "literal data {} != shape {:?} product {expect}",
                data.len(),
                shape
            )));
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| exec_err(format!("reshape {shape:?}: {e:?}")))
    }

    pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
        Self::lit_2d(data, shape)
    }

    pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
        Self::lit_2d(data, shape)
    }

    /// Execute artifact `name`; returns the single tuple element as a
    /// literal (aot.py lowers everything with `return_tuple=True`).
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        self.exec_count += 1;
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| exec_err(format!("executing {name}: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| exec_err(format!("fetching {name} result: {e:?}")))?;
        result
            .to_tuple1()
            .map_err(|e| exec_err(format!("untupling {name} result: {e:?}")))
    }

    pub fn execute_to_f32(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        self.execute(name, inputs)?
            .to_vec::<f32>()
            .map_err(|e| exec_err(format!("f32 result of {name}: {e:?}")))
    }

    pub fn execute_to_i32(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<i32>> {
        self.execute(name, inputs)?
            .to_vec::<i32>()
            .map_err(|e| exec_err(format!("i32 result of {name}: {e:?}")))
    }

    /// Expected input shapes of an artifact (from the manifest).
    pub fn input_shapes(&self, name: &str) -> Option<&[Vec<usize>]> {
        self.manifest.artifacts.get(name).map(|(_, s)| s.as_slice())
    }
}

// Manifest parsing tests live in super::manifest; live PJRT tests are in
// rust/tests/runtime_integration.rs (they need `make artifacts`).
