//! Parsed `artifacts/manifest.json` — pure JSON work, shared by the real
//! PJRT client (`xla` feature) and the dependency-free stub.

use crate::error::{HetcdcError, Result};
use crate::util::json::Json;
use std::collections::HashMap;

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    /// ModelConfig fields baked into the artifacts.
    pub vocab: usize,
    pub q: usize,
    pub t: usize,
    pub map_batch: usize,
    pub keys_per_file: usize,
    pub reduce_batch: usize,
    /// name -> (file, input shapes)
    pub artifacts: HashMap<String, (String, Vec<Vec<usize>>)>,
}

impl ArtifactManifest {
    pub fn parse(text: &str) -> Result<Self> {
        let bad = |m: String| HetcdcError::Json(format!("manifest: {m}"));
        let j = Json::parse(text).map_err(|e| bad(e.to_string()))?;
        let cfg = j.get("config").ok_or_else(|| bad("no config".into()))?;
        let get = |k: &str| -> Result<usize> {
            cfg.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| bad(format!("config missing '{k}'")))
        };
        let mut artifacts = HashMap::new();
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| bad("no artifacts".into()))?;
        for (name, entry) in arts {
            let file = entry
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| bad(format!("artifact {name}: no file")))?
                .to_string();
            let inputs = entry
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| bad(format!("artifact {name}: no inputs")))?
                .iter()
                .map(|inp| {
                    inp.get("shape")
                        .and_then(|s| s.as_arr())
                        .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
                        .ok_or_else(|| bad(format!("artifact {name}: bad shape")))
                })
                .collect::<Result<Vec<Vec<usize>>>>()?;
            artifacts.insert(name.clone(), (file, inputs));
        }
        Ok(ArtifactManifest {
            vocab: get("vocab")?,
            q: get("q")?,
            t: get("t")?,
            map_batch: get("map_batch")?,
            keys_per_file: get("keys_per_file")?,
            reduce_batch: get("reduce_batch")?,
            artifacts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{
          "artifacts": {
            "map_project": {"file": "map_project.hlo.txt",
              "inputs": [{"dtype": "float32", "shape": [96, 256]},
                         {"dtype": "float32", "shape": [256, 16]}]}
          },
          "config": {"vocab": 256, "q": 3, "t": 32, "map_batch": 16,
                     "keys_per_file": 512, "reduce_batch": 16,
                     "xor_rows": 8, "xor_cols": 128}
        }"#;
        let m = ArtifactManifest::parse(text).unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.q, 3);
        let (file, shapes) = &m.artifacts["map_project"];
        assert_eq!(file, "map_project.hlo.txt");
        assert_eq!(shapes[0], vec![96, 256]);
        assert_eq!(shapes[1], vec![256, 16]);
    }

    #[test]
    fn manifest_rejects_missing_fields() {
        assert!(ArtifactManifest::parse("{}").is_err());
        assert!(ArtifactManifest::parse(r#"{"config": {}, "artifacts": {}}"#).is_err());
    }
}
