//! PJRT execution runtime: loads the AOT artifacts (HLO text) emitted by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! This is the only module that touches the `xla` crate; Python never
//! runs at request time.
//!
//! The `xla` cargo feature gates the real client (it needs the vendored
//! `xla` crate closure — see DESIGN.md). Without it, [`stub`] provides
//! the same surface with every execution path returning
//! [`crate::error::HetcdcError::RuntimeUnavailable`], so the rest of the
//! crate (and its binaries, benches, and examples) builds dependency-free
//! and falls back to the native backend at runtime.

pub mod manifest;

#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub use client::Runtime;

#[cfg(not(feature = "xla"))]
pub mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::Runtime;

pub use manifest::ArtifactManifest;
