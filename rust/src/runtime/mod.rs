//! PJRT execution runtime: loads the AOT artifacts (HLO text) emitted by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! This is the only module that touches the `xla` crate; Python never runs
//! at request time.

pub mod client;

pub use client::{ArtifactManifest, Runtime};
