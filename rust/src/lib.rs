//! # hetcdc — Heterogeneous Coded Distributed Computing
//!
//! A production-shaped implementation of *On Heterogeneous Coded
//! Distributed Computing* (Kiamari, Wang, Avestimehr, 2017): a
//! MapReduce-style distributed computing framework whose Shuffle phase is
//! **coded** (multi-round XOR multicast on a group-structured shuffle IR,
//! eqs. (8)–(10)) and whose file placement is optimized for clusters with
//! **heterogeneous per-node storage** (Theorem 1 for K=3; the §V linear
//! program for general K; a combinatorial grid design for large K).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **Layer 1/2 (build-time Python)** — Pallas kernels + JAX Map/Reduce
//!   graphs, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **Layer 3 (this crate)** — placement theory, LP solver, coded shuffle
//!   planning, broadcast-network simulation, the staged execution
//!   pipeline, and the PJRT runtime that executes the artifacts (`xla`
//!   feature). Python never runs at request time.
//!
//! ## The staged pipeline
//!
//! The public API separates what depends on *shape* from what depends on
//! *data*:
//!
//! ```text
//! JobBuilder ──build()──▶ Plan ──with_config()──▶ Executor ──run_batch()──▶ RunReport
//!  (cluster, job,          immutable, validated,      reusable buffers,        per-batch
//!   placer, coder, mode)   serializable artifact      many data batches        measurements
//! ```
//!
//! * [`engine::JobBuilder`] resolves a [`placement::Placer`] and a
//!   [`coding::ShuffleCoder`] from their registries (the five classic
//!   strategies are trait impls) and builds a plan.
//! * [`engine::Plan`] bundles the allocation, the broadcast schedule, the
//!   decode schedule, and exact predicted loads/times. It is verified by
//!   the symbolic decoder **at build time** — execution never re-checks
//!   decodability — and round-trips through JSON (`hetcdc plan`,
//!   `hetcdc run --plan`; schema in DESIGN.md).
//! * [`engine::Executor`] runs many data batches against one plan,
//!   reusing every per-node buffer; [`engine::PlanCache`] memoizes plans
//!   by (cluster shape, job shape, strategy) for the heavy-traffic path.
//!   [`engine::ExecMode::Parallel`] shards per-node Map and decode across
//!   scoped threads, and [`engine::ExecMode::Pipelined`] additionally
//!   overlaps the Map of batch `i+1` with the Shuffle of batch `i` on
//!   double-buffered epoch banks ([`engine::Executor::run_batches`]) —
//!   both with **bit-identical** outputs and reports to serial mode
//!   (DESIGN.md "Parallel execution model" and "Pipelined execution
//!   model").
//! * [`engine::Engine`] is the one-shot facade when a single batch is all
//!   you need.
//!
//! Every fallible API returns [`error::HetcdcError`] (re-exported at the
//! crate root) — no stringly-typed errors.
//!
//! Theory quick tour:
//! * [`theory`] — Theorem 1 closed forms, converse bounds, baselines.
//! * [`placement`] — optimal K=3 placements, Lemma-1 pairing, §V LP, the
//!   combinatorial grid design.
//! * [`coding`] — the round/group shuffle IR, the coders, the symbolic
//!   decoder, decode schedules.
//! * [`lp`] — two-phase simplex (f64 + exact rational), from scratch.

pub mod bench;
pub mod coding;
pub mod engine;
pub mod error;
pub mod lp;
pub mod model;
pub mod net;
pub mod placement;
pub mod prop;
pub mod runtime;
pub mod theory;
pub mod util;
pub mod workloads;

pub use error::HetcdcError;
