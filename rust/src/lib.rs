//! # hetcdc — Heterogeneous Coded Distributed Computing
//!
//! A production-shaped implementation of *On Heterogeneous Coded
//! Distributed Computing* (Kiamari, Wang, Avestimehr, 2017): a
//! MapReduce-style distributed computing framework whose Shuffle phase is
//! **coded** (XOR multicast, eqs. (8)–(10)) and whose file placement is
//! optimized for clusters with **heterogeneous per-node storage**
//! (Theorem 1 for K=3; the §V linear program for general K).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **Layer 1/2 (build-time Python)** — Pallas kernels + JAX Map/Reduce
//!   graphs, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **Layer 3 (this crate)** — placement theory, LP solver, coded shuffle
//!   planning, broadcast-network simulation, the MapReduce engine, and the
//!   PJRT runtime that executes the artifacts. Python never runs at
//!   request time.
//!
//! Quick tour:
//! * [`theory`] — Theorem 1 closed forms, converse bounds, baselines.
//! * [`placement`] — optimal K=3 placements, Lemma-1 pairing, §V LP.
//! * [`lp`] — two-phase simplex (f64 + exact rational), from scratch.

pub mod bench;
pub mod coding;
pub mod engine;
pub mod lp;
pub mod model;
pub mod net;
pub mod placement;
pub mod prop;
pub mod runtime;
pub mod theory;
pub mod util;
pub mod workloads;
