//! From-scratch substrates: PRNG, JSON, CLI parsing, logging, statistics.
//!
//! The offline build vendors only the `xla` crate closure, so everything a
//! typical project would pull from `rand`/`serde`/`clap`/`log` is
//! implemented (and tested) here.

pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod shard;
pub mod stats;
