//! Deterministic index-sharded fan-out on scoped threads — the one
//! implementation of "split an index space across workers and merge the
//! shards back in index order" that every parallel plan-build stage
//! shares (combinatorial groups and rounds, decoder node sharding).

/// Build `n` items by index with up to `workers` scoped threads: the
/// index space splits into contiguous per-worker ranges, each worker
/// maps its range with `build`, and the shards concatenate back in
/// index order. Because `build` is a pure function of the range, the
/// result is **identical** for every worker count (including 0/1 =
/// serial) — this is where the determinism argument of the threaded
/// build path lives, in one place.
///
/// Panics if a worker panics (the panic is propagated on join), like
/// running `build` inline would.
pub fn shard_indexed<T, F>(n: usize, workers: usize, build: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> Vec<T> + Sync,
{
    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 {
        return build(0..n);
    }
    let chunk = n.div_ceil(workers);
    let build = &build;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let lo = w * chunk;
                let hi = ((w + 1) * chunk).min(n);
                s.spawn(move || build(lo..hi))
            })
            .collect();
        let mut all = Vec::with_capacity(n);
        for h in handles {
            all.extend(h.join().expect("index-shard worker"));
        }
        all
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_for_every_worker_count() {
        let serial: Vec<usize> = (0..37).map(|i| i * i).collect();
        for workers in [0usize, 1, 2, 3, 5, 8, 64] {
            let sharded =
                shard_indexed(37, workers, |r| r.map(|i| i * i).collect());
            assert_eq!(serial, sharded, "workers={workers}");
        }
    }

    #[test]
    fn empty_and_tiny_inputs_are_fine() {
        assert!(shard_indexed(0, 4, |r| r.collect::<Vec<_>>()).is_empty());
        assert_eq!(shard_indexed(1, 4, |r| r.collect::<Vec<_>>()), vec![0]);
    }
}
