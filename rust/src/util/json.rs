//! Minimal JSON substrate (no `serde` in the offline build).
//!
//! Parses and serializes the subset of JSON the framework needs: the AOT
//! `artifacts/manifest.json`, cluster-spec config files, and metrics dumps.
//! Numbers are `f64` (integers round-trip exactly up to 2^53, far beyond
//! any file/byte count used here).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns None on any missing step.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, text: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{text}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.bytes.len());
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap(),
            &Json::Str("x".to_string())
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let text = r#"{"k":[1,2.5,"s\"q"],"m":{"x":true}}"#;
        let v = Json::parse(text).unwrap();
        let compact = v.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_roundtrip_exactly() {
        let v = Json::parse("123456789012").unwrap();
        assert_eq!(v.to_string(), "123456789012");
        assert_eq!(v.as_usize(), Some(123456789012));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "artifacts": {"map_project": {"file": "map_project.hlo.txt",
            "inputs": [{"dtype": "float32", "shape": [96, 256]}]}},
          "config": {"vocab": 256, "q": 3}
        }"#;
        let v = Json::parse(text).unwrap();
        let inputs = v
            .get("artifacts")
            .unwrap()
            .get("map_project")
            .unwrap()
            .get("inputs")
            .unwrap()
            .as_arr()
            .unwrap();
        let shape: Vec<usize> = inputs[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![96, 256]);
        assert_eq!(v.get("config").unwrap().get("vocab").unwrap().as_usize(), Some(256));
    }

    #[test]
    fn unicode_strings_roundtrip() {
        let v = Json::parse("\"héllo ∑ \\u00e9\"").unwrap();
        assert_eq!(v, Json::Str("héllo ∑ é".to_string()));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
