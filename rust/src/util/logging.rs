//! Leveled stderr logger substrate (no `log`/`env_logger` in offline build).
//!
//! `HETCDC_LOG=debug|info|warn|error` controls verbosity (default `info`).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: std::sync::Once = std::sync::Once::new();

pub fn init() {
    INIT.call_once(|| {
        let lvl = match std::env::var("HETCDC_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[hetcdc {tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_gates_output() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Info);
    }
}
