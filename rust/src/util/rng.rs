//! Deterministic PRNG substrate (no `rand` crate in the offline build).
//!
//! [`SplitMix64`] seeds [`Xoshiro256`] (xoshiro256++), the generator used
//! throughout the workload generators, property tests and benches. Both are
//! well-studied public-domain designs; determinism matters more than
//! cryptographic quality here — every experiment in EXPERIMENTS.md records
//! its seed and reproduces bit-for-bit.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the main generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, bound)` (Lemire's rejection method).
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)` for `usize` ranges.
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa).
    pub fn f64_unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64_unit();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64_unit();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Uniformly pick an element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose on empty slice");
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

/// Zipf(s) sampler over `{0, .., n-1}` by inverse-CDF table; models the
/// skewed token distribution of the WordCount corpus (DESIGN.md §4).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 1..=n {
            acc += 1.0 / (i as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let u = rng.f64_unit();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256::seed_from_u64(7);
        let mut b = Xoshiro256::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.gen_range(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f64_unit_in_range_and_mean_near_half() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.f64_unit();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let z = Zipf::new(100, 1.1);
        let mut counts = [0u32; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[50] && counts[0] > counts[99]);
        assert!(counts[0] > 1000, "head heavy: {}", counts[0]);
    }
}
