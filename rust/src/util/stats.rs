//! Summary-statistics substrate for benches and the network simulator.

/// Online accumulator plus exact percentiles over retained samples.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .samples
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / (n - 1) as f64;
        var.sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile by nearest-rank (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Human-readable duration (ns input).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Human-readable byte count.
pub fn fmt_bytes(b: f64) -> String {
    if b < 1024.0 {
        format!("{b:.0} B")
    } else if b < 1024.0 * 1024.0 {
        format!("{:.2} KiB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} MiB", b / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_known_values() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for x in 0..=100 {
            s.add(x as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.median(), 50.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.percentile(90.0), 90.0);
    }

    #[test]
    fn empty_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(512.0), "512 ns");
        assert_eq!(fmt_ns(1_500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_bytes(10.0), "10 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert_eq!(fmt_bytes(3.0 * 1024.0 * 1024.0), "3.00 MiB");
    }
}
