//! Tiny argument-parsing substrate (no `clap` in the offline build).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with typed getters and generated usage text.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Option names the user explicitly passed (vs spec defaults).
    explicit: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse `argv` against `specs`. Unknown `--options` are errors.
    pub fn parse(argv: &[String], specs: &[ArgSpec]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for spec in specs {
            if let (true, Some(d)) = (spec.takes_value, spec.default) {
                args.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}")))?;
                if spec.takes_value {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                        }
                    };
                    args.explicit.push(name.clone());
                    args.values.insert(name, val);
                } else {
                    if inline.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    args.explicit.push(name.clone());
                    args.flags.push(name);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// True when the user explicitly passed `--name` (spec defaults do
    /// not count).
    pub fn provided(&self, name: &str) -> bool {
        self.explicit.iter().any(|n| n == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        self.req(name)?
            .parse()
            .map_err(|_| CliError(format!("--{name} expects an integer")))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        self.req(name)?
            .parse()
            .map_err(|_| CliError(format!("--{name} expects an integer")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.req(name)?
            .parse()
            .map_err(|_| CliError(format!("--{name} expects a number")))
    }

    /// Parse a comma-separated list of integers, e.g. `--storage 6,7,7`.
    pub fn get_u64_list(&self, name: &str) -> Result<Vec<u64>, CliError> {
        self.req(name)?
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| CliError(format!("--{name}: bad integer '{s}'")))
            })
            .collect()
    }

    fn req(&self, name: &str) -> Result<&str, CliError> {
        self.get(name)
            .ok_or_else(|| CliError(format!("missing required option --{name}")))
    }
}

/// Option specs shared verbatim by the planning/execution subcommands
/// (`plan`, `run`, and — where applicable — `bench-json`), so the three
/// help outputs can never drift apart on the flags they share. Each
/// subcommand flattens the consts it supports into its own spec table;
/// `cli_integration` asserts the help texts agree.
///
/// `bench-json` deliberately keeps its *own* `--threads` spec: its
/// default is `0` (auto) where `plan`/`run` default to `1` (serial), and
/// changing either default would change behavior.
pub mod common {
    use super::ArgSpec;

    pub const THREADS: ArgSpec = ArgSpec {
        name: "threads",
        help: "worker threads for plan build and execution: 1 = serial; N > 1 = sharded; \
               0 = auto-detect (results are byte-identical at every N)",
        takes_value: true,
        default: Some("1"),
    };

    pub const PLACEMENT: ArgSpec = ArgSpec {
        name: "placement",
        help: "auto | optimal-k3 | lp-general (exact) | lp-capped | homogeneous | oblivious \
               | combinatorial",
        takes_value: true,
        default: Some("auto"),
    };

    pub const CODER: ArgSpec = ArgSpec {
        name: "coder",
        help: "pairing | greedy | multicast | memshare | combinatorial (default: placer's)",
        takes_value: true,
        default: None,
    };

    pub const LP_CAP: ArgSpec = ArgSpec {
        name: "lp-cap",
        help: "max perfect collections per §V LP subsystem (Remark 7 cap; default 4096)",
        takes_value: true,
        default: None,
    };

    pub const TOPOLOGY: ArgSpec = ArgSpec {
        name: "topology",
        help: "network topology: shared | flat | rack:q=R,oversub=S | fat-tree:q=R \
               (overrides the cluster's; default shared medium)",
        takes_value: true,
        default: None,
    };

    pub const FAULTS: ArgSpec = ArgSpec {
        name: "faults",
        help: "fault model: none | straggle:seed=S,amp=A | repair:f=N | \
               erase:seed=S,p=P | erase:list=r.g.b,... | \
               drop:node=I,at_batch=B | clauses joined with ';' \
               (overrides the cluster's; default none)",
        takes_value: true,
        default: None,
    };

    pub const HELP: ArgSpec = ArgSpec {
        name: "help",
        help: "show usage",
        takes_value: false,
        default: None,
    };
}

pub fn usage(program: &str, about: &str, specs: &[ArgSpec]) -> String {
    let mut s = format!("{about}\n\nUsage: {program} [options]\n\nOptions:\n");
    for spec in specs {
        let val = if spec.takes_value { " <value>" } else { "" };
        let default = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{val}\n      {}{default}\n", spec.name, spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ArgSpec> {
        vec![
            ArgSpec { name: "n", help: "files", takes_value: true, default: Some("12") },
            ArgSpec { name: "storage", help: "per-node", takes_value: true, default: None },
            ArgSpec { name: "verbose", help: "log more", takes_value: false, default: None },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&[], &specs()).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 12);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn parses_values_flags_positional() {
        let argv = sv(&["--n", "20", "--verbose", "pos1", "--storage=6,7,7"]);
        let a = Args::parse(&argv, &specs()).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 20);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.get_u64_list("storage").unwrap(), vec![6, 7, 7]);
    }

    #[test]
    fn unknown_option_is_error() {
        assert!(Args::parse(&sv(&["--bogus"]), &specs()).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["--n"]), &specs()).is_err());
        let a = Args::parse(&[], &specs()).unwrap();
        assert!(a.get_u64_list("storage").is_err());
    }

    #[test]
    fn bad_integer_is_error() {
        let a = Args::parse(&sv(&["--n", "xyz"]), &specs()).unwrap();
        assert!(a.get_usize("n").is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("hetcdc", "about", &specs());
        assert!(u.contains("--n") && u.contains("--storage") && u.contains("--verbose"));
    }

    #[test]
    fn common_specs_are_well_formed() {
        let all = [
            common::THREADS,
            common::PLACEMENT,
            common::CODER,
            common::LP_CAP,
            common::TOPOLOGY,
            common::FAULTS,
            common::HELP,
        ];
        for spec in &all {
            assert!(!spec.name.is_empty() && !spec.help.is_empty(), "{spec:?}");
        }
        // --help is the only shared flag; everything else takes a value.
        assert!(!common::HELP.takes_value);
        assert!(all.iter().filter(|s| s.takes_value).count() == all.len() - 1);
        // A spec table built from the consts parses normally.
        let argv: Vec<String> = ["--faults", "straggle:seed=1,amp=0.5", "--threads", "2"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&argv, &all).unwrap();
        assert_eq!(a.get("faults"), Some("straggle:seed=1,amp=0.5"));
        assert_eq!(a.get_usize("threads").unwrap(), 2);
    }
}
