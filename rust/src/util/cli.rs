//! Tiny argument-parsing substrate (no `clap` in the offline build).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with typed getters and generated usage text.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Option names the user explicitly passed (vs spec defaults).
    explicit: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse `argv` against `specs`. Unknown `--options` are errors.
    pub fn parse(argv: &[String], specs: &[ArgSpec]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for spec in specs {
            if let (true, Some(d)) = (spec.takes_value, spec.default) {
                args.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}")))?;
                if spec.takes_value {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                        }
                    };
                    args.explicit.push(name.clone());
                    args.values.insert(name, val);
                } else {
                    if inline.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    args.explicit.push(name.clone());
                    args.flags.push(name);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// True when the user explicitly passed `--name` (spec defaults do
    /// not count).
    pub fn provided(&self, name: &str) -> bool {
        self.explicit.iter().any(|n| n == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        self.req(name)?
            .parse()
            .map_err(|_| CliError(format!("--{name} expects an integer")))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        self.req(name)?
            .parse()
            .map_err(|_| CliError(format!("--{name} expects an integer")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.req(name)?
            .parse()
            .map_err(|_| CliError(format!("--{name} expects a number")))
    }

    /// Parse a comma-separated list of integers, e.g. `--storage 6,7,7`.
    pub fn get_u64_list(&self, name: &str) -> Result<Vec<u64>, CliError> {
        self.req(name)?
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| CliError(format!("--{name}: bad integer '{s}'")))
            })
            .collect()
    }

    fn req(&self, name: &str) -> Result<&str, CliError> {
        self.get(name)
            .ok_or_else(|| CliError(format!("missing required option --{name}")))
    }
}

pub fn usage(program: &str, about: &str, specs: &[ArgSpec]) -> String {
    let mut s = format!("{about}\n\nUsage: {program} [options]\n\nOptions:\n");
    for spec in specs {
        let val = if spec.takes_value { " <value>" } else { "" };
        let default = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{val}\n      {}{default}\n", spec.name, spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ArgSpec> {
        vec![
            ArgSpec { name: "n", help: "files", takes_value: true, default: Some("12") },
            ArgSpec { name: "storage", help: "per-node", takes_value: true, default: None },
            ArgSpec { name: "verbose", help: "log more", takes_value: false, default: None },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&[], &specs()).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 12);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn parses_values_flags_positional() {
        let argv = sv(&["--n", "20", "--verbose", "pos1", "--storage=6,7,7"]);
        let a = Args::parse(&argv, &specs()).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 20);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.get_u64_list("storage").unwrap(), vec![6, 7, 7]);
    }

    #[test]
    fn unknown_option_is_error() {
        assert!(Args::parse(&sv(&["--bogus"]), &specs()).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["--n"]), &specs()).is_err());
        let a = Args::parse(&[], &specs()).unwrap();
        assert!(a.get_u64_list("storage").is_err());
    }

    #[test]
    fn bad_integer_is_error() {
        let a = Args::parse(&sv(&["--n", "xyz"]), &specs()).unwrap();
        assert!(a.get_usize("n").is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("hetcdc", "about", &specs());
        assert!(u.contains("--n") && u.contains("--storage") && u.contains("--verbose"));
    }
}
