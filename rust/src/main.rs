//! `hetcdc` CLI — the framework launcher.
//!
//! Subcommands:
//! * `loadstar`  — Theorem-1 closed form, regime, converse bounds.
//! * `place`     — construct + print the optimal allocation.
//! * `lp`        — run the §V LP for general K.
//! * `plan`      — build a validated execution plan and emit it as JSON.
//! * `run`       — execute a MapReduce job (native or XLA backend),
//!                 either planning inline or consuming `--plan FILE`,
//!                 for one or many data batches, serial, sharded across
//!                 threads (`--threads`), or batch-pipelined
//!                 (`--pipeline`: Map of batch i+1 overlaps Shuffle of
//!                 batch i — bit-identical reports, higher batches/sec).
//! * `bench-json`— deterministic shuffle/executor benchmark suite,
//!                 emitted as `BENCH_shuffle.json` and optionally gated
//!                 against a committed baseline (the CI bench-smoke job).
//! * `sweep`     — L* table over a storage grid.
//! * `info`      — artifact manifest summary.

use hetcdc::bench::{self, BaselineStatus, Bench};
use hetcdc::engine::{
    ExecConfig, ExecMode, Executor, JobBuilder, MapBackend, NativeBackend, Plan, RunReport,
    XlaBackend,
};
use hetcdc::model::cluster::ClusterSpec;
use hetcdc::model::job::{JobSpec, ShuffleMode};
use hetcdc::net::{FaultSpec, Topology};
use hetcdc::placement::{k3, lp_general};
use hetcdc::runtime::Runtime;
use hetcdc::theory::params::{Params3, ParamsK};
use hetcdc::theory::{converse, homogeneous as th_hom, load};
use hetcdc::util::cli::{common, usage, ArgSpec, Args};
use hetcdc::HetcdcError;

fn main() {
    hetcdc::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("loadstar") => cmd_loadstar(&argv[1..]),
        Some("place") => cmd_place(&argv[1..]),
        Some("lp") => cmd_lp(&argv[1..]),
        Some("plan") => cmd_plan(&argv[1..]),
        Some("run") => cmd_run(&argv[1..]),
        Some("bench-json") => cmd_bench_json(&argv[1..]),
        Some("sweep") => cmd_sweep(&argv[1..]),
        Some("verify") => cmd_verify(&argv[1..]),
        Some("info") => cmd_info(&argv[1..]),
        Some("--help") | Some("-h") | None => {
            print_help();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "hetcdc — Heterogeneous Coded Distributed Computing\n\n\
         Usage: hetcdc <subcommand> [options]\n\n\
         Subcommands:\n\
         \x20 loadstar  --storage M1,M2,M3 --n N     Theorem-1 minimum load\n\
         \x20 place     --storage M1,M2,M3 --n N     optimal file placement\n\
         \x20 lp        --storage M1,..,MK --n N     §V LP for general K\n\
         \x20 plan      --workload wordcount|terasort [--storage ... | --config ...]\n\
         \x20           [--placement NAME] [--coder NAME] [--out plan.json]\n\
         \x20           [--threads N] [--lp-cap N] [--topology SPEC] [--faults SPEC]\n\
         \x20           build + verify an execution plan (threaded build), emit JSON\n\
         \x20 run       --workload wordcount|terasort [--backend native|xla]\n\
         \x20           [--config cluster.json | --storage ...] [--mode coded|uncoded]\n\
         \x20           [--plan plan.json] [--batches B] [--threads N] [--pipeline]\n\
         \x20           [--lp-cap N] [--topology SPEC] [--faults SPEC]\n\
         \x20 bench-json [--out FILE] [--baseline FILE] [--tolerance-pct P] [--check-armed]\n\
         \x20           [--topology SPEC] [--faults SPEC]\n\
         \x20           deterministic shuffle bench suite -> BENCH_shuffle.json\n\
         \x20 sweep     --n N [--max-m M]            L* table over storage grid\n\
         \x20 verify    [--n N]                      full self-check (theory, coding, LP)\n\
         \x20 info      [--artifacts DIR]            artifact manifest summary\n\n\
         Run `hetcdc <subcommand> --help` for details."
    );
}

fn fail(e: impl std::fmt::Display) -> i32 {
    eprintln!("error: {e}");
    1
}

#[rustfmt::skip]
const STORAGE_SPECS: &[ArgSpec] = &[
    ArgSpec { name: "storage", help: "comma-separated per-node storage (files)", takes_value: true, default: Some("6,7,7") },
    ArgSpec { name: "n", help: "number of files N", takes_value: true, default: Some("12") },
    ArgSpec { name: "help", help: "show usage", takes_value: false, default: None },
];

fn parse_params3(args: &Args) -> Result<Params3, HetcdcError> {
    let m = args
        .get_u64_list("storage")
        .map_err(|e| HetcdcError::InvalidParams(e.to_string()))?;
    if m.len() != 3 {
        return Err(HetcdcError::InvalidParams(format!(
            "expected 3 storage values, got {}",
            m.len()
        )));
    }
    let n = args
        .get_u64("n")
        .map_err(|e| HetcdcError::InvalidParams(e.to_string()))?;
    Params3::new(m[0], m[1], m[2], n)
}

fn cmd_loadstar(argv: &[String]) -> i32 {
    let args = match Args::parse(argv, STORAGE_SPECS) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if args.flag("help") {
        println!(
            "{}",
            usage("hetcdc loadstar", "Theorem-1 minimum communication load", STORAGE_SPECS)
        );
        return 0;
    }
    let p = match parse_params3(&args) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let b = converse::bounds_half(&p);
    println!("params            {p}");
    println!("regime            {}", load::classify(&p));
    println!("L* (coded)        {}", load::lstar(&p));
    println!("uncoded           {}", load::uncoded(&p));
    println!(
        "saving            {} ({:.1}%)",
        load::saving(&p),
        100.0 * load::saving(&p) / load::uncoded(&p).max(1e-12)
    );
    println!(
        "converse bounds   corollary={} loose={} cutset={} genie={}",
        b.corollary_tight as f64 / 2.0,
        b.corollary_loose as f64 / 2.0,
        b.cutset as f64 / 2.0,
        b.genie as f64 / 2.0
    );
    if p.is_homogeneous() {
        let r = 3.0 * p.m[0] as f64 / p.n as f64;
        println!(
            "homogeneous [2]   r={r:.2} envelope={}",
            th_hom::load_envelope(3, r, p.n)
        );
    }
    0
}

fn cmd_place(argv: &[String]) -> i32 {
    let args = match Args::parse(argv, STORAGE_SPECS) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if args.flag("help") {
        println!(
            "{}",
            usage("hetcdc place", "Optimal K=3 file placement (Figs 5-11)", STORAGE_SPECS)
        );
        return 0;
    }
    let p = match parse_params3(&args) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let alloc = k3::optimal_allocation(&p);
    let sizes = alloc.subset_sizes();
    println!("params {p}  regime {}  sp={}", load::classify(&p), alloc.sp);
    println!("subset sizes (subfile units, sp·files):");
    for mask in 1u32..8 {
        let nodes: Vec<String> = (0..3)
            .filter(|i| mask & (1 << i) != 0)
            .map(|i| (i + 1).to_string())
            .collect();
        println!("  S{{{}}} = {}", nodes.join(","), sizes[mask as usize]);
    }
    let plan = hetcdc::coding::plan::plan_k3(&alloc);
    println!(
        "achievable load {} (L* = {}), {} broadcasts in {} rounds ({:.0}% coded)",
        plan.load_equations(&alloc),
        load::lstar(&p),
        plan.n_broadcasts(),
        plan.round_count(),
        100.0 * plan.coded_fraction()
    );
    0
}

fn cmd_lp(argv: &[String]) -> i32 {
    #[rustfmt::skip]
    let specs: Vec<ArgSpec> = vec![
        ArgSpec { name: "storage", help: "comma-separated per-node storage", takes_value: true, default: Some("3,5,6,8") },
        ArgSpec { name: "n", help: "number of files N", takes_value: true, default: Some("12") },
        ArgSpec { name: "cap", help: "max perfect collections per subsystem", takes_value: true, default: Some("4096") },
        ArgSpec { name: "capped", help: "legacy capped relaxation (skip the exact dual-certified path)", takes_value: false, default: None },
        ArgSpec { name: "help", help: "show usage", takes_value: false, default: None },
    ];
    let args = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if args.flag("help") {
        println!("{}", usage("hetcdc lp", "§V general-K achievability LP", &specs));
        return 0;
    }
    let m = match args.get_u64_list("storage") {
        Ok(m) => m,
        Err(e) => return fail(e),
    };
    let n = match args.get_u64("n") {
        Ok(n) => n,
        Err(e) => return fail(e),
    };
    let cap = args.get_usize("cap").unwrap_or(4096);
    let p = match ParamsK::new(m.clone(), n) {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    let sol = if args.flag("capped") {
        match lp_general::solve_general(&p, cap) {
            Ok(s) => s,
            Err(e) => return fail(e),
        }
    } else {
        match lp_general::solve_general_exact(&p, cap) {
            Ok(s) => s,
            Err(e) => return fail(e),
        }
    };
    let k = p.k();
    println!("K={k} storage={m:?} N={n}");
    println!(
        "LP: {} vars, {} constraints, {} pivots",
        sol.n_vars, sol.n_constraints, sol.pivots
    );
    if let Some(stats) = &sol.stats {
        println!(
            "exact: z_exact={:.6} certified={} rounds={} enumerated={} grown={}",
            stats.z_exact,
            stats.certified,
            stats.exact_rounds,
            stats.enumerated_collections,
            stats.grown_subsystems
        );
        println!(
            "work: pivots={} eta_applications={} dense_cells={} reinversions={}",
            stats.pivots, stats.eta_applications, stats.dense_cells, stats.reinversions
        );
    }
    for (j, d) in &sol.dropped {
        println!("  note: subsystem j={j} dropped {d} collections (cap {cap})");
    }
    println!("predicted load  {:.3}", sol.load);
    println!("uncoded load    {}", (k as u64 * n) - p.total());
    println!("nonzero S_T:");
    for mask in 1u32..(1 << k) {
        let v = sol.s_values[mask as usize];
        if v > 1e-9 {
            let nodes: Vec<String> = (0..k)
                .filter(|i| mask & (1 << i) != 0)
                .map(|i| (i + 1).to_string())
                .collect();
            println!("  S{{{}}} = {v:.3}", nodes.join(","));
        }
    }
    0
}

/// Surface the §V LP's Remark-7 truncation on stderr (GitHub-annotation
/// style, so CI runs turn it into a visible warning): a capped
/// enumeration means the placement may be suboptimal, and that must never
/// pass silently.
fn warn_dropped_collections(plan: &Plan) {
    for &(j, d) in &plan.dropped_collections {
        eprintln!(
            "::warning title=LP collection cap::subsystem j={j}: {d} perfect \
             collection(s) dropped by the enumeration cap — the {} placement \
             may be suboptimal for this shape (inspect with `hetcdc lp --cap N`)",
            plan.placer
        );
    }
}

/// Shared cluster/job parsing for `plan` and `run`.
fn parse_cluster_job(args: &Args) -> Result<(ClusterSpec, JobSpec), HetcdcError> {
    let n = args
        .get_u64("n")
        .map_err(|e| HetcdcError::InvalidParams(e.to_string()))?;
    let cluster = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| HetcdcError::Io(format!("config {path}: {e}")))?;
        ClusterSpec::from_json_str(&text)?
    } else {
        let m = args
            .get_u64_list("storage")
            .map_err(|e| HetcdcError::InvalidParams(e.to_string()))?;
        let mut c = ClusterSpec::homogeneous(m.len(), 1, 1000.0);
        for (node, &mk) in c.nodes.iter_mut().zip(&m) {
            node.storage = mk;
        }
        c
    };
    // --topology overrides whatever the cluster (JSON or synthesized)
    // carries; validated against K here so a bad spec fails before any
    // planning work starts.
    let cluster = match args.get("topology") {
        Some(spec) => {
            let t = Topology::parse(spec)?;
            t.validate(cluster.k())?;
            cluster.with_topology(t)
        }
        None => cluster,
    };
    // --faults mirrors --topology: it overrides the cluster's fault
    // model and is validated against K before any planning work.
    let cluster = match args.get("faults") {
        Some(spec) => {
            let f = FaultSpec::parse(spec)?;
            f.validate(cluster.k())?;
            cluster.with_faults(f)
        }
        None => cluster,
    };
    let job = match args.get("workload") {
        Some("wordcount") => JobSpec::wordcount(n),
        Some("terasort") => JobSpec::terasort(n),
        other => {
            return Err(HetcdcError::InvalidJob(format!(
                "unknown workload {other:?}"
            )))
        }
    };
    Ok((cluster, job))
}

fn cmd_plan(argv: &[String]) -> i32 {
    #[rustfmt::skip]
    let specs: Vec<ArgSpec> = vec![
        ArgSpec { name: "workload", help: "wordcount | terasort", takes_value: true, default: Some("terasort") },
        ArgSpec { name: "n", help: "number of files N", takes_value: true, default: Some("12") },
        ArgSpec { name: "storage", help: "per-node storage (ignored with --config)", takes_value: true, default: Some("6,7,7") },
        ArgSpec { name: "config", help: "cluster JSON config path", takes_value: true, default: None },
        common::PLACEMENT,
        common::CODER,
        ArgSpec { name: "mode", help: "coded | uncoded", takes_value: true, default: Some("coded") },
        ArgSpec { name: "out", help: "write plan JSON here (default: stdout)", takes_value: true, default: None },
        common::THREADS,
        common::LP_CAP,
        common::TOPOLOGY,
        common::FAULTS,
        common::HELP,
    ];
    let args = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if args.flag("help") {
        println!("{}", usage("hetcdc plan", "Build + verify an execution plan, emit JSON", &specs));
        return 0;
    }
    let (cluster, job) = match parse_cluster_job(&args) {
        Ok(x) => x,
        Err(e) => return fail(e),
    };
    let mode = match ShuffleMode::parse(args.get("mode").unwrap_or("coded")) {
        Ok(m) => m,
        Err(e) => return fail(e),
    };
    let threads = match args.get_usize("threads") {
        Ok(t) => t,
        Err(e) => return fail(e),
    };
    let mut builder = JobBuilder::new(&cluster, &job)
        .placer(args.get("placement").unwrap_or("auto"))
        .mode(mode)
        .threads(threads);
    if let Some(c) = args.get("coder") {
        builder = builder.coder(c);
    }
    if args.provided("lp-cap") {
        match args.get_usize("lp-cap") {
            Ok(cap) => builder = builder.lp_cap(cap),
            Err(e) => return fail(e),
        }
    }
    let plan = match builder.build() {
        Ok(p) => p,
        Err(e) => return fail(e),
    };
    warn_dropped_collections(&plan);
    // --threads N (N != 1): certify the plan for sharded execution by
    // diffing one serial batch against one parallel batch, bit for bit.
    if threads != 1 {
        match certify_parallel(&plan, threads) {
            Ok(()) => eprintln!(
                "plan certified for parallel execution ({threads} worker threads requested): \
                 serial and parallel batches are bit-identical"
            ),
            Err(e) => return fail(e),
        }
    }
    let text = plan.to_json_string();
    match args.get("out") {
        Some(path) => {
            if let Err(e) = std::fs::write(path, &text) {
                return fail(format!("writing {path}: {e}"));
            }
            println!(
                "plan written to {path}: placer={} coder={} mode={} predicted load {} IV-equations \
                 ({} messages, fingerprint {:016x})",
                plan.placer,
                plan.coder,
                plan.mode.as_str(),
                plan.predicted.load_equations,
                plan.predicted.messages,
                plan.fingerprint
            );
        }
        None => println!("{text}"),
    }
    0
}

/// Print one batch report; returns false when verification failed.
fn print_report(report: &RunReport, json_out: bool) -> bool {
    if json_out {
        println!("{}", report.to_json());
        return report.verified;
    }
    println!(
        "--- {:?} ({} backend, {} placement)",
        report.mode, report.backend, report.placement
    );
    println!(
        "  load {} IV-equations | payload {} B | wire {} B | {} msgs",
        report.load_equations, report.payload_bytes, report.wire_bytes, report.messages
    );
    println!(
        "  map {:.4}s  shuffle {:.4}s  ({:.0}% of job)  verified={}",
        report.map_time_s,
        report.shuffle_time_s,
        100.0 * report.shuffle_fraction(),
        report.verified
    );
    report.verified
}

/// One serial + one parallel batch of `plan` on the native backend must
/// produce bit-identical reports and network accounting.
fn certify_parallel(plan: &Plan, threads: usize) -> Result<(), HetcdcError> {
    let mut be = NativeBackend;
    let mut serial = Executor::with_config(plan, ExecConfig::default())?;
    let a = serial.run_batch(&mut be, plan.job.seed)?;
    let mut parallel = Executor::with_config(
        plan,
        ExecConfig::default().mode(ExecMode::Parallel).threads(threads),
    )?;
    let b = parallel.run_batch(&mut be, plan.job.seed)?;
    if !a.verified || !b.verified {
        return Err(HetcdcError::Backend("certification batch failed verification".into()));
    }
    if a.payload_bytes != b.payload_bytes
        || a.wire_bytes != b.wire_bytes
        || a.messages != b.messages
        || a.shuffle_time_s.to_bits() != b.shuffle_time_s.to_bits()
        || serial.net_report() != parallel.net_report()
    {
        return Err(HetcdcError::Shuffle(
            "serial and parallel execution diverged for this plan".into(),
        ));
    }
    Ok(())
}

/// Execute `batches` data batches of one plan on one executor, with
/// per-batch seeds derived from the plan's base seed. `threads` = 1 runs
/// serial; anything else runs the sharded executor (0 = auto-detect,
/// falling back to one worker when the host parallelism is unknown).
/// `pipeline` selects the batch-pipelined mode: Map of batch `i+1`
/// overlaps Shuffle of batch `i`, with bit-identical per-batch reports.
fn run_batches(
    plan: &Plan,
    backend: &mut dyn MapBackend,
    batches: u64,
    threads: usize,
    pipeline: bool,
    json_out: bool,
) -> Result<(), HetcdcError> {
    let mode = if pipeline {
        ExecMode::Pipelined
    } else if threads == 1 {
        ExecMode::Serial
    } else {
        ExecMode::Parallel
    };
    // Single construction path: cfg.faults stays None, so the executor
    // meters under the plan's own fault spec (the CLI's --faults was
    // already resolved into the cluster at plan-build time).
    let mut exec =
        Executor::with_config(plan, ExecConfig::default().mode(mode).threads(threads))?;
    if mode == ExecMode::Pipelined || exec.faults().dropout.is_some() {
        // The pipeline consumes the whole seed list (batch i+1 Maps while
        // batch i shuffles), so reports arrive together at the end. A
        // mid-run dropout clause also needs the whole list: the executor
        // splits it at the departure boundary and re-plans on the
        // survivors, which single-batch `run_batch` calls cannot see.
        let seeds: Vec<u64> = (0..batches)
            .map(|b| plan.job.seed.wrapping_add(b))
            .collect();
        for report in exec.run_batches(backend, &seeds)? {
            if !print_report(&report, json_out) {
                return Err(HetcdcError::Backend(
                    "output verification FAILED".into(),
                ));
            }
        }
        return Ok(());
    }
    // Serial/parallel: stream each report as its batch finishes and stop
    // at the first verification failure.
    for batch in 0..batches {
        let report = exec.run_batch(backend, plan.job.seed.wrapping_add(batch))?;
        if !print_report(&report, json_out) {
            return Err(HetcdcError::Backend(
                "output verification FAILED".into(),
            ));
        }
    }
    Ok(())
}

fn cmd_run(argv: &[String]) -> i32 {
    #[rustfmt::skip]
    let specs: Vec<ArgSpec> = vec![
        ArgSpec { name: "workload", help: "wordcount | terasort", takes_value: true, default: Some("terasort") },
        ArgSpec { name: "n", help: "number of files N", takes_value: true, default: Some("12") },
        ArgSpec { name: "storage", help: "per-node storage (ignored with --config)", takes_value: true, default: Some("6,7,7") },
        ArgSpec { name: "config", help: "cluster JSON config path", takes_value: true, default: None },
        ArgSpec { name: "plan", help: "execute this serialized plan (skips inline planning)", takes_value: true, default: None },
        ArgSpec { name: "batches", help: "data batches to run against the plan", takes_value: true, default: Some("1") },
        common::THREADS,
        ArgSpec { name: "pipeline", help: "overlap Map of batch i+1 with Shuffle of batch i (bit-identical results; needs --batches >= 2 to overlap)", takes_value: false, default: None },
        ArgSpec { name: "mode", help: "coded | uncoded | both", takes_value: true, default: Some("both") },
        ArgSpec { name: "backend", help: "native | xla", takes_value: true, default: Some("native") },
        common::PLACEMENT,
        common::CODER,
        common::LP_CAP,
        common::TOPOLOGY,
        common::FAULTS,
        ArgSpec { name: "artifacts", help: "artifact dir for --backend xla", takes_value: true, default: None },
        ArgSpec { name: "json", help: "emit machine-readable JSON reports", takes_value: false, default: None },
        common::HELP,
    ];
    let args = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if args.flag("help") {
        println!("{}", usage("hetcdc run", "Execute a full MapReduce job", &specs));
        return 0;
    }
    let json_out = args.flag("json");
    let batches = match args.get_u64("batches") {
        Ok(b) => b.max(1),
        Err(e) => return fail(e),
    };
    let threads = match args.get_usize("threads") {
        Ok(t) => t,
        Err(e) => return fail(e),
    };
    let pipeline = args.flag("pipeline");

    let mut rt_holder: Option<Runtime> = None;
    if args.get("backend") == Some("xla") {
        let dir = args
            .get("artifacts")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(Runtime::default_dir);
        match Runtime::load(&dir) {
            Ok(rt) => rt_holder = Some(rt),
            Err(e) => return fail(e),
        }
    }

    // --plan: consume a serialized artifact (cluster + job come from it).
    if let Some(path) = args.get("plan") {
        // The plan fixes cluster, job, placement, coder, and mode; accept
        // no conflicting flags rather than silently ignoring them.
        for conflict in [
            "workload", "n", "storage", "config", "mode", "placement", "coder", "lp-cap",
            "topology", "faults",
        ] {
            if args.provided(conflict) {
                return fail(format!(
                    "--{conflict} conflicts with --plan (the plan already fixes it); \
                     rebuild the plan with `hetcdc plan` instead"
                ));
            }
        }
        let plan = match std::fs::read_to_string(path)
            .map_err(|e| HetcdcError::Io(format!("plan {path}: {e}")))
            .and_then(|text| Plan::from_json_str(&text))
        {
            Ok(p) => p,
            Err(e) => return fail(e),
        };
        warn_dropped_collections(&plan);
        let result = match rt_holder.as_mut() {
            Some(rt) => {
                let mut be = XlaBackend::new(rt);
                run_batches(&plan, &mut be, batches, threads, pipeline, json_out)
            }
            None => {
                let mut be = NativeBackend;
                run_batches(&plan, &mut be, batches, threads, pipeline, json_out)
            }
        };
        return match result {
            Ok(()) => 0,
            Err(e) => fail(e),
        };
    }

    let (cluster, job) = match parse_cluster_job(&args) {
        Ok(x) => x,
        Err(e) => return fail(e),
    };
    let placement = args.get("placement").unwrap_or("auto");
    let modes: Vec<ShuffleMode> = match args.get("mode") {
        Some("coded") => vec![ShuffleMode::Coded],
        Some("uncoded") => vec![ShuffleMode::Uncoded],
        Some("both") => vec![ShuffleMode::Coded, ShuffleMode::Uncoded],
        other => return fail(format!("unknown mode {other:?}")),
    };

    for mode in modes {
        let mut builder = JobBuilder::new(&cluster, &job)
            .placer(placement)
            .mode(mode)
            .threads(threads);
        if let Some(c) = args.get("coder") {
            builder = builder.coder(c);
        }
        if args.provided("lp-cap") {
            match args.get_usize("lp-cap") {
                Ok(cap) => builder = builder.lp_cap(cap),
                Err(e) => return fail(e),
            }
        }
        let plan = match builder.build() {
            Ok(p) => p,
            Err(e) => return fail(e),
        };
        warn_dropped_collections(&plan);
        let result = match rt_holder.as_mut() {
            Some(rt) => {
                let mut be = XlaBackend::new(rt);
                run_batches(&plan, &mut be, batches, threads, pipeline, json_out)
            }
            None => {
                let mut be = NativeBackend;
                run_batches(&plan, &mut be, batches, threads, pipeline, json_out)
            }
        };
        if let Err(e) = result {
            return fail(e);
        }
    }
    if cluster.k() == 3 {
        if let Ok(p) = cluster.params3(job.n_files) {
            println!(
                "theory: L*={} uncoded={} saving={:.1}%",
                load::lstar(&p),
                load::uncoded(&p),
                100.0 * load::saving(&p) / load::uncoded(&p).max(1e-12)
            );
        }
    }
    0
}

/// Deterministic perf harness: run the fixed-seed shuffle/executor suite
/// (K ∈ {3,5,8} heterogeneous clusters, serial-vs-parallel certified),
/// emit `BENCH_shuffle.json`, and optionally gate against a committed
/// baseline. Exit codes: 0 = ok (or baseline pending), 1 = regression or
/// execution failure.
fn cmd_bench_json(argv: &[String]) -> i32 {
    #[rustfmt::skip]
    let specs: Vec<ArgSpec> = vec![
        ArgSpec { name: "out", help: "write the bench artifact here", takes_value: true, default: Some("BENCH_shuffle.json") },
        ArgSpec { name: "baseline", help: "committed baseline JSON to gate against", takes_value: true, default: None },
        ArgSpec { name: "tolerance-pct", help: "max allowed shuffle-byte regression, percent", takes_value: true, default: Some("5") },
        ArgSpec { name: "threads", help: "worker threads for the parallel half of each scenario (0 = auto)", takes_value: true, default: Some("0") },
        ArgSpec { name: "timing", help: "also record wall-clock timings (nondeterministic; never gated)", takes_value: false, default: None },
        ArgSpec { name: "check-armed", help: "only check that --baseline is a blessed (non-PENDING) artifact: exit 0 if armed, 3 if still the placeholder, 1 on a malformed baseline — runs no benchmarks", takes_value: false, default: None },
        common::TOPOLOGY,
        common::FAULTS,
        common::HELP,
    ];
    let args = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if args.flag("help") {
        println!(
            "{}",
            usage("hetcdc bench-json", "Deterministic shuffle bench suite + baseline gate", &specs)
        );
        return 0;
    }
    // --check-armed: answer "is the regression gate armed?" and nothing
    // else — no suite run, no artifact. CI uses it on PRs to surface a
    // still-PENDING committed baseline as a visible warning (the normal
    // bench run only mentions it in stderr scrollback).
    if args.flag("check-armed") {
        let Some(path) = args.get("baseline") else {
            return fail("--check-armed requires --baseline FILE");
        };
        let parsed = std::fs::read_to_string(path)
            .map_err(|e| HetcdcError::Io(format!("baseline {path}: {e}")))
            .and_then(|text| {
                hetcdc::util::json::Json::parse(&text).map_err(HetcdcError::from)
            });
        let baseline = match parsed {
            Ok(j) => j,
            Err(e) => return fail(e),
        };
        return match baseline.get("scenarios").map(|s| s.as_arr().map(|a| a.len())) {
            Some(Some(0)) => {
                eprintln!(
                    "baseline '{path}' is still the PENDING placeholder: the shuffle-byte \
                     regression gate is DISARMED. Bless a generated artifact \
                     (cargo run --release -- bench-json --out BENCH_shuffle.json) to arm it."
                );
                3
            }
            Some(Some(n)) => {
                println!("baseline '{path}' is armed ({n} scenarios gate this suite)");
                0
            }
            _ => fail(format!(
                "baseline '{path}' is malformed: 'scenarios' is missing or not an array"
            )),
        };
    }
    let threads = match args.get_usize("threads") {
        Ok(t) => t,
        Err(e) => return fail(e),
    };
    let tolerance = match args.get_f64("tolerance-pct") {
        Ok(t) if t >= 0.0 => t,
        Ok(t) => return fail(format!("--tolerance-pct must be >= 0, got {t}")),
        Err(e) => return fail(e),
    };
    let timing_cfg = Bench {
        measure: std::time::Duration::from_millis(300),
        ..Bench::default()
    };
    let timing = args.flag("timing").then_some(&timing_cfg);

    // --topology / --faults: exploration modes. Every scenario runs on
    // the given fabric / under the given fault spec; the resulting
    // artifact is not comparable to the committed fault-free
    // shared-medium baseline, so the gate is skipped with a warning.
    let topology_override = match args.get("topology") {
        Some(spec) => match Topology::parse(spec) {
            Ok(t) => Some(t),
            Err(e) => return fail(e),
        },
        None => None,
    };
    let faults_override = match args.get("faults") {
        Some(spec) => match FaultSpec::parse(spec) {
            Ok(f) => Some(f),
            Err(e) => return fail(e),
        },
        None => None,
    };
    let report = match bench::run_extended_suite_with(
        threads,
        timing,
        topology_override,
        faults_override.clone(),
    ) {
        Ok(r) => r,
        Err(e) => return fail(e),
    };
    let rows: Vec<Vec<String>> = report
        .results
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                format!("{}", r.k),
                r.placer.clone(),
                r.coder.clone(),
                format!("{}", r.rounds),
                format!("{}", r.messages),
                format!("{}", r.payload_bytes),
                format!("{}", r.wire_bytes),
                format!("{:.5}", r.shuffle_time_s),
                format!("{:.5}", r.makespan_s),
            ]
        })
        .collect();
    bench::table(
        &["scenario", "K", "placer", "coder", "rounds", "msgs", "payload B", "wire B", "shuffle s", "makespan s"],
        &rows,
    );
    println!(
        "totals: payload {} B, wire {} B, {} messages (all scenarios serial==parallel)",
        report.total_payload_bytes(),
        report.total_wire_bytes(),
        report.total_messages()
    );

    let artifact = report.to_json();
    let out = args.get("out").unwrap_or("BENCH_shuffle.json");
    if let Err(e) = std::fs::write(out, artifact.to_string_pretty()) {
        return fail(format!("writing {out}: {e}"));
    }
    println!("bench artifact written to {out}");

    if let Some(path) = args.get("baseline") {
        if let Some(t) = topology_override {
            eprintln!(
                "WARNING: baseline gate SKIPPED — the suite ran under --topology {} and is \
                 not comparable to the committed shared-medium baseline '{path}'",
                t.spec()
            );
            return 0;
        }
        if let Some(f) = faults_override {
            eprintln!(
                "WARNING: baseline gate SKIPPED — the suite ran under --faults {} and is \
                 not comparable to the committed fault-free baseline '{path}'",
                f.spec()
            );
            return 0;
        }
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => return fail(format!("baseline {path}: {e}")),
        };
        let baseline = match hetcdc::util::json::Json::parse(&text) {
            Ok(j) => j,
            Err(e) => return fail(format!("baseline {path}: {e}")),
        };
        let cmp = bench::compare_to_baseline(&artifact, &baseline, tolerance);
        for note in &cmp.notes {
            println!("baseline: {note}");
        }
        match cmp.status {
            BaselineStatus::Pass => {
                println!("baseline gate PASSED (tolerance {tolerance}%)");
            }
            BaselineStatus::Pending => {
                // A pending baseline means the regression gate protects
                // NOTHING — say so loudly (stdout keeps the stable
                // "PENDING" line; stderr carries the warning so it
                // survives output filtering; CI gets an annotation).
                println!(
                    "baseline gate PENDING: no blessed baseline yet — commit {out} as the \
                     baseline to arm the gate"
                );
                eprintln!(
                    "WARNING: the shuffle-byte regression gate is DISARMED (baseline '{path}' \
                     has no scenarios)."
                );
                eprintln!(
                    "WARNING: bless a generated artifact to arm it: \
                     cargo run --release -- bench-json --out BENCH_shuffle.json"
                );
                if std::env::var_os("GITHUB_ACTIONS").is_some() {
                    println!(
                        "::warning title=bench baseline pending::BENCH_shuffle.json has no \
                         blessed scenarios; the >{tolerance}% shuffle-byte regression gate is \
                         disarmed. Bless the generated artifact from this run."
                    );
                }
            }
            BaselineStatus::Regression => {
                eprintln!("error: baseline gate FAILED (tolerance {tolerance}%)");
                return 1;
            }
        }
    }
    0
}

fn cmd_sweep(argv: &[String]) -> i32 {
    #[rustfmt::skip]
    let specs: Vec<ArgSpec> = vec![
        ArgSpec { name: "n", help: "number of files N", takes_value: true, default: Some("12") },
        ArgSpec { name: "step", help: "storage grid step", takes_value: true, default: Some("2") },
        ArgSpec { name: "help", help: "show usage", takes_value: false, default: None },
    ];
    let args = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if args.flag("help") {
        println!("{}", usage("hetcdc sweep", "L* over a storage grid", &specs));
        return 0;
    }
    let n = args.get_u64("n").unwrap_or(12);
    let step = args.get_u64("step").unwrap_or(2).max(1);
    println!("| M1 | M2 | M3 | regime | L* | uncoded | saving % |");
    println!("|----|----|----|--------|-----|---------|----------|");
    let mut m1 = 1;
    while m1 <= n {
        let mut m2 = m1;
        while m2 <= n {
            let mut m3 = m2;
            while m3 <= n {
                if let Ok(p) = Params3::new(m1, m2, m3, n) {
                    println!(
                        "| {m1} | {m2} | {m3} | {} | {} | {} | {:.1} |",
                        load::classify(&p),
                        load::lstar(&p),
                        load::uncoded(&p),
                        100.0 * load::saving(&p) / load::uncoded(&p).max(1e-12)
                    );
                }
                m3 += step;
            }
            m2 += step;
        }
        m1 += step;
    }
    0
}

/// Production-style doctor: verify the deployed binary's theory, coding
/// and LP layers agree on an exhaustive grid before trusting it with a
/// cluster. (The same invariants the test suite property-checks, exposed
/// operationally.)
fn cmd_verify(argv: &[String]) -> i32 {
    #[rustfmt::skip]
    let specs: Vec<ArgSpec> = vec![
        ArgSpec { name: "n", help: "grid file count (exhaustive sweep over storage)", takes_value: true, default: Some("10") },
        ArgSpec { name: "lp", help: "also check LP == Theorem 1 (slower)", takes_value: false, default: None },
        ArgSpec { name: "help", help: "show usage", takes_value: false, default: None },
    ];
    let args = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if args.flag("help") {
        println!("{}", usage("hetcdc verify", "Self-check: theory/coding/LP consistency", &specs));
        return 0;
    }
    let n = args.get_u64("n").unwrap_or(10);
    let mut points = 0u64;
    for m1 in 1..=n {
        for m2 in m1..=n {
            for m3 in m2..=n {
                let Ok(p) = Params3::new(m1, m2, m3, n) else { continue };
                let lstar2 = load::lstar_half(&p);
                let alloc = k3::optimal_allocation(&p);
                if let Err(e) = alloc.validate(&[m1, m2, m3], n) {
                    return fail(format!("{p}: invalid placement: {e}"));
                }
                let plan = hetcdc::coding::plan::plan_k3(&alloc);
                if plan.load_units() as u64 != lstar2 {
                    return fail(format!(
                        "{p}: plan load {} != L*half {lstar2}",
                        plan.load_units()
                    ));
                }
                if converse::bounds_half(&p).max_half() != lstar2 {
                    return fail(format!("{p}: converse != L*"));
                }
                // The decode schedule doubles as the decodability proof.
                if let Err(e) = hetcdc::coding::decoder::schedule(&alloc, &plan) {
                    return fail(format!("{p}: {e}"));
                }
                if args.flag("lp") {
                    let pk = match ParamsK::new(vec![m1, m2, m3], n) {
                        Ok(pk) => pk,
                        Err(e) => return fail(format!("{p}: {e}")),
                    };
                    match lp_general::solve_general(&pk, 4096) {
                        Ok(sol) if (sol.load - load::lstar(&p)).abs() < 1e-6 => {}
                        Ok(sol) => {
                            return fail(format!("{p}: LP {} != L* {}", sol.load, load::lstar(&p)))
                        }
                        Err(e) => return fail(format!("{p}: LP failed: {e}")),
                    }
                }
                points += 1;
            }
        }
    }
    println!(
        "verify OK: {points} parameter points (N={n}); L* == achievability == converse, all plans decode{}",
        if args.flag("lp") { ", LP == Theorem 1" } else { "" }
    );
    0
}

fn cmd_info(argv: &[String]) -> i32 {
    #[rustfmt::skip]
    let specs: Vec<ArgSpec> = vec![
        ArgSpec { name: "artifacts", help: "artifact directory", takes_value: true, default: None },
        ArgSpec { name: "help", help: "show usage", takes_value: false, default: None },
    ];
    let args = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    if args.flag("help") {
        println!("{}", usage("hetcdc info", "Artifact manifest summary", &specs));
        return 0;
    }
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Runtime::default_dir);
    match Runtime::load(&dir) {
        Ok(rt) => {
            let m = &rt.manifest;
            println!("artifacts at {}", dir.display());
            println!(
                "config: vocab={} q={} t={} map_batch={} keys_per_file={}",
                m.vocab, m.q, m.t, m.map_batch, m.keys_per_file
            );
            let mut names: Vec<&String> = m.artifacts.keys().collect();
            names.sort();
            for name in names {
                let (file, shapes) = &m.artifacts[name];
                println!("  {name}: {file} inputs={shapes:?}");
            }
            0
        }
        Err(e) => fail(e),
    }
}
