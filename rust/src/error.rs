//! Typed errors for the public API.
//!
//! Hand-rolled in the `thiserror` style (the offline build carries no
//! proc-macro dependencies): one enum, `Display` messages that read like
//! the old string errors, `std::error::Error`, and `From` impls for the
//! substrate error types so `?` composes across layers.
//!
//! Every public fallible API in this crate returns [`HetcdcError`]; the
//! [`Result`] alias defaults its error parameter accordingly.

use std::fmt;

/// Everything that can go wrong between a cluster description and a
/// verified [`crate::engine::RunReport`].
#[derive(Clone, Debug, PartialEq)]
pub enum HetcdcError {
    /// Cluster parameters violate the §II model (storage cannot cover the
    /// file set, K out of range, zero-node cluster, ...).
    InvalidParams(String),
    /// Job specification is inconsistent (no files, zero-length IVs, a
    /// workload knob left unset).
    InvalidJob(String),
    /// An allocation violates coverage or capacity constraints.
    InvalidPlacement(String),
    /// A placer or coder cannot serve this cluster/job shape (e.g. the
    /// homogeneous placer on unequal storage).
    Unsupported {
        strategy: &'static str,
        reason: String,
    },
    /// No placer/coder is registered under this name.
    UnknownStrategy {
        kind: &'static str,
        name: String,
    },
    /// The §V linear program failed (infeasible/unbounded).
    Lp(crate::lp::LpError),
    /// A shuffle plan failed symbolic decode verification: some node ends
    /// the Shuffle phase still missing intermediate values.
    Undecodable {
        node: usize,
        missing: usize,
    },
    /// A compute backend (native or PJRT) failed.
    Backend(String),
    /// Byte-level shuffle execution failed (a sender was scheduled to
    /// transmit data it does not hold, ...).
    Shuffle(String),
    /// JSON parse or schema error (configs, plan artifacts, manifests).
    Json(String),
    /// A serialized plan artifact is internally inconsistent or does not
    /// match the cluster/job it is being executed against.
    PlanMismatch(String),
    /// Filesystem I/O (config files, plan files, artifacts).
    Io(String),
    /// The PJRT runtime is unavailable (built without the `xla` feature,
    /// or artifacts missing).
    RuntimeUnavailable(String),
}

/// Crate-wide result alias; the error parameter defaults to
/// [`HetcdcError`] but stays overridable.
pub type Result<T, E = HetcdcError> = std::result::Result<T, E>;

impl HetcdcError {
    /// Wrap any displayable failure as a backend error.
    pub fn backend(e: impl fmt::Display) -> Self {
        HetcdcError::Backend(e.to_string())
    }

    /// Wrap any displayable failure as an I/O error.
    pub fn io(e: impl fmt::Display) -> Self {
        HetcdcError::Io(e.to_string())
    }
}

impl fmt::Display for HetcdcError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HetcdcError::InvalidParams(m) => write!(f, "invalid parameters: {m}"),
            HetcdcError::InvalidJob(m) => write!(f, "invalid job: {m}"),
            HetcdcError::InvalidPlacement(m) => write!(f, "invalid placement: {m}"),
            HetcdcError::Unsupported { strategy, reason } => {
                write!(f, "{strategy}: unsupported here: {reason}")
            }
            HetcdcError::UnknownStrategy { kind, name } => {
                write!(f, "unknown {kind} '{name}'")
            }
            HetcdcError::Lp(e) => write!(f, "LP: {e}"),
            HetcdcError::Undecodable { node, missing } => write!(
                f,
                "plan not decodable: node {node} misses {missing} intermediate value(s)"
            ),
            HetcdcError::Backend(m) => write!(f, "backend: {m}"),
            HetcdcError::Shuffle(m) => write!(f, "shuffle execution: {m}"),
            HetcdcError::Json(m) => write!(f, "json: {m}"),
            HetcdcError::PlanMismatch(m) => write!(f, "plan mismatch: {m}"),
            HetcdcError::Io(m) => write!(f, "io: {m}"),
            HetcdcError::RuntimeUnavailable(m) => write!(f, "runtime unavailable: {m}"),
        }
    }
}

impl std::error::Error for HetcdcError {}

impl From<crate::lp::LpError> for HetcdcError {
    fn from(e: crate::lp::LpError) -> Self {
        HetcdcError::Lp(e)
    }
}

impl From<crate::util::json::JsonError> for HetcdcError {
    fn from(e: crate::util::json::JsonError) -> Self {
        HetcdcError::Json(e.to_string())
    }
}

impl From<std::io::Error> for HetcdcError {
    fn from(e: std::io::Error) -> Self {
        HetcdcError::Io(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = HetcdcError::Undecodable { node: 2, missing: 3 };
        let s = e.to_string();
        assert!(s.contains("node 2") && s.contains("3"));
        assert!(HetcdcError::UnknownStrategy { kind: "placer", name: "nope".into() }
            .to_string()
            .contains("nope"));
    }

    #[test]
    fn from_lp_error() {
        let e: HetcdcError = crate::lp::LpError::Infeasible.into();
        assert_eq!(e, HetcdcError::Lp(crate::lp::LpError::Infeasible));
    }
}
