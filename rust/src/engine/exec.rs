//! Byte-level shuffle execution: senders assemble XOR payloads, receivers
//! decode them, all traffic metered by the network simulator.
//!
//! This mirrors [`crate::coding::decoder`] but with real bytes: the
//! symbolic decoder proves plans are decodable; this module proves the
//! *implementation* delivers bit-correct IVs (the engine verifies Reduce
//! outputs against the oracle afterwards).
//!
//! Two execution paths:
//! * [`execute_planned`] — the production path: replays the
//!   [`DecodeSchedule`] baked into a [`crate::engine::Plan`], so no
//!   fixpoint iteration or deferred-message queue is needed.
//! * [`execute_shuffle`] — the schedule-free fallback (fixpoint over
//!   deferred messages), kept for ad-hoc plans and benches.
//!
//! [`NodeState`] buffers are epoch-versioned so an
//! [`crate::engine::Executor`] reuses every allocation across batches:
//! `reset()` is O(1) and the payload buffers keep their capacity. The
//! executor holds **two** such banks per node (front/back) so the
//! pipelined mode can keep two batch epochs in flight — the back bank is
//! reset and re-filled by the Map of batch `i+1` while the front bank
//! drains batch `i`'s shuffle; an O(1) bank swap promotes it afterwards.

use crate::coding::decoder::{runtime_recovery, DecodeSchedule};
use crate::coding::plan::{Broadcast, IvId, Part, ShufflePlan};
use crate::coding::xor::xor_into;
use crate::error::{HetcdcError, Result};
use crate::net::BroadcastNet;
use crate::placement::alloc::Allocation;
use std::collections::HashMap;

/// Fixed per-message wire overhead (sender id, kind, part descriptors) —
/// counted in wire bytes so the time model is honest, excluded from the
/// paper's load metric (which counts IV bits only).
pub const HEADER_BYTES: usize = 16;
pub const PER_PART_BYTES: usize = 12;

/// Byte range of segment `seg` of `nseg` over a payload of `len` bytes
/// (equal ceil-sized strides; the tail segment may be short).
pub fn seg_range(len: usize, seg: u32, nseg: u32) -> (usize, usize) {
    let stride = len.div_ceil(nseg as usize);
    let start = (seg as usize * stride).min(len);
    let end = (start + stride).min(len);
    (start, end)
}

/// Wire length of a segment message (zero-padded to the stride).
pub fn seg_wire_len(len: usize, nseg: u32) -> usize {
    len.div_ceil(nseg as usize)
}

/// (payload, wire) byte sizes of one broadcast for IVs of `iv_bytes` —
/// the single source of the wire-framing arithmetic, shared by the
/// byte-level executor and [`crate::engine::PredictedLoads`] so predicted
/// and measured accounting cannot drift.
pub fn broadcast_sizes(b: &Broadcast, iv_bytes: usize) -> (usize, usize) {
    match b {
        Broadcast::Uncoded { .. } => (iv_bytes, iv_bytes + HEADER_BYTES + PER_PART_BYTES),
        Broadcast::Coded { parts, .. } => {
            let stride = seg_wire_len(iv_bytes, parts.first().map(|p| p.nseg).unwrap_or(1));
            (stride, stride + HEADER_BYTES + PER_PART_BYTES * parts.len())
        }
    }
}

/// Per-node IV knowledge with real bytes.
///
/// Payload buffers are epoch-versioned: [`NodeState::reset`] invalidates
/// every slot in O(1) without freeing, so repeated batches through one
/// [`crate::engine::Executor`] reuse all allocations.
pub struct NodeState {
    q: usize,
    n_sub: usize,
    iv_bytes: usize,
    /// Payload buffer per IV: index `group * n_sub + sub`. A buffer holds
    /// valid bytes only when its epoch matches `cur`.
    bufs: Vec<Vec<u8>>,
    epoch: Vec<u32>,
    cur: u32,
    /// Partially assembled IVs: iv -> (nseg, per-seg bytes).
    partial: HashMap<IvId, (u32, Vec<Option<Vec<u8>>>)>,
}

impl NodeState {
    pub fn new(q: usize, n_sub: usize, iv_bytes: usize) -> Self {
        Self {
            q,
            n_sub,
            iv_bytes,
            bufs: vec![Vec::new(); q * n_sub],
            epoch: vec![0; q * n_sub],
            cur: 1,
            partial: HashMap::new(),
        }
    }

    /// Start a new batch: forget all IV knowledge, keep all buffers.
    pub fn reset(&mut self) {
        self.partial.clear();
        if self.cur == u32::MAX {
            self.epoch.fill(0);
            self.cur = 1;
        } else {
            self.cur += 1;
        }
    }

    fn idx(&self, iv: IvId) -> usize {
        debug_assert!(iv.group < self.q && iv.sub < self.n_sub);
        iv.group * self.n_sub + iv.sub
    }

    /// Store a full IV payload, reusing the slot's buffer capacity.
    pub fn set_full(&mut self, iv: IvId, payload: Vec<u8>) {
        debug_assert_eq!(payload.len(), self.iv_bytes);
        let i = self.idx(iv);
        self.bufs[i] = payload;
        self.epoch[i] = self.cur;
    }

    /// Like [`Self::set_full`] but copies into the existing buffer.
    pub fn set_full_from(&mut self, iv: IvId, bytes: &[u8]) {
        debug_assert_eq!(bytes.len(), self.iv_bytes);
        let i = self.idx(iv);
        self.bufs[i].clear();
        self.bufs[i].extend_from_slice(bytes);
        self.epoch[i] = self.cur;
    }

    pub fn get_full(&self, iv: IvId) -> Option<&[u8]> {
        let i = self.idx(iv);
        if self.epoch[i] == self.cur {
            Some(&self.bufs[i])
        } else {
            None
        }
    }

    pub fn knows_part(&self, p: &Part) -> bool {
        if self.get_full(p.iv).is_some() {
            return true;
        }
        self.partial
            .get(&p.iv)
            .map(|(nseg, segs)| *nseg == p.nseg && segs[p.seg as usize].is_some())
            .unwrap_or(false)
    }

    /// Bytes of a part, zero-padded to the segment stride.
    pub fn part_bytes(&self, p: &Part) -> Option<Vec<u8>> {
        let stride = seg_wire_len(self.iv_bytes, p.nseg);
        if let Some(full) = self.get_full(p.iv) {
            let (s, e) = seg_range(self.iv_bytes, p.seg, p.nseg);
            let mut out = full[s..e].to_vec();
            out.resize(stride, 0);
            return Some(out);
        }
        self.partial.get(&p.iv).and_then(|(nseg, segs)| {
            if *nseg == p.nseg {
                segs[p.seg as usize].clone()
            } else {
                None
            }
        })
    }

    /// Record a decoded part; assemble the full IV when complete.
    pub fn learn_part(&mut self, p: &Part, bytes: &[u8]) {
        if self.get_full(p.iv).is_some() {
            return;
        }
        if p.nseg == 1 {
            let i = self.idx(p.iv);
            let take = bytes.len().min(self.iv_bytes);
            self.bufs[i].clear();
            self.bufs[i].extend_from_slice(&bytes[..take]);
            self.bufs[i].resize(self.iv_bytes, 0);
            self.epoch[i] = self.cur;
            return;
        }
        let entry = self
            .partial
            .entry(p.iv)
            .or_insert_with(|| (p.nseg, vec![None; p.nseg as usize]));
        if entry.0 != p.nseg {
            return; // mixed granularity not used by any built-in plan
        }
        entry.1[p.seg as usize] = Some(bytes.to_vec());
        if entry.1.iter().any(|s| s.is_none()) {
            return;
        }
        let Some((nseg, segs)) = self.partial.remove(&p.iv) else {
            return;
        };
        let mut payload = Vec::with_capacity(self.iv_bytes);
        for (i, seg_bytes) in segs.into_iter().enumerate() {
            let Some(seg_bytes) = seg_bytes else { continue };
            let (s, e) = seg_range(self.iv_bytes, i as u32, nseg);
            payload.extend_from_slice(&seg_bytes[..e - s]);
        }
        self.set_full(p.iv, payload);
    }

    /// Try to decode a coded message; true on progress.
    pub fn try_decode(&mut self, parts: &[Part], msg: &[u8]) -> bool {
        let unknown: Vec<usize> = (0..parts.len())
            .filter(|&i| !self.knows_part(&parts[i]))
            .collect();
        if unknown.len() != 1 {
            return unknown.is_empty(); // fully known: no new info, but "done"
        }
        let target = unknown[0];
        let mut recovered = msg.to_vec();
        for (i, p) in parts.iter().enumerate() {
            if i != target {
                // knows_part passed above, so part_bytes is Some; a miss
                // would mean inconsistent state — report no progress
                // rather than panic.
                let Some(known) = self.part_bytes(p) else {
                    return false;
                };
                xor_into(&mut recovered, &known);
            }
        }
        self.learn_part(&parts[target], &recovered);
        true
    }
}

/// Shuffle execution result.
#[derive(Clone, Debug)]
pub struct ShuffleOutcome {
    /// IV payload bytes broadcast (the paper's load metric, in bytes).
    pub payload_bytes: u64,
    /// Payload + headers (what the network actually carries).
    pub wire_bytes: u64,
    pub messages: u64,
}

/// Assemble the wire message of one broadcast from the sender's current
/// state. `None` when the sender does not (yet) know a transmitted part.
fn assemble_message(b: &Broadcast, states: &[NodeState]) -> Option<Vec<u8>> {
    let sender = b.sender();
    let (payload_len, _) = broadcast_sizes(b, states[sender].iv_bytes);
    let msg = match b {
        Broadcast::Uncoded { sender, iv } => states[*sender].get_full(*iv)?.to_vec(),
        Broadcast::Coded { sender, parts } => {
            let mut msg = vec![0u8; payload_len];
            for p in parts {
                xor_into(&mut msg, &states[*sender].part_bytes(p)?);
            }
            msg
        }
    };
    debug_assert_eq!(msg.len(), payload_len);
    Some(msg)
}

/// Assemble the wire message of one broadcast from the sender's state,
/// metering it on the network. Returns the message bytes.
fn assemble_and_meter(
    b: &Broadcast,
    states: &[NodeState],
    net: &mut BroadcastNet,
    payload_bytes: &mut u64,
    wire_bytes: &mut u64,
) -> Result<Vec<u8>> {
    let sender = b.sender();
    let (payload_len, wire) = broadcast_sizes(b, states[sender].iv_bytes);
    let msg = assemble_message(b, states).ok_or_else(|| {
        HetcdcError::Shuffle(format!("sender {sender} lacks a part of {b:?}"))
    })?;
    *payload_bytes += payload_len as u64;
    *wire_bytes += wire as u64;
    net.broadcast(sender, wire);
    Ok(msg)
}

/// Bounds-check a [`DecodeSchedule`] against `plan` and return the
/// per-broadcast scheduled-consumer counts (flat broadcast indices).
fn schedule_consumers(
    plan: &ShufflePlan,
    schedule: &DecodeSchedule,
    k: usize,
) -> Result<Vec<u32>> {
    if schedule.order.len() != k {
        return Err(HetcdcError::Shuffle(format!(
            "schedule covers {} nodes, cluster has {}",
            schedule.order.len(),
            k
        )));
    }
    let n_broadcasts = plan.n_broadcasts();
    let mut remaining = vec![0u32; n_broadcasts];
    for order in &schedule.order {
        for &bi in order {
            if bi >= n_broadcasts {
                return Err(HetcdcError::Shuffle(format!(
                    "schedule references broadcast {bi} out of range"
                )));
            }
            remaining[bi] += 1;
        }
    }
    Ok(remaining)
}

/// Replay one node's decode schedule over the transmitted messages.
/// Identical to the per-node work of [`execute_planned`]: decoding only
/// reads the node's own state and the message bytes, so replaying the
/// per-node order in isolation produces the same final state as the
/// interleaved serial replay.
fn replay_node_schedule(
    node: usize,
    st: &mut NodeState,
    order: &[usize],
    broadcasts: &[&Broadcast],
    msgs: &[Option<Vec<u8>>],
) -> Result<()> {
    for &bi in order {
        let msg = msgs[bi].as_deref().ok_or_else(|| {
            HetcdcError::Shuffle(format!(
                "internal: message {bi} unavailable for node {node}"
            ))
        })?;
        match broadcasts[bi] {
            Broadcast::Uncoded { sender, iv } => {
                if node != *sender {
                    st.learn_part(&Part::whole(*iv), msg);
                }
            }
            Broadcast::Coded { sender, parts } => {
                if node != *sender && !st.try_decode(parts, msg) {
                    return Err(HetcdcError::Shuffle(format!(
                        "decode schedule violated: node {node} cannot decode \
                         broadcast {bi}"
                    )));
                }
            }
        }
    }
    Ok(())
}

/// Execute `plan` along a pre-verified [`DecodeSchedule`]: broadcasts are
/// transmitted (metered) in flattened plan order — round by round, each
/// round opening its own [`crate::net::PhaseLedger`] section — and each
/// node's decode order is replayed as its next scheduled message becomes
/// available — no fixpoint, no deferred-message queue. A message buffer
/// is dropped as soon as its last scheduled consumer has decoded it, so
/// peak memory is bounded by the messages still awaiting a consumer, not
/// the whole shuffle payload. The schedule was proven at plan-build time;
/// a violation here is an internal error.
pub fn execute_planned(
    plan: &ShufflePlan,
    schedule: &DecodeSchedule,
    states: &mut [NodeState],
    net: &mut BroadcastNet,
) -> Result<ShuffleOutcome> {
    // Consumers per broadcast, from the schedule (bounds-checked here).
    schedule_consumers(plan, schedule, states.len())?;
    let flat: Vec<&Broadcast> = plan.iter_broadcasts().collect();
    execute_serial_orders(plan, &flat, &schedule.order, states, net, &[])
}

/// The serial transmit-and-decode cursor loop shared by
/// [`execute_planned`] (baked schedule, nothing erased) and the runtime
/// erasure path (worklist orders over survivors). Broadcasts are metered
/// in flat plan order; an index flagged in `erased` is transmitted and
/// metered exactly like a survivor — the sender cannot know the medium
/// lost it — but its message is delivered to nobody and
/// [`BroadcastNet::note_erased`] records the loss. `orders` must never
/// reference an erased index (the worklist pass guarantees this).
fn execute_serial_orders(
    plan: &ShufflePlan,
    flat: &[&Broadcast],
    orders: &[Vec<usize>],
    states: &mut [NodeState],
    net: &mut BroadcastNet,
    erased: &[bool],
) -> Result<ShuffleOutcome> {
    let k = states.len();
    let starts_round = plan.round_start_flags();
    let group_starts = plan.group_start_masks();
    let n_broadcasts = flat.len();
    let mut remaining = vec![0u32; n_broadcasts];
    for order in orders {
        for &bi in order {
            if bi >= n_broadcasts {
                return Err(HetcdcError::Shuffle(format!(
                    "decode order references broadcast {bi} out of range"
                )));
            }
            remaining[bi] += 1;
        }
    }

    let mut payload_bytes = 0u64;
    let mut wire_bytes = 0u64;
    let mut msgs: Vec<Option<Vec<u8>>> = vec![None; n_broadcasts];
    let mut cursors = vec![0usize; k];
    for (bi, &b) in flat.iter().enumerate() {
        if starts_round[bi] {
            net.begin_round();
        }
        if let Some(members) = group_starts[bi] {
            net.begin_group(members);
        }
        let msg = assemble_and_meter(b, states, net, &mut payload_bytes, &mut wire_bytes)?;
        if erased.get(bi).copied().unwrap_or(false) {
            net.note_erased();
            continue;
        }
        if remaining[bi] > 0 {
            msgs[bi] = Some(msg);
        }
        // Advance every node whose next scheduled message has now been
        // transmitted. A node's order may point backwards (an earlier
        // index decodable only after a later one): entries wait until
        // their own index is reached, then drain in dependency order.
        for node in 0..k {
            while let Some(&next) = orders[node].get(cursors[node]) {
                if next > bi {
                    break;
                }
                let msg = msgs[next].as_deref().ok_or_else(|| {
                    HetcdcError::Shuffle(format!(
                        "internal: message {next} dropped before node {node} consumed it"
                    ))
                })?;
                match flat[next] {
                    Broadcast::Uncoded { sender, iv } => {
                        if node != *sender {
                            states[node].learn_part(&Part::whole(*iv), msg);
                        }
                    }
                    Broadcast::Coded { sender, parts } => {
                        if node != *sender && !states[node].try_decode(parts, msg) {
                            return Err(HetcdcError::Shuffle(format!(
                                "decode schedule violated: node {node} cannot decode \
                                 broadcast {next}"
                            )));
                        }
                    }
                }
                cursors[node] += 1;
                remaining[next] -= 1;
                if remaining[next] == 0 {
                    msgs[next] = None;
                }
            }
        }
    }

    Ok(ShuffleOutcome {
        payload_bytes,
        wire_bytes,
        messages: n_broadcasts as u64,
    })
}

/// Shard-parallel variant of [`execute_planned`]: per-node decode runs on
/// [`std::thread::scope`] workers while metering stays a single
/// plan-order pass, so the outcome is **bit-identical** to the serial
/// path — same decoded IV bytes, same [`crate::net::NetReport`] (the
/// clock is the same sequential float fold; see [`crate::net::sim`]).
///
/// Three phases:
/// 1. **Assemble** (parallel): every broadcast's wire message is built
///    from the sender's post-Map state. Built-in coders only ever
///    transmit IV parts the sender computed in its own Map phase, so
///    this matches the serial interleaved assembly. A plan whose sender
///    needs mid-shuffle knowledge (possible for hand-written plans)
///    makes this function fall back to the serial path — correctness
///    over speed.
/// 2. **Meter** (serial, plan order): the exact [`BroadcastNet`] calls
///    of the serial path, in the same order.
/// 3. **Decode** (parallel): each node replays its own schedule order;
///    decoding touches only that node's state plus the shared read-only
///    message buffers.
///
/// Peak memory holds all messages at once (the serial path drops each
/// after its last scheduled consumer) — the price of decode parallelism.
pub fn execute_planned_parallel(
    plan: &ShufflePlan,
    schedule: &DecodeSchedule,
    states: &mut [NodeState],
    net: &mut BroadcastNet,
    threads: usize,
) -> Result<ShuffleOutcome> {
    let k = states.len();
    schedule_consumers(plan, schedule, k)?;
    let flat: Vec<&Broadcast> = plan.iter_broadcasts().collect();
    let n_broadcasts = flat.len();
    let threads = threads.clamp(1, k.max(1));
    if n_broadcasts == 0 {
        return Ok(ShuffleOutcome { payload_bytes: 0, wire_bytes: 0, messages: 0 });
    }
    if threads <= 1 {
        // One worker = no parallelism: the serial path is strictly better
        // (it also bounds peak memory by dropping consumed messages).
        return execute_planned(plan, schedule, states, net);
    }

    // ---- Phase 1: assemble all messages from post-Map sender state.
    let Some(msgs) = assemble_all_parallel(&flat, states, threads)? else {
        // A sender transmits something it only learns mid-shuffle: replay
        // serially (states and net are still untouched).
        return execute_planned(plan, schedule, states, net);
    };

    // ---- Phase 2: meter in flattened plan order (identical to the
    // serial path, including the per-sender iv_bytes lookup and the
    // per-round ledger sections).
    let (payload_bytes, wire_bytes) = meter_plan_order(plan, &flat, states, net, &[]);

    // ---- Phase 3: per-node decode replay, sharded across workers.
    replay_all_parallel(&schedule.order, &flat, &msgs, states, threads)?;

    Ok(ShuffleOutcome {
        payload_bytes,
        wire_bytes,
        messages: n_broadcasts as u64,
    })
}

/// Phase-1 helper of the parallel paths: assemble every broadcast's wire
/// message from post-Map sender state on scoped workers. `Ok(None)` =
/// some sender needs mid-shuffle knowledge, so the caller must fall back
/// to the serial interleaved path (states and net are untouched).
fn assemble_all_parallel(
    flat: &[&Broadcast],
    states: &[NodeState],
    threads: usize,
) -> Result<Option<Vec<Option<Vec<u8>>>>> {
    let n_broadcasts = flat.len();
    let mut msgs: Vec<Option<Vec<u8>>> = vec![None; n_broadcasts];
    let chunk = n_broadcasts.div_ceil(threads).max(1);
    let assembled_all = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (ci, out) in msgs.chunks_mut(chunk).enumerate() {
            let base = ci * chunk;
            handles.push(scope.spawn(move || {
                for (off, slot) in out.iter_mut().enumerate() {
                    match assemble_message(flat[base + off], states) {
                        Some(m) => *slot = Some(m),
                        None => return false,
                    }
                }
                true
            }));
        }
        // Join every worker before deciding: returning early would
        // make thread::scope re-panic on a second panicked worker.
        let joined: Vec<_> = handles.into_iter().map(|h| h.join()).collect();
        let mut all = true;
        for j in joined {
            match j {
                Ok(ok) => all = all && ok,
                Err(_) => return Err(HetcdcError::Shuffle("assembly worker panicked".into())),
            }
        }
        Ok(all)
    })?;
    Ok(if assembled_all { Some(msgs) } else { None })
}

/// Phase-2 helper of the parallel paths: the exact [`BroadcastNet`] call
/// sequence of the serial path, in flat plan order. Erased indices are
/// metered like survivors (the wire carried them) and then recorded via
/// [`BroadcastNet::note_erased`]. Returns `(payload_bytes, wire_bytes)`.
fn meter_plan_order(
    plan: &ShufflePlan,
    flat: &[&Broadcast],
    states: &[NodeState],
    net: &mut BroadcastNet,
    erased: &[bool],
) -> (u64, u64) {
    let mut payload_bytes = 0u64;
    let mut wire_bytes = 0u64;
    let starts_round = plan.round_start_flags();
    let group_starts = plan.group_start_masks();
    for (bi, &b) in flat.iter().enumerate() {
        if starts_round[bi] {
            net.begin_round();
        }
        if let Some(members) = group_starts[bi] {
            net.begin_group(members);
        }
        let (payload, wire) = broadcast_sizes(b, states[b.sender()].iv_bytes);
        payload_bytes += payload as u64;
        wire_bytes += wire as u64;
        net.broadcast(b.sender(), wire);
        if erased.get(bi).copied().unwrap_or(false) {
            net.note_erased();
        }
    }
    (payload_bytes, wire_bytes)
}

/// Phase-3 helper of the parallel paths: every node replays its own
/// decode order on scoped workers; decoding touches only that node's
/// state plus the shared read-only message buffers.
fn replay_all_parallel(
    orders: &[Vec<usize>],
    flat: &[&Broadcast],
    msgs: &[Option<Vec<u8>>],
    states: &mut [NodeState],
    threads: usize,
) -> Result<()> {
    let k = states.len();
    let chunk = k.div_ceil(threads).max(1);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (ci, st_chunk) in states.chunks_mut(chunk).enumerate() {
            let base = ci * chunk;
            handles.push(scope.spawn(move || -> Result<()> {
                for (off, st) in st_chunk.iter_mut().enumerate() {
                    let node = base + off;
                    replay_node_schedule(node, st, &orders[node], flat, msgs)?;
                }
                Ok(())
            }));
        }
        // Join all workers first (see assemble_all_parallel), then
        // propagate the first failure.
        let joined: Vec<_> = handles.into_iter().map(|h| h.join()).collect();
        for j in joined {
            j.map_err(|_| HetcdcError::Shuffle("decode worker panicked".into()))??;
        }
        Ok::<(), HetcdcError>(())
    })
}

/// Execute `plan` under a runtime erasure pattern: every broadcast is
/// assembled and metered in flat plan order exactly as fault-free — the
/// sender cannot know the medium lost its transmission, so plan-round
/// bytes, messages, and clocks match the fault-free run — but an erased
/// broadcast reaches no receiver. Decoding replays the runtime worklist
/// orders over the survivors ([`runtime_recovery`] reuses the symbolic
/// decoder's `DecodeIndex`), and any IV the survivors cannot complete
/// (losses exceeded the plan's repair tolerance) is restored by
/// deterministic NACK-driven unicast retransmission, metered on top.
///
/// `threads > 1` uses the same three-phase parallel split as
/// [`execute_planned_parallel`]; the outcome is bit-identical to the
/// serial path for every thread count. The returned [`ShuffleOutcome`]
/// counts **plan** traffic only (identical to fault-free); recovery
/// traffic is metered in the ledger's recovery counters
/// ([`crate::net::NetReport::recovery_bytes`] et al.).
pub fn execute_planned_erased(
    plan: &ShufflePlan,
    alloc: &Allocation,
    states: &mut [NodeState],
    net: &mut BroadcastNet,
    erased: &[bool],
    threads: usize,
) -> Result<ShuffleOutcome> {
    let k = states.len();
    let rec = runtime_recovery(alloc, plan, erased);
    if rec.orders.len() != k {
        return Err(HetcdcError::Shuffle(format!(
            "recovery orders cover {} nodes, cluster has {k}",
            rec.orders.len()
        )));
    }
    let flat: Vec<&Broadcast> = plan.iter_broadcasts().collect();
    let n_broadcasts = flat.len();
    let threads = threads.clamp(1, k.max(1));

    let outcome = if threads <= 1 || n_broadcasts == 0 {
        execute_serial_orders(plan, &flat, &rec.orders, states, net, erased)?
    } else {
        match assemble_all_parallel(&flat, states, threads)? {
            None => {
                // A sender needs mid-shuffle knowledge: serial fallback
                // (states and net are still untouched).
                execute_serial_orders(plan, &flat, &rec.orders, states, net, erased)?
            }
            Some(msgs) => {
                let (payload_bytes, wire_bytes) =
                    meter_plan_order(plan, &flat, states, net, erased);
                replay_all_parallel(&rec.orders, &flat, &msgs, states, threads)?;
                ShuffleOutcome {
                    payload_bytes,
                    wire_bytes,
                    messages: n_broadcasts as u64,
                }
            }
        }
    };

    retransmit_stranded(alloc, states, net, &rec.stranded)?;
    Ok(outcome)
}

/// Restore stranded IVs by deterministic NACK-driven unicast
/// retransmissions. For each stranded `(dest, iv)` — ordered node
/// ascending, then `(group, sub)` — the lowest-indexed surviving holder
/// of `iv.sub` resends exactly the segments `dest` is missing (the whole
/// IV when it has no partial assembly) as **reliable** point-to-point
/// messages: the erasure model applies only to plan broadcasts, so
/// recovery terminates even at `p = 1`. Each retransmission round pays
/// an exponentially backed-off penalty before its resends and each
/// resend a NACK round trip ([`BroadcastNet::retransmit_unicast`]); one
/// round always suffices for the built-in plans — a holder of the
/// subfile knows every group's IV from its own Map — so the outer loop
/// is defensive structure, bounded rather than unbounded.
fn retransmit_stranded(
    alloc: &Allocation,
    states: &mut [NodeState],
    net: &mut BroadcastNet,
    stranded: &[(usize, IvId)],
) -> Result<()> {
    if stranded.is_empty() {
        return Ok(());
    }
    let k = states.len();
    let mut pending: Vec<(usize, IvId)> = stranded.to_vec();
    let mut round = 0usize;
    while !pending.is_empty() {
        round += 1;
        if round > k.max(8) {
            return Err(HetcdcError::Shuffle(
                "retransmission did not converge".into(),
            ));
        }
        net.begin_retransmit_round(round);
        for (dest, iv) in std::mem::take(&mut pending) {
            let holders = alloc.holders[iv.sub];
            let holder = (0..k).find(|&n| n != dest && holders & (1 << n) != 0);
            let Some(holder) = holder else {
                return Err(HetcdcError::Shuffle(format!(
                    "no surviving holder can retransmit {iv:?} to node {dest}"
                )));
            };
            let iv_bytes = states[holder].iv_bytes;
            let full = states[holder]
                .get_full(iv)
                .map(<[u8]>::to_vec)
                .ok_or_else(|| {
                    HetcdcError::Shuffle(format!(
                        "holder {holder} lacks {iv:?} needed for retransmission"
                    ))
                })?;
            // Resend at the dest's partial granularity when it has one —
            // only the missing segments ride the wire.
            let missing: Vec<(u32, u32)> = match states[dest].partial.get(&iv) {
                Some((nseg, segs)) => segs
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.is_none())
                    .map(|(i, _)| (i as u32, *nseg))
                    .collect(),
                None => vec![(0, 1)],
            };
            let wire = missing
                .iter()
                .map(|&(_, nseg)| seg_wire_len(iv_bytes, nseg))
                .sum::<usize>()
                + HEADER_BYTES
                + PER_PART_BYTES * missing.len();
            net.retransmit_unicast(holder, wire);
            for (seg, nseg) in missing {
                let (s, e) = seg_range(iv_bytes, seg, nseg);
                let mut bytes = full[s..e].to_vec();
                bytes.resize(seg_wire_len(iv_bytes, nseg), 0);
                states[dest].learn_part(&Part { iv, seg, nseg }, &bytes);
            }
            if states[dest].get_full(iv).is_none() {
                pending.push((dest, iv));
            }
        }
    }
    Ok(())
}

/// Execute `plan` without a schedule: senders read `states[sender]`,
/// every other node decodes, deferred messages iterate to fixpoint.
/// Meters round by round like the planned paths.
pub fn execute_shuffle(
    plan: &ShufflePlan,
    states: &mut [NodeState],
    net: &mut BroadcastNet,
) -> Result<ShuffleOutcome> {
    let k = states.len();
    let mut payload_bytes = 0u64;
    let mut wire_bytes = 0u64;
    // Deferred messages per node for fixpoint decoding.
    let mut pending: Vec<Vec<(Vec<Part>, Vec<u8>)>> = vec![Vec::new(); k];

    let flat: Vec<&Broadcast> = plan.iter_broadcasts().collect();
    let starts_round = plan.round_start_flags();
    let group_starts = plan.group_start_masks();
    for (bi, &b) in flat.iter().enumerate() {
        if starts_round[bi] {
            net.begin_round();
        }
        if let Some(members) = group_starts[bi] {
            net.begin_group(members);
        }
        let msg = assemble_and_meter(b, states, net, &mut payload_bytes, &mut wire_bytes)?;
        match b {
            Broadcast::Uncoded { sender, iv } => {
                let part = Part::whole(*iv);
                for (node, st) in states.iter_mut().enumerate() {
                    if node != *sender && !st.knows_part(&part) {
                        st.learn_part(&part, &msg);
                    }
                }
            }
            Broadcast::Coded { sender, parts } => {
                for (node, st) in states.iter_mut().enumerate() {
                    if node == *sender {
                        continue;
                    }
                    if !st.try_decode(parts, &msg) {
                        pending[node].push((parts.clone(), msg.clone()));
                    }
                }
            }
        }
    }

    // Fixpoint pass over deferred messages.
    loop {
        let mut progress = false;
        for (node, queue) in pending.iter_mut().enumerate() {
            let mut i = 0;
            while i < queue.len() {
                let (parts, msg) = &queue[i];
                if states[node].try_decode(parts, msg) {
                    queue.swap_remove(i);
                    progress = true;
                } else {
                    i += 1;
                }
            }
        }
        if !progress {
            break;
        }
    }

    Ok(ShuffleOutcome {
        payload_bytes,
        wire_bytes,
        messages: flat.len() as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::decoder;
    use crate::prop;

    #[test]
    fn seg_ranges_tile_payload() {
        for len in [128usize, 127, 1, 12] {
            for nseg in [1u32, 2, 3, 4] {
                let mut covered = 0;
                for seg in 0..nseg {
                    let (s, e) = seg_range(len, seg, nseg);
                    assert_eq!(s, covered.min(len));
                    covered = e;
                }
                assert_eq!(covered, len, "len={len} nseg={nseg}");
            }
        }
    }

    #[test]
    fn node_state_full_roundtrip() {
        let mut st = NodeState::new(3, 4, 16);
        let iv = IvId { group: 1, sub: 2 };
        assert!(st.get_full(iv).is_none());
        st.set_full(iv, vec![7u8; 16]);
        assert_eq!(st.get_full(iv).unwrap(), &[7u8; 16]);
        assert!(st.knows_part(&Part::whole(iv)));
    }

    #[test]
    fn reset_forgets_everything_without_reallocating() {
        let mut st = NodeState::new(2, 2, 8);
        let iv = IvId { group: 0, sub: 1 };
        st.set_full(iv, vec![9u8; 8]);
        st.learn_part(&Part { iv: IvId { group: 1, sub: 0 }, seg: 0, nseg: 2 }, &[1u8; 4]);
        st.reset();
        assert!(st.get_full(iv).is_none());
        assert!(!st.knows_part(&Part { iv: IvId { group: 1, sub: 0 }, seg: 0, nseg: 2 }));
        // Slots are reusable after reset.
        st.set_full_from(iv, &[3u8; 8]);
        assert_eq!(st.get_full(iv).unwrap(), &[3u8; 8]);
    }

    #[test]
    fn segment_assembly_reconstructs_payload() {
        let mut st = NodeState::new(1, 1, 10); // stride ceil(10/3) = 4
        let payload: Vec<u8> = (0u8..10).collect();
        let iv = IvId { group: 0, sub: 0 };
        for seg in 0..3u32 {
            let (s, e) = seg_range(10, seg, 3);
            let mut bytes = payload[s..e].to_vec();
            bytes.resize(4, 0);
            st.learn_part(&Part { iv, seg, nseg: 3 }, &bytes);
        }
        assert_eq!(st.get_full(iv).unwrap(), payload.as_slice());
    }

    #[test]
    fn try_decode_recovers_missing_part() {
        let mut st = NodeState::new(2, 2, 8);
        let a = IvId { group: 0, sub: 0 };
        let b = IvId { group: 1, sub: 1 };
        let pa: Vec<u8> = (0..8).collect();
        let pb: Vec<u8> = (100..108).collect();
        st.set_full(a, pa.clone());
        let msg: Vec<u8> = pa.iter().zip(&pb).map(|(x, y)| x ^ y).collect();
        assert!(st.try_decode(&[Part::whole(a), Part::whole(b)], &msg));
        assert_eq!(st.get_full(b).unwrap(), pb.as_slice());
    }

    #[test]
    fn prop_decode_order_independent_via_pending() {
        // Whatever the payload bytes, (X ^ known) recovers exactly.
        prop::run("xor decode exact", 100, |g| {
            let len = g.usize_in(1..=64);
            let pa: Vec<u8> = (0..len).map(|_| g.u64_in(0..=255) as u8).collect();
            let pb: Vec<u8> = (0..len).map(|_| g.u64_in(0..=255) as u8).collect();
            let mut st = NodeState::new(2, 1, len);
            let a = IvId { group: 0, sub: 0 };
            let b = IvId { group: 1, sub: 0 };
            st.set_full(a, pa.clone());
            let msg: Vec<u8> = pa.iter().zip(&pb).map(|(x, y)| x ^ y).collect();
            st.try_decode(&[Part::whole(a), Part::whole(b)], &msg);
            prop::check(
                st.get_full(b) == Some(pb.as_slice()),
                format!("len={len}"),
            )
        });
    }

    /// Seed every holder's Map knowledge with synthetic payloads.
    fn seeded_states(
        alloc: &crate::placement::alloc::Allocation,
        iv_bytes: usize,
    ) -> Vec<NodeState> {
        let k = alloc.k;
        let mut states: Vec<NodeState> = (0..k)
            .map(|_| NodeState::new(k, alloc.n_sub(), iv_bytes))
            .collect();
        for (sub, &h) in alloc.holders.iter().enumerate() {
            for (node, st) in states.iter_mut().enumerate() {
                if h & (1 << node) != 0 {
                    for g in 0..k {
                        let byte = (sub as u8).wrapping_mul(31) ^ (g as u8);
                        st.set_full(IvId { group: g, sub }, vec![byte; iv_bytes]);
                    }
                }
            }
        }
        states
    }

    #[test]
    fn planned_and_fixpoint_execution_agree() {
        let p = crate::theory::params::Params3::new(5, 8, 11, 12).unwrap();
        let alloc = crate::placement::k3::optimal_allocation(&p);
        let plan = crate::coding::plan::plan_k3(&alloc);
        let sched = decoder::schedule(&alloc, &plan).unwrap();
        let iv_bytes = 32;

        let mut s1 = seeded_states(&alloc, iv_bytes);
        let mut n1 = BroadcastNet::homogeneous(3, 1e9, 0.0).unwrap();
        let o1 = execute_shuffle(&plan, &mut s1, &mut n1).unwrap();

        let mut s2 = seeded_states(&alloc, iv_bytes);
        let mut n2 = BroadcastNet::homogeneous(3, 1e9, 0.0).unwrap();
        let o2 = execute_planned(&plan, &sched, &mut s2, &mut n2).unwrap();

        assert_eq!(o1.payload_bytes, o2.payload_bytes);
        assert_eq!(o1.wire_bytes, o2.wire_bytes);
        assert_eq!(o1.messages, o2.messages);
        // Both paths deliver identical bytes everywhere.
        for node in 0..3 {
            for sub in 0..alloc.n_sub() {
                let iv = IvId { group: node, sub };
                assert_eq!(
                    s1[node].get_full(iv).expect("fixpoint complete"),
                    s2[node].get_full(iv).expect("planned complete"),
                    "node {node} sub {sub}"
                );
            }
        }
    }

    #[test]
    fn erased_execution_recovers_bit_identical_state_and_meters_on_top() {
        let p = crate::theory::params::Params3::new(5, 8, 11, 12).unwrap();
        let alloc = crate::placement::k3::optimal_allocation(&p);
        let plan = crate::coding::plan::plan_k3(&alloc);
        let sched = decoder::schedule(&alloc, &plan).unwrap();
        let iv_bytes = 32;
        let net = || BroadcastNet::new(vec![4.5e8, 7.5e8, 1e9], 5e-4).unwrap();

        // Fault-free reference.
        let mut s0 = seeded_states(&alloc, iv_bytes);
        let mut n0 = net();
        let o0 = execute_planned(&plan, &sched, &mut s0, &mut n0).unwrap();
        let r0 = n0.report();

        // Nothing erased: the erased path is the planned path, byte for
        // byte — states, outcome, and NetReport.
        let nb = plan.n_broadcasts();
        let mut s_clean = seeded_states(&alloc, iv_bytes);
        let mut n_clean = net();
        let o_clean = execute_planned_erased(
            &plan, &alloc, &mut s_clean, &mut n_clean, &vec![false; nb], 1,
        )
        .unwrap();
        assert_eq!(o0.wire_bytes, o_clean.wire_bytes);
        assert_eq!(r0, n_clean.report());

        let mut any_retransmit = false;
        for bi in 0..nb {
            let mut erased = vec![false; nb];
            erased[bi] = true;
            let mut reports = Vec::new();
            for threads in [1usize, 3] {
                let mut s1 = seeded_states(&alloc, iv_bytes);
                let mut n1 = net();
                let o1 = execute_planned_erased(
                    &plan, &alloc, &mut s1, &mut n1, &erased, threads,
                )
                .unwrap();
                // Plan traffic is identical to fault-free: the sender
                // transmitted; only delivery was lost.
                assert_eq!(o0.payload_bytes, o1.payload_bytes);
                assert_eq!(o0.wire_bytes, o1.wire_bytes);
                assert_eq!(o0.messages, o1.messages);
                let r = n1.report();
                assert_eq!(r.erased_broadcasts, 1, "bi={bi}");
                // Full-IV state everywhere bit-equal to fault-free.
                for node in 0..3 {
                    for g in 0..3 {
                        for sub in 0..alloc.n_sub() {
                            let iv = IvId { group: g, sub };
                            assert_eq!(
                                s0[node].get_full(iv),
                                s1[node].get_full(iv),
                                "bi={bi} threads={threads} node={node} {iv:?}"
                            );
                        }
                    }
                }
                // Recovery rides on top of (never replaces) plan bytes.
                if r.retransmit_rounds > 0 {
                    any_retransmit = true;
                    assert!(r.recovery_bytes > 0 && r.nack_rtts > 0, "bi={bi}");
                    assert!(r.total_bytes > r0.total_bytes, "bi={bi}");
                } else {
                    assert_eq!(r.recovery_bytes, 0);
                    assert_eq!(r.total_bytes, r0.total_bytes);
                }
                reports.push(r);
            }
            // Serial and parallel meter identically, recovery included.
            assert_eq!(reports[0], reports[1], "bi={bi}");
        }
        // The bare k3 plan has critical broadcasts, so at least one
        // erasure must exercise the retransmission path.
        assert!(any_retransmit, "no erasure needed retransmission");
    }

    #[test]
    fn repair_rounds_absorb_single_erasures_without_retransmission() {
        use crate::coding::plan::with_repair_rounds;
        let p = crate::theory::params::Params3::new(5, 8, 11, 12).unwrap();
        let alloc = crate::placement::k3::optimal_allocation(&p);
        let base = crate::coding::plan::plan_k3(&alloc);
        let plan = with_repair_rounds(&base, &alloc, 1).unwrap();
        let sched = decoder::schedule(&alloc, &plan).unwrap();
        let iv_bytes = 16;

        let mut s0 = seeded_states(&alloc, iv_bytes);
        let mut n0 = BroadcastNet::homogeneous(3, 1e9, 1e-4).unwrap();
        execute_planned(&plan, &sched, &mut s0, &mut n0).unwrap();

        for bi in 0..plan.n_broadcasts() {
            let mut erased = vec![false; plan.n_broadcasts()];
            erased[bi] = true;
            let mut s1 = seeded_states(&alloc, iv_bytes);
            let mut n1 = BroadcastNet::homogeneous(3, 1e9, 1e-4).unwrap();
            execute_planned_erased(&plan, &alloc, &mut s1, &mut n1, &erased, 1).unwrap();
            let r = n1.report();
            // f=1 repair absorbs every single loss: recovery counters
            // stay zero and every node ends bit-equal to fault-free.
            assert_eq!(r.retransmit_rounds, 0, "bi={bi}");
            assert_eq!(r.recovery_bytes, 0, "bi={bi}");
            for node in 0..3 {
                for sub in 0..alloc.n_sub() {
                    let iv = IvId { group: node, sub };
                    assert_eq!(
                        s0[node].get_full(iv),
                        s1[node].get_full(iv),
                        "bi={bi} node={node} sub={sub}"
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_execution_is_bit_identical_to_serial() {
        let p = crate::theory::params::Params3::new(5, 8, 11, 12).unwrap();
        let alloc = crate::placement::k3::optimal_allocation(&p);
        let plan = crate::coding::plan::plan_k3(&alloc);
        let sched = decoder::schedule(&alloc, &plan).unwrap();
        let iv_bytes = 32;

        let mut s1 = seeded_states(&alloc, iv_bytes);
        let mut n1 = BroadcastNet::new(vec![4.5e8, 7.5e8, 1e9], 5e-4).unwrap();
        let o1 = execute_planned(&plan, &sched, &mut s1, &mut n1).unwrap();

        for threads in [1usize, 2, 3] {
            let mut s2 = seeded_states(&alloc, iv_bytes);
            let mut n2 = BroadcastNet::new(vec![4.5e8, 7.5e8, 1e9], 5e-4).unwrap();
            let o2 =
                execute_planned_parallel(&plan, &sched, &mut s2, &mut n2, threads).unwrap();
            assert_eq!(o1.payload_bytes, o2.payload_bytes);
            assert_eq!(o1.wire_bytes, o2.wire_bytes);
            assert_eq!(o1.messages, o2.messages);
            // NetReport equality is bit-exact, including the float clock.
            assert_eq!(n1.report(), n2.report(), "threads={threads}");
            for node in 0..3 {
                for g in 0..3 {
                    for sub in 0..alloc.n_sub() {
                        let iv = IvId { group: g, sub };
                        assert_eq!(
                            s1[node].get_full(iv),
                            s2[node].get_full(iv),
                            "threads={threads} node={node} {iv:?}"
                        );
                    }
                }
            }
        }
    }
}
