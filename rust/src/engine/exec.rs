//! Byte-level shuffle execution: senders assemble XOR payloads, receivers
//! decode them, all traffic metered by the network simulator.
//!
//! This mirrors [`crate::coding::decoder`] but with real bytes: the
//! symbolic decoder proves plans are decodable; this module proves the
//! *implementation* delivers bit-correct IVs (the engine verifies Reduce
//! outputs against the oracle afterwards).

use crate::coding::plan::{Broadcast, IvId, Part, ShufflePlan};
use crate::coding::xor::xor_into;
use crate::net::BroadcastNet;
use std::collections::HashMap;

/// Fixed per-message wire overhead (sender id, kind, part descriptors) —
/// counted in wire bytes so the time model is honest, excluded from the
/// paper's load metric (which counts IV bits only).
pub const HEADER_BYTES: usize = 16;
pub const PER_PART_BYTES: usize = 12;

/// Byte range of segment `seg` of `nseg` over a payload of `len` bytes
/// (equal ceil-sized strides; the tail segment may be short).
pub fn seg_range(len: usize, seg: u32, nseg: u32) -> (usize, usize) {
    let stride = len.div_ceil(nseg as usize);
    let start = (seg as usize * stride).min(len);
    let end = (start + stride).min(len);
    (start, end)
}

/// Wire length of a segment message (zero-padded to the stride).
pub fn seg_wire_len(len: usize, nseg: u32) -> usize {
    len.div_ceil(nseg as usize)
}

/// Per-node IV knowledge with real bytes.
pub struct NodeState {
    q: usize,
    n_sub: usize,
    iv_bytes: usize,
    /// Full payloads: index `group * n_sub + sub`.
    known: Vec<Option<Vec<u8>>>,
    /// Partially assembled IVs: iv -> (nseg, per-seg bytes).
    partial: HashMap<IvId, (u32, Vec<Option<Vec<u8>>>)>,
}

impl NodeState {
    pub fn new(q: usize, n_sub: usize, iv_bytes: usize) -> Self {
        Self {
            q,
            n_sub,
            iv_bytes,
            known: vec![None; q * n_sub],
            partial: HashMap::new(),
        }
    }

    fn idx(&self, iv: IvId) -> usize {
        debug_assert!(iv.group < self.q && iv.sub < self.n_sub);
        iv.group * self.n_sub + iv.sub
    }

    pub fn set_full(&mut self, iv: IvId, payload: Vec<u8>) {
        debug_assert_eq!(payload.len(), self.iv_bytes);
        let i = self.idx(iv);
        self.known[i] = Some(payload);
    }

    pub fn get_full(&self, iv: IvId) -> Option<&[u8]> {
        self.known[self.idx(iv)].as_deref()
    }

    pub fn knows_part(&self, p: &Part) -> bool {
        if self.get_full(p.iv).is_some() {
            return true;
        }
        self.partial
            .get(&p.iv)
            .map(|(nseg, segs)| *nseg == p.nseg && segs[p.seg as usize].is_some())
            .unwrap_or(false)
    }

    /// Bytes of a part, zero-padded to the segment stride.
    pub fn part_bytes(&self, p: &Part) -> Option<Vec<u8>> {
        let stride = seg_wire_len(self.iv_bytes, p.nseg);
        if let Some(full) = self.get_full(p.iv) {
            let (s, e) = seg_range(self.iv_bytes, p.seg, p.nseg);
            let mut out = full[s..e].to_vec();
            out.resize(stride, 0);
            return Some(out);
        }
        self.partial.get(&p.iv).and_then(|(nseg, segs)| {
            if *nseg == p.nseg {
                segs[p.seg as usize].clone()
            } else {
                None
            }
        })
    }

    /// Record a decoded part; assemble the full IV when complete.
    pub fn learn_part(&mut self, p: &Part, bytes: Vec<u8>) {
        if self.get_full(p.iv).is_some() {
            return;
        }
        if p.nseg == 1 {
            let mut payload = bytes;
            payload.truncate(self.iv_bytes);
            payload.resize(self.iv_bytes, 0);
            self.set_full(p.iv, payload);
            return;
        }
        let entry = self
            .partial
            .entry(p.iv)
            .or_insert_with(|| (p.nseg, vec![None; p.nseg as usize]));
        if entry.0 != p.nseg {
            return; // mixed granularity not used by any built-in plan
        }
        entry.1[p.seg as usize] = Some(bytes);
        if entry.1.iter().all(|s| s.is_some()) {
            let (nseg, segs) = self.partial.remove(&p.iv).unwrap();
            let mut payload = Vec::with_capacity(self.iv_bytes);
            for (i, seg_bytes) in segs.into_iter().enumerate() {
                let (s, e) = seg_range(self.iv_bytes, i as u32, nseg);
                payload.extend_from_slice(&seg_bytes.unwrap()[..e - s]);
            }
            self.set_full(p.iv, payload);
        }
    }

    /// Try to decode a coded message; true on progress.
    pub fn try_decode(&mut self, parts: &[Part], msg: &[u8]) -> bool {
        let unknown: Vec<usize> = (0..parts.len())
            .filter(|&i| !self.knows_part(&parts[i]))
            .collect();
        if unknown.len() != 1 {
            return unknown.is_empty(); // fully known: no new info, but "done"
        }
        let target = unknown[0];
        let mut recovered = msg.to_vec();
        for (i, p) in parts.iter().enumerate() {
            if i != target {
                let known = self.part_bytes(p).expect("knows_part checked");
                xor_into(&mut recovered, &known);
            }
        }
        self.learn_part(&parts[target], recovered);
        true
    }
}

/// Shuffle execution result.
#[derive(Clone, Debug)]
pub struct ShuffleOutcome {
    /// IV payload bytes broadcast (the paper's load metric, in bytes).
    pub payload_bytes: u64,
    /// Payload + headers (what the network actually carries).
    pub wire_bytes: u64,
    pub messages: u64,
}

/// Execute `plan`: senders read `states[sender]`, every other node
/// decodes. Returns byte accounting; panics if a sender lacks data it is
/// scheduled to transmit (plans are validated upstream).
pub fn execute_shuffle(
    plan: &ShufflePlan,
    states: &mut [NodeState],
    net: &mut BroadcastNet,
) -> Result<ShuffleOutcome, String> {
    let k = states.len();
    let mut payload_bytes = 0u64;
    let mut wire_bytes = 0u64;
    // Deferred messages per node for fixpoint decoding.
    let mut pending: Vec<Vec<(Vec<Part>, Vec<u8>)>> = vec![Vec::new(); k];

    for b in &plan.broadcasts {
        match b {
            Broadcast::Uncoded { sender, iv } => {
                let payload = states[*sender]
                    .get_full(*iv)
                    .ok_or_else(|| format!("sender {sender} lacks {iv:?}"))?
                    .to_vec();
                let wire = payload.len() + HEADER_BYTES + PER_PART_BYTES;
                payload_bytes += payload.len() as u64;
                wire_bytes += wire as u64;
                net.broadcast(*sender, wire);
                let part = Part::whole(*iv);
                for (node, st) in states.iter_mut().enumerate() {
                    if node != *sender && !st.knows_part(&part) {
                        st.learn_part(&part, payload.clone());
                    }
                }
            }
            Broadcast::Coded { sender, parts } => {
                // Assemble XOR of the sender's parts.
                let stride = seg_wire_len(states[*sender].iv_bytes, parts[0].nseg);
                let mut msg = vec![0u8; stride];
                for p in parts {
                    let bytes = states[*sender]
                        .part_bytes(p)
                        .ok_or_else(|| format!("sender {sender} lacks part {p:?}"))?;
                    xor_into(&mut msg, &bytes);
                }
                let wire = msg.len() + HEADER_BYTES + PER_PART_BYTES * parts.len();
                payload_bytes += msg.len() as u64;
                wire_bytes += wire as u64;
                net.broadcast(*sender, wire);
                for (node, st) in states.iter_mut().enumerate() {
                    if node == *sender {
                        continue;
                    }
                    if !st.try_decode(parts, &msg) {
                        pending[node].push((parts.clone(), msg.clone()));
                    }
                }
            }
        }
    }

    // Fixpoint pass over deferred messages.
    loop {
        let mut progress = false;
        for (node, queue) in pending.iter_mut().enumerate() {
            let mut i = 0;
            while i < queue.len() {
                let (parts, msg) = &queue[i];
                if states[node].try_decode(parts, msg) {
                    queue.swap_remove(i);
                    progress = true;
                } else {
                    i += 1;
                }
            }
        }
        if !progress {
            break;
        }
    }

    Ok(ShuffleOutcome {
        payload_bytes,
        wire_bytes,
        messages: plan.broadcasts.len() as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn seg_ranges_tile_payload() {
        for len in [128usize, 127, 1, 12] {
            for nseg in [1u32, 2, 3, 4] {
                let mut covered = 0;
                for seg in 0..nseg {
                    let (s, e) = seg_range(len, seg, nseg);
                    assert_eq!(s, covered.min(len));
                    covered = e;
                }
                assert_eq!(covered, len, "len={len} nseg={nseg}");
            }
        }
    }

    #[test]
    fn node_state_full_roundtrip() {
        let mut st = NodeState::new(3, 4, 16);
        let iv = IvId { group: 1, sub: 2 };
        assert!(st.get_full(iv).is_none());
        st.set_full(iv, vec![7u8; 16]);
        assert_eq!(st.get_full(iv).unwrap(), &[7u8; 16]);
        assert!(st.knows_part(&Part::whole(iv)));
    }

    #[test]
    fn segment_assembly_reconstructs_payload() {
        let mut st = NodeState::new(1, 1, 10); // stride ceil(10/3) = 4
        let payload: Vec<u8> = (0u8..10).collect();
        let iv = IvId { group: 0, sub: 0 };
        for seg in 0..3u32 {
            let (s, e) = seg_range(10, seg, 3);
            let mut bytes = payload[s..e].to_vec();
            bytes.resize(4, 0);
            st.learn_part(&Part { iv, seg, nseg: 3 }, bytes);
        }
        assert_eq!(st.get_full(iv).unwrap(), payload.as_slice());
    }

    #[test]
    fn try_decode_recovers_missing_part() {
        let mut st = NodeState::new(2, 2, 8);
        let a = IvId { group: 0, sub: 0 };
        let b = IvId { group: 1, sub: 1 };
        let pa: Vec<u8> = (0..8).collect();
        let pb: Vec<u8> = (100..108).collect();
        st.set_full(a, pa.clone());
        let msg: Vec<u8> = pa.iter().zip(&pb).map(|(x, y)| x ^ y).collect();
        assert!(st.try_decode(&[Part::whole(a), Part::whole(b)], &msg));
        assert_eq!(st.get_full(b).unwrap(), pb.as_slice());
    }

    #[test]
    fn prop_decode_order_independent_via_pending() {
        // Whatever the payload bytes, (X ^ known) recovers exactly.
        prop::run("xor decode exact", 100, |g| {
            let len = g.usize_in(1..=64);
            let pa: Vec<u8> = (0..len).map(|_| g.u64_in(0..=255) as u8).collect();
            let pb: Vec<u8> = (0..len).map(|_| g.u64_in(0..=255) as u8).collect();
            let mut st = NodeState::new(2, 1, len);
            let a = IvId { group: 0, sub: 0 };
            let b = IvId { group: 1, sub: 0 };
            st.set_full(a, pa.clone());
            let msg: Vec<u8> = pa.iter().zip(&pb).map(|(x, y)| x ^ y).collect();
            st.try_decode(&[Part::whole(a), Part::whole(b)], &msg);
            prop::check(
                st.get_full(b) == Some(pb.as_slice()),
                format!("len={len}"),
            )
        });
    }
}
