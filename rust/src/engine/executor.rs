//! The execution stage of the pipeline: run many data batches against one
//! validated [`Plan`], reusing per-node buffers across batches.
//!
//! All plan-shaped work (placement, shuffle planning, decode verification,
//! load prediction) happened at [`Plan`] build time; a batch run is pure
//! data movement: Map → replay the baked decode schedule → Reduce →
//! oracle verification. Batches differ only by data seed, so one plan
//! serves the production path's repeated jobs.
//!
//! ## Execution modes
//!
//! The paper's Map and Shuffle phases are embarrassingly parallel across
//! nodes — each node maps its placed files independently and decodes
//! multicasts independently. [`ExecMode::Parallel`] shards both phases
//! across [`std::thread::scope`] workers (per-node Map when the backend
//! supports concurrent workers, per-node decode always), while the
//! network metering stays a single plan-order pass — so a parallel run is
//! **bit-identical** to a serial one: same decoded IVs, same
//! [`RunReport`], same [`crate::net::NetReport`]. Determinism tests diff
//! the two modes directly (`tests/parallel_equivalence.rs`).

use super::backend::MapBackend;
use super::engine::RunReport;
use super::exec::{execute_planned, execute_planned_parallel, NodeState};
use super::plan::Plan;
use crate::coding::plan::IvId;
use crate::error::{HetcdcError, Result};
use crate::net::{BroadcastNet, NetReport};
use crate::workloads;

/// How a batch run schedules its per-node work.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ExecMode {
    /// One thread does everything, in plan order (the reference path).
    Serial,
    /// Per-node Map, message assembly, and schedule-driven decode run on
    /// scoped worker threads; metering stays serialized, so outputs and
    /// reports are bit-identical to [`ExecMode::Serial`].
    Parallel,
}

impl ExecMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ExecMode::Serial => "serial",
            ExecMode::Parallel => "parallel",
        }
    }
}

/// Runs batches against one [`Plan`]. Holds the per-node byte buffers,
/// the per-node held-subfile lists, and the network simulator; buffers
/// are reset (not reallocated) per batch, and all shape-derived work
/// (held lists, the map-time barrier) is computed once here.
pub struct Executor<'p> {
    plan: &'p Plan,
    states: Vec<NodeState>,
    /// Subfiles stored at each node, precomputed from the allocation.
    held: Vec<Vec<usize>>,
    net: BroadcastNet,
    mode: ExecMode,
    /// Worker threads for [`ExecMode::Parallel`]; `0` = auto-detect.
    threads: usize,
    batches_run: u64,
}

impl<'p> Executor<'p> {
    /// Serial executor (the reference mode).
    pub fn new(plan: &'p Plan) -> Result<Self> {
        Self::with_mode(plan, ExecMode::Serial)
    }

    pub fn with_mode(plan: &'p Plan, mode: ExecMode) -> Result<Self> {
        let k = plan.cluster.k();
        let q = k; // Q = K (one reduce-function group per node, as in the paper)
        let n_sub = plan.alloc.n_sub();
        let states = (0..k)
            .map(|_| NodeState::new(q, n_sub, plan.job.iv_bytes()))
            .collect();
        let held = (0..k)
            .map(|node| {
                (0..n_sub)
                    .filter(|&s| plan.alloc.holders[s] & (1 << node) != 0)
                    .collect()
            })
            .collect();
        Ok(Executor {
            plan,
            states,
            held,
            net: plan.cluster.network()?,
            mode,
            threads: 0,
            batches_run: 0,
        })
    }

    pub fn plan(&self) -> &'p Plan {
        self.plan
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    pub fn set_mode(&mut self, mode: ExecMode) {
        self.mode = mode;
    }

    /// Cap the worker count for [`ExecMode::Parallel`]; `0` (the default)
    /// uses [`std::thread::available_parallelism`]. No effect on results
    /// — only on wall-clock.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads;
    }

    /// Worker count a parallel phase would use right now.
    pub fn effective_threads(&self) -> usize {
        let hw = || {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        };
        let t = if self.threads == 0 { hw() } else { self.threads };
        t.clamp(1, self.plan.cluster.k().max(1))
    }

    /// Batches executed so far.
    pub fn batches_run(&self) -> u64 {
        self.batches_run
    }

    /// Network accounting of the most recent batch (equal across
    /// [`ExecMode`]s for the same batch — asserted by tier-1 tests).
    pub fn net_report(&self) -> NetReport {
        self.net.report()
    }

    /// Read one decoded IV payload of the most recent batch (`None` if
    /// that node never held or decoded it). Lets equivalence tests diff
    /// the complete post-shuffle state across execution modes.
    pub fn iv(&self, node: usize, iv: IvId) -> Option<&[u8]> {
        self.states.get(node)?.get_full(iv)
    }

    /// Run one batch with the plan's own data seed.
    pub fn run(&mut self, backend: &mut dyn MapBackend) -> Result<RunReport> {
        self.run_batch(backend, self.plan.job.seed)
    }

    /// Map phase, serial: every node computes all groups' IVs of its
    /// subfiles on the caller's backend.
    fn map_serial(
        &mut self,
        backend: &mut dyn MapBackend,
        job: &crate::model::job::JobSpec,
        q: usize,
    ) -> Result<()> {
        for node in 0..self.states.len() {
            let held = &self.held[node];
            let ivs = backend.map_subfiles(job, q, held)?;
            store_mapped(&mut self.states[node], held, ivs)?;
        }
        Ok(())
    }

    /// Map phase, parallel: nodes are sharded across scoped workers, each
    /// with its own backend from [`MapBackend::worker_clone`]. Falls back
    /// to [`Self::map_serial`] when the backend cannot be cloned (e.g.
    /// the PJRT runtime owns device state). Results are identical either
    /// way: Map output depends only on (job, q, held subfiles).
    fn map_parallel(
        &mut self,
        backend: &mut dyn MapBackend,
        job: &crate::model::job::JobSpec,
        q: usize,
    ) -> Result<()> {
        let threads = self.effective_threads();
        if threads <= 1 {
            return self.map_serial(backend, job, q);
        }
        let chunk = self.states.len().div_ceil(threads);
        let mut workers = Vec::new();
        for _ in 0..self.states.len().div_ceil(chunk) {
            match backend.worker_clone() {
                Some(w) => workers.push(w),
                None => return self.map_serial(backend, job, q),
            }
        }
        let held = &self.held;
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for ((ci, st_chunk), mut worker) in
                self.states.chunks_mut(chunk).enumerate().zip(workers)
            {
                let base = ci * chunk;
                handles.push(scope.spawn(move || -> Result<()> {
                    for (off, st) in st_chunk.iter_mut().enumerate() {
                        let held = &held[base + off];
                        let ivs = worker.map_subfiles(job, q, held)?;
                        store_mapped(st, held, ivs)?;
                    }
                    Ok(())
                }));
            }
            // Join all workers before propagating any error: an early
            // return would make thread::scope re-panic if a second
            // worker also panicked.
            let joined: Vec<_> = handles.into_iter().map(|h| h.join()).collect();
            for j in joined {
                j.map_err(|_| HetcdcError::Backend("map worker panicked".into()))??;
            }
            Ok(())
        })
    }

    /// Run one data batch: same plan, batch-specific `seed`. The report's
    /// loads and times must equal the plan's predictions (deterministic
    /// simulator); only the payload bytes differ between batches.
    pub fn run_batch(&mut self, backend: &mut dyn MapBackend, seed: u64) -> Result<RunReport> {
        let plan = self.plan;
        let k = plan.cluster.k();
        let q = k;
        let alloc = &plan.alloc;
        let n_sub = alloc.n_sub();
        let mut job = plan.job.clone();
        job.seed = seed;

        for st in &mut self.states {
            st.reset();
        }
        self.net.reset();

        // ---- Map phase. The barrier time over per-node compute rates is
        // shape-only work, computed once at plan build.
        let map_time_s = plan.predicted.map_time_s;
        match self.mode {
            ExecMode::Serial => self.map_serial(backend, &job, q)?,
            ExecMode::Parallel => self.map_parallel(backend, &job, q)?,
        }

        // ---- Shuffle phase: replay the decode schedule proven at plan
        // build time — no re-verification, no fixpoint.
        let outcome = match self.mode {
            ExecMode::Serial => {
                execute_planned(&plan.shuffle, &plan.schedule, &mut self.states, &mut self.net)?
            }
            ExecMode::Parallel => {
                let threads = self.effective_threads();
                execute_planned_parallel(
                    &plan.shuffle,
                    &plan.schedule,
                    &mut self.states,
                    &mut self.net,
                    threads,
                )?
            }
        };
        let shuffle_time_s = self.net.report().elapsed_s;

        // ---- Reduce phase + oracle verification (all groups' oracles in
        // one Map pass; per-group recomputation tripled verify cost).
        let mut verified = true;
        let mut max_abs_err = 0f64;
        let oracles = workloads::native_reduce_oracle_all(&job, q, n_sub);
        for node in 0..k {
            let payloads: Vec<&[u8]> = (0..n_sub)
                .map(|sub| {
                    self.states[node]
                        .get_full(IvId { group: node, sub })
                        .ok_or_else(|| {
                            HetcdcError::Shuffle(format!(
                                "node {node} missing IV for subfile {sub}"
                            ))
                        })
                })
                .collect::<Result<_>>()?;
            let out = backend.reduce_group(&job, &payloads)?;
            let oracle = &oracles[node];
            for (a, b) in out.iter().zip(oracle) {
                let err = (a - b).abs();
                max_abs_err = max_abs_err.max(err);
                // f32 accumulation tolerance, scaled to magnitude.
                if err > 1e-2 + 1e-4 * b.abs() {
                    verified = false;
                }
            }
        }

        self.batches_run += 1;
        let load_equations =
            outcome.payload_bytes as f64 / (job.iv_bytes() as f64 * alloc.sp as f64);
        Ok(RunReport {
            k,
            n_files: job.n_files,
            n_sub,
            sp: alloc.sp,
            placement: plan.placer.clone(),
            coder: plan.coder.clone(),
            mode: plan.mode,
            backend: backend.name().to_string(),
            seed,
            load_equations,
            plan_equations: plan.predicted.load_equations,
            payload_bytes: outcome.payload_bytes,
            wire_bytes: outcome.wire_bytes,
            messages: outcome.messages,
            map_time_s,
            shuffle_time_s,
            job_time_s: map_time_s + shuffle_time_s,
            verified,
            max_abs_err,
        })
    }
}

/// Validate and store one node's Map output (shared by both Map paths).
fn store_mapped(
    st: &mut NodeState,
    held: &[usize],
    ivs: Vec<Vec<Vec<u8>>>,
) -> Result<()> {
    if ivs.len() != held.len() {
        return Err(HetcdcError::Backend(format!(
            "map returned {} subfiles, expected {}",
            ivs.len(),
            held.len()
        )));
    }
    for (groups, &sub) in ivs.into_iter().zip(held) {
        for (g, payload) in groups.into_iter().enumerate() {
            st.set_full(IvId { group: g, sub }, payload);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::NativeBackend;
    use crate::engine::plan::JobBuilder;
    use crate::model::cluster::ClusterSpec;
    use crate::model::job::JobSpec;

    fn cluster(storage: &[u64]) -> ClusterSpec {
        let mut c = ClusterSpec::homogeneous(storage.len(), 1, 1000.0);
        for (node, &m) in c.nodes.iter_mut().zip(storage) {
            node.storage = m;
        }
        c
    }

    #[test]
    fn one_plan_many_batches_identical_loads() {
        let c = cluster(&[6, 7, 7]);
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        let mut be = NativeBackend;
        let mut exec = Executor::new(&plan).unwrap();
        let mut reports = Vec::new();
        for batch in 0u64..3 {
            let r = exec.run_batch(&mut be, job.seed + batch).unwrap();
            assert!(r.verified, "batch {batch} failed verification");
            reports.push(r);
        }
        assert_eq!(exec.batches_run(), 3);
        for r in &reports {
            // Measured equals predicted, batch after batch.
            assert_eq!(r.load_equations, plan.predicted.load_equations);
            assert_eq!(r.payload_bytes, plan.predicted.payload_bytes);
            assert_eq!(r.wire_bytes, plan.predicted.wire_bytes);
            assert_eq!(r.messages, plan.predicted.messages);
            assert_eq!(r.shuffle_time_s, plan.predicted.shuffle_time_s);
            assert_eq!(r.map_time_s, plan.predicted.map_time_s);
        }
        // Different seeds -> different data, same loads.
        assert_ne!(reports[0].seed, reports[1].seed);
    }

    #[test]
    fn parallel_mode_matches_serial_bit_for_bit() {
        let c = cluster(&[4, 8, 12]);
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        let mut be = NativeBackend;
        let mut serial = Executor::new(&plan).unwrap();
        let mut parallel = Executor::with_mode(&plan, ExecMode::Parallel).unwrap();
        parallel.set_threads(3);
        let a = serial.run_batch(&mut be, 42).unwrap();
        let b = parallel.run_batch(&mut be, 42).unwrap();
        assert!(a.verified && b.verified);
        assert_eq!(a.payload_bytes, b.payload_bytes);
        assert_eq!(a.wire_bytes, b.wire_bytes);
        assert_eq!(a.messages, b.messages);
        assert_eq!(a.shuffle_time_s.to_bits(), b.shuffle_time_s.to_bits());
        assert_eq!(serial.net_report(), parallel.net_report());
        let n_sub = plan.alloc.n_sub();
        for node in 0..3 {
            for g in 0..3 {
                for sub in 0..n_sub {
                    let iv = IvId { group: g, sub };
                    assert_eq!(serial.iv(node, iv), parallel.iv(node, iv), "node {node} {iv:?}");
                }
            }
        }
    }

    #[test]
    fn thread_knob_never_changes_results() {
        let c = cluster(&[6, 7, 7]);
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).build().unwrap();
        let mut be = NativeBackend;
        let mut reference = Executor::new(&plan).unwrap();
        let base = reference.run_batch(&mut be, 7).unwrap();
        for threads in [1usize, 2, 3, 8] {
            let mut exec = Executor::with_mode(&plan, ExecMode::Parallel).unwrap();
            exec.set_threads(threads);
            let r = exec.run_batch(&mut be, 7).unwrap();
            assert_eq!(r.payload_bytes, base.payload_bytes, "threads={threads}");
            assert_eq!(r.shuffle_time_s.to_bits(), base.shuffle_time_s.to_bits());
            assert_eq!(reference.net_report(), exec.net_report(), "threads={threads}");
        }
    }
}
