//! The execution stage of the pipeline: run many data batches against one
//! validated [`Plan`], reusing per-node buffers across batches.
//!
//! All plan-shaped work (placement, shuffle planning, decode verification,
//! load prediction) happened at [`Plan`] build time; a batch run is pure
//! data movement: Map → replay the baked decode schedule → Reduce →
//! oracle verification. Batches differ only by data seed, so one plan
//! serves the production path's repeated jobs.

use super::backend::MapBackend;
use super::engine::RunReport;
use super::exec::{execute_planned, NodeState};
use super::plan::Plan;
use crate::coding::plan::IvId;
use crate::error::{HetcdcError, Result};
use crate::net::BroadcastNet;
use crate::workloads;

/// Runs batches against one [`Plan`]. Holds the per-node byte buffers,
/// the per-node held-subfile lists, and the network simulator; buffers
/// are reset (not reallocated) per batch, and all shape-derived work
/// (held lists, the map-time barrier) is computed once here.
pub struct Executor<'p> {
    plan: &'p Plan,
    states: Vec<NodeState>,
    /// Subfiles stored at each node, precomputed from the allocation.
    held: Vec<Vec<usize>>,
    net: BroadcastNet,
    batches_run: u64,
}

impl<'p> Executor<'p> {
    pub fn new(plan: &'p Plan) -> Self {
        let k = plan.cluster.k();
        let q = k; // Q = K (one reduce-function group per node, as in the paper)
        let n_sub = plan.alloc.n_sub();
        let states = (0..k)
            .map(|_| NodeState::new(q, n_sub, plan.job.iv_bytes()))
            .collect();
        let held = (0..k)
            .map(|node| {
                (0..n_sub)
                    .filter(|&s| plan.alloc.holders[s] & (1 << node) != 0)
                    .collect()
            })
            .collect();
        Executor {
            plan,
            states,
            held,
            net: plan.cluster.network(),
            batches_run: 0,
        }
    }

    pub fn plan(&self) -> &'p Plan {
        self.plan
    }

    /// Batches executed so far.
    pub fn batches_run(&self) -> u64 {
        self.batches_run
    }

    /// Run one batch with the plan's own data seed.
    pub fn run(&mut self, backend: &mut dyn MapBackend) -> Result<RunReport> {
        self.run_batch(backend, self.plan.job.seed)
    }

    /// Run one data batch: same plan, batch-specific `seed`. The report's
    /// loads and times must equal the plan's predictions (deterministic
    /// simulator); only the payload bytes differ between batches.
    pub fn run_batch(&mut self, backend: &mut dyn MapBackend, seed: u64) -> Result<RunReport> {
        let plan = self.plan;
        let k = plan.cluster.k();
        let q = k;
        let alloc = &plan.alloc;
        let n_sub = alloc.n_sub();
        let mut job = plan.job.clone();
        job.seed = seed;

        for st in &mut self.states {
            st.reset();
        }
        self.net.reset();

        // ---- Map phase: every node computes all groups' IVs of its
        // subfiles. The barrier time over per-node compute rates is
        // shape-only work, computed once at plan build.
        let map_time_s = plan.predicted.map_time_s;
        for node in 0..k {
            let held = &self.held[node];
            let ivs = backend.map_subfiles(&job, q, held)?;
            if ivs.len() != held.len() {
                return Err(HetcdcError::Backend(format!(
                    "map returned {} subfiles, expected {}",
                    ivs.len(),
                    held.len()
                )));
            }
            for (groups, &sub) in ivs.into_iter().zip(held) {
                for (g, payload) in groups.into_iter().enumerate() {
                    self.states[node].set_full(IvId { group: g, sub }, payload);
                }
            }
        }

        // ---- Shuffle phase: replay the decode schedule proven at plan
        // build time — no re-verification, no fixpoint.
        let outcome = execute_planned(&plan.shuffle, &plan.schedule, &mut self.states, &mut self.net)?;
        let shuffle_time_s = self.net.report().elapsed_s;

        // ---- Reduce phase + oracle verification (all groups' oracles in
        // one Map pass; per-group recomputation tripled verify cost).
        let mut verified = true;
        let mut max_abs_err = 0f64;
        let oracles = workloads::native_reduce_oracle_all(&job, q, n_sub);
        for node in 0..k {
            let payloads: Vec<&[u8]> = (0..n_sub)
                .map(|sub| {
                    self.states[node]
                        .get_full(IvId { group: node, sub })
                        .ok_or_else(|| {
                            HetcdcError::Shuffle(format!(
                                "node {node} missing IV for subfile {sub}"
                            ))
                        })
                })
                .collect::<Result<_>>()?;
            let out = backend.reduce_group(&job, &payloads)?;
            let oracle = &oracles[node];
            for (a, b) in out.iter().zip(oracle) {
                let err = (a - b).abs();
                max_abs_err = max_abs_err.max(err);
                // f32 accumulation tolerance, scaled to magnitude.
                if err > 1e-2 + 1e-4 * b.abs() {
                    verified = false;
                }
            }
        }

        self.batches_run += 1;
        let load_equations =
            outcome.payload_bytes as f64 / (job.iv_bytes() as f64 * alloc.sp as f64);
        Ok(RunReport {
            k,
            n_files: job.n_files,
            n_sub,
            sp: alloc.sp,
            placement: plan.placer.clone(),
            coder: plan.coder.clone(),
            mode: plan.mode,
            backend: backend.name().to_string(),
            seed,
            load_equations,
            plan_equations: plan.predicted.load_equations,
            payload_bytes: outcome.payload_bytes,
            wire_bytes: outcome.wire_bytes,
            messages: outcome.messages,
            map_time_s,
            shuffle_time_s,
            job_time_s: map_time_s + shuffle_time_s,
            verified,
            max_abs_err,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::NativeBackend;
    use crate::engine::plan::JobBuilder;
    use crate::model::cluster::ClusterSpec;
    use crate::model::job::JobSpec;

    fn cluster(storage: &[u64]) -> ClusterSpec {
        let mut c = ClusterSpec::homogeneous(storage.len(), 1, 1000.0);
        for (node, &m) in c.nodes.iter_mut().zip(storage) {
            node.storage = m;
        }
        c
    }

    #[test]
    fn one_plan_many_batches_identical_loads() {
        let c = cluster(&[6, 7, 7]);
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        let mut be = NativeBackend;
        let mut exec = Executor::new(&plan);
        let mut reports = Vec::new();
        for batch in 0u64..3 {
            let r = exec.run_batch(&mut be, job.seed + batch).unwrap();
            assert!(r.verified, "batch {batch} failed verification");
            reports.push(r);
        }
        assert_eq!(exec.batches_run(), 3);
        for r in &reports {
            // Measured equals predicted, batch after batch.
            assert_eq!(r.load_equations, plan.predicted.load_equations);
            assert_eq!(r.payload_bytes, plan.predicted.payload_bytes);
            assert_eq!(r.wire_bytes, plan.predicted.wire_bytes);
            assert_eq!(r.messages, plan.predicted.messages);
            assert_eq!(r.shuffle_time_s, plan.predicted.shuffle_time_s);
            assert_eq!(r.map_time_s, plan.predicted.map_time_s);
        }
        // Different seeds -> different data, same loads.
        assert_ne!(reports[0].seed, reports[1].seed);
    }
}
