//! The execution stage of the pipeline: run many data batches against one
//! validated [`Plan`], reusing per-node buffers across batches.
//!
//! All plan-shaped work (placement, shuffle planning, decode verification,
//! load prediction) happened at [`Plan`] build time; a batch run is pure
//! data movement: Map → replay the baked decode schedule → Reduce →
//! oracle verification. Batches differ only by data seed, so one plan
//! serves the production path's repeated jobs.
//!
//! ## Execution modes
//!
//! The paper's Map and Shuffle phases are embarrassingly parallel across
//! nodes — each node maps its placed files independently and decodes
//! multicasts independently. [`ExecMode::Parallel`] shards both phases
//! across [`std::thread::scope`] workers (per-node Map when the backend
//! supports concurrent workers, per-node decode always), while the
//! network metering stays a single plan-order pass — so a parallel run is
//! **bit-identical** to a serial one: same decoded IVs, same
//! [`RunReport`], same [`crate::net::NetReport`].
//!
//! [`ExecMode::Pipelined`] additionally overlaps *batches*: nothing in
//! the paper's scheme couples batch `i+1`'s Map to batch `i`'s Shuffle,
//! so [`Executor::run_batches`] runs a two-stage pipeline — a worker
//! thread Maps batch `i+1` into the **back** epoch bank (via
//! [`MapBackend::worker_clone`]) while the main thread assembles,
//! meters, decodes, and Reduce-verifies batch `i` on the **front** bank.
//! The banks swap in O(1) per batch, each bank's [`NodeState::reset`] is
//! an O(1) epoch bump, and every batch is still metered by its own
//! single plan-order pass — so pipelined runs are bit-identical to
//! serial ones, batch by batch. Determinism tests diff all three modes
//! directly (`tests/parallel_equivalence.rs`).

use super::backend::MapBackend;
use super::engine::RunReport;
use super::exec::{execute_planned, execute_planned_erased, execute_planned_parallel, NodeState};
use super::plan::{straggler_ready, Plan};
use crate::coding::plan::IvId;
use crate::error::{HetcdcError, Result};
use crate::model::job::JobSpec;
use crate::net::{BroadcastNet, FaultSpec, NetReport};
use crate::workloads;

/// How a batch run schedules its per-node work.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ExecMode {
    /// One thread does everything, in plan order (the reference path).
    Serial,
    /// Per-node Map, message assembly, and schedule-driven decode run on
    /// scoped worker threads; metering stays serialized, so outputs and
    /// reports are bit-identical to [`ExecMode::Serial`].
    Parallel,
    /// Two-stage batch pipeline: [`Executor::run_batches`] Maps batch
    /// `i+1` on a worker thread while batch `i` shuffles and reduces,
    /// double-buffered on the two per-node epoch banks. Bit-identical
    /// per-batch results; only steady-state batches/sec changes. A
    /// single [`Executor::run_batch`] call (nothing to overlap) behaves
    /// like [`ExecMode::Parallel`].
    Pipelined,
}

impl ExecMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ExecMode::Serial => "serial",
            ExecMode::Parallel => "parallel",
            ExecMode::Pipelined => "pipelined",
        }
    }
}

/// Everything an [`Executor`] can be configured with, in one typed value.
/// [`Executor::with_config`] is the single construction path — the engine,
/// the bench suite, and the CLI all build executors through it, and
/// `xtask lint` bans the legacy constructor names everywhere outside test
/// code (rule `construction-path`).
///
/// Which runs read which field:
/// * `mode` — read by [`Executor::run_batch`] (Map sharding + decode
///   threads) and [`Executor::run_batches`] (whether to pipeline).
/// * `threads` — read by every parallel phase; `0` = auto-detect from
///   [`std::thread::available_parallelism`]. Never changes results.
/// * `faults` — `None` (the default) meters under the plan's own
///   [`crate::model::cluster::ClusterSpec::faults`]; `Some(spec)` is an
///   execution-time override installed into this executor's network
///   simulator at construction. Straggler jitter shifts clocks
///   (`shuffle_time_s`, `straggler_delay_s`) but never bytes; runtime
///   erasures (`erase:`) drop broadcast deliveries and meter the
///   recovery traffic on top; mid-run dropout (`drop:`) re-plans the
///   remaining batches without the lost node. Under *every* spec the
///   decoded IVs are bit-equal to the fault-free run and the bit-identity
///   contract across modes holds. Repair rounds are plan *shape* and
///   cannot be overridden here — rebuild the plan for that.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecConfig {
    pub mode: ExecMode,
    /// Worker threads for the parallel phases; `0` = auto-detect.
    pub threads: usize,
    /// Execution-time fault override; `None` = use the plan's spec.
    pub faults: Option<FaultSpec>,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig {
            mode: ExecMode::Serial,
            threads: 0,
            faults: None,
        }
    }
}

impl ExecConfig {
    /// Builder-style mode override.
    pub fn mode(mut self, mode: ExecMode) -> Self {
        self.mode = mode;
        self
    }

    /// Builder-style thread-cap override.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Builder-style fault override.
    pub fn faults(mut self, faults: FaultSpec) -> Self {
        self.faults = Some(faults);
        self
    }
}

/// Runs batches against one [`Plan`]. Holds the per-node byte buffers,
/// the per-node held-subfile lists, and the network simulator; buffers
/// are reset (not reallocated) per batch, and all shape-derived work
/// (held lists, the map-time barrier) is computed once here.
///
/// Two epoch banks of [`NodeState`] can be in flight at once: `states`
/// (the **front** bank — always the most recently executed batch) and
/// `back` (the bank the pipelined mode Maps the next batch into,
/// allocated lazily on the first pipelined multi-batch run). Serial and
/// parallel modes only ever touch the front bank.
pub struct Executor<'p> {
    plan: &'p Plan,
    /// Front epoch bank: post-shuffle state of the most recent batch.
    states: Vec<NodeState>,
    /// Back epoch bank: the in-flight Map target of batch `i+1` during a
    /// pipelined run. Empty until [`ExecMode::Pipelined`] first needs it.
    back: Vec<NodeState>,
    /// Subfiles stored at each node, precomputed from the allocation.
    held: Vec<Vec<usize>>,
    net: BroadcastNet,
    mode: ExecMode,
    /// Worker threads for parallel phases; `0` = auto-detect.
    threads: usize,
    /// The fault spec this executor meters under (the config override if
    /// one was given, else the plan's own).
    faults: FaultSpec,
    /// Set when a pipelined multi-batch run had to degrade to the
    /// sequential loop because the backend cannot Map concurrently.
    pipeline_degraded: bool,
    batches_run: u64,
}

impl<'p> Executor<'p> {
    /// The single construction path: every field of `cfg` is applied
    /// here, including installing the effective fault spec's straggler
    /// jitter into the network simulator so all subsequent batch runs
    /// meter under it.
    pub fn with_config(plan: &'p Plan, cfg: ExecConfig) -> Result<Self> {
        let k = plan.cluster.k();
        let q = k; // Q = K (one reduce-function group per node, as in the paper)
        let n_sub = plan.alloc.n_sub();
        let states = (0..k)
            .map(|_| NodeState::new(q, n_sub, plan.job.iv_bytes()))
            .collect();
        let held = (0..k)
            .map(|node| {
                (0..n_sub)
                    .filter(|&s| plan.alloc.holders[s] & (1 << node) != 0)
                    .collect()
            })
            .collect();
        let faults = cfg
            .faults
            .unwrap_or_else(|| plan.cluster.faults.clone());
        faults.validate(k)?;
        let mut net = plan.cluster.network()?;
        if faults.straggle.is_some() {
            // straggler_ready reads the spec off the cluster, so apply
            // the effective spec to a throwaway clone when overriding.
            let cluster = plan.cluster.clone().with_faults(faults.clone());
            if let Some(ready) = straggler_ready(&cluster, &plan.alloc) {
                net.set_straggle(&ready)?;
            }
        }
        Ok(Executor {
            plan,
            states,
            back: Vec::new(),
            held,
            net,
            mode: cfg.mode,
            threads: cfg.threads,
            faults,
            pipeline_degraded: false,
            batches_run: 0,
        })
    }

    pub fn plan(&self) -> &'p Plan {
        self.plan
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    pub fn set_mode(&mut self, mode: ExecMode) {
        self.mode = mode;
    }

    /// Worker count a parallel phase would use right now. Never errors:
    /// an unqueryable [`std::thread::available_parallelism`] degrades to
    /// one worker.
    pub fn effective_threads(&self) -> usize {
        let hw = || {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        };
        let t = if self.threads == 0 { hw() } else { self.threads };
        t.clamp(1, self.plan.cluster.k().max(1))
    }

    /// The fault spec this executor meters under: the
    /// [`ExecConfig::faults`] override when one was given, else the
    /// plan's own cluster spec.
    pub fn faults(&self) -> &FaultSpec {
        &self.faults
    }

    /// `true` once a [`ExecMode::Pipelined`] multi-batch run lost its
    /// Map/Shuffle overlap — either because [`MapBackend::worker_clone`]
    /// returned `None` (the backend cannot Map concurrently, so the whole
    /// run degrades to the sequential loop) or because a fault spec
    /// forced a batch to serialize erasure-recovery retransmission rounds
    /// on the front stage. Results are unaffected in both cases — only
    /// the overlap (and with it the steady-state throughput) is lost;
    /// each trigger warns once on stderr and latches here.
    pub fn pipeline_degraded(&self) -> bool {
        self.pipeline_degraded
    }

    /// Batches executed so far.
    pub fn batches_run(&self) -> u64 {
        self.batches_run
    }

    /// Network accounting of the most recent batch (equal across
    /// [`ExecMode`]s for the same batch — asserted by tier-1 tests). The
    /// report's `epoch` equals [`Self::batches_run`]: each batch is
    /// metered by exactly one ledger epoch, pipelined or not. Exception:
    /// after a mid-run dropout switchover this ledger froze at the last
    /// pre-switchover batch (the survivor plan metered on its own
    /// executor), so `epoch` stops short of [`Self::batches_run`].
    pub fn net_report(&self) -> NetReport {
        self.net.report()
    }

    /// Read one decoded IV payload of the most recent batch (`None` if
    /// that node never held or decoded it). Lets equivalence tests diff
    /// the complete post-shuffle state across execution modes. In
    /// pipelined runs this reads the front bank, which always holds the
    /// last *finished* batch — never the in-flight Map of the next one.
    pub fn iv(&self, node: usize, iv: IvId) -> Option<&[u8]> {
        self.states.get(node)?.get_full(iv)
    }

    /// Run one batch with the plan's own data seed.
    pub fn run(&mut self, backend: &mut dyn MapBackend) -> Result<RunReport> {
        self.run_batch(backend, self.plan.job.seed)
    }

    /// Map phase, serial: every node computes all groups' IVs of its
    /// subfiles on the caller's backend.
    fn map_serial(
        &mut self,
        backend: &mut dyn MapBackend,
        job: &JobSpec,
        q: usize,
    ) -> Result<()> {
        for node in 0..self.states.len() {
            let held = &self.held[node];
            let ivs = backend.map_subfiles(job, q, held)?;
            store_mapped(&mut self.states[node], held, ivs)?;
        }
        Ok(())
    }

    /// Map phase, parallel: nodes are sharded across scoped workers, each
    /// with its own backend from [`MapBackend::worker_clone`]. Falls back
    /// to [`Self::map_serial`] when the backend cannot be cloned (e.g.
    /// the PJRT runtime owns device state). Results are identical either
    /// way: Map output depends only on (job, q, held subfiles).
    fn map_parallel(
        &mut self,
        backend: &mut dyn MapBackend,
        job: &JobSpec,
        q: usize,
    ) -> Result<()> {
        let threads = self.effective_threads();
        if threads <= 1 {
            return self.map_serial(backend, job, q);
        }
        let chunk = self.states.len().div_ceil(threads);
        let mut workers = Vec::new();
        for _ in 0..self.states.len().div_ceil(chunk) {
            match backend.worker_clone() {
                Some(w) => workers.push(w),
                None => return self.map_serial(backend, job, q),
            }
        }
        let held = &self.held;
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for ((ci, st_chunk), mut worker) in
                self.states.chunks_mut(chunk).enumerate().zip(workers)
            {
                let base = ci * chunk;
                handles.push(scope.spawn(move || -> Result<()> {
                    for (off, st) in st_chunk.iter_mut().enumerate() {
                        let held = &held[base + off];
                        let ivs = worker.map_subfiles(job, q, held)?;
                        store_mapped(st, held, ivs)?;
                    }
                    Ok(())
                }));
            }
            // Join all workers before propagating any error: an early
            // return would make thread::scope re-panic if a second
            // worker also panicked.
            let joined: Vec<_> = handles.into_iter().map(|h| h.join()).collect();
            for j in joined {
                j.map_err(|_| HetcdcError::Backend("map worker panicked".into()))??;
            }
            Ok(())
        })
    }

    /// Run one data batch: same plan, batch-specific `seed`. The report's
    /// loads and times must equal the plan's predictions (deterministic
    /// simulator); only the payload bytes differ between batches. When an
    /// [`ExecConfig::faults`] override diverges from the plan's spec, the
    /// clock fields (`shuffle_time_s`, and the report's straggler delay)
    /// diverge from the prediction too — bytes, messages, and rounds
    /// never do.
    ///
    /// Config fields read: `mode` (Map sharding + decode threads),
    /// `threads` (worker count), and `faults` (already installed in the
    /// network simulator at construction — jitter survives the per-batch
    /// ledger reset by design).
    pub fn run_batch(&mut self, backend: &mut dyn MapBackend, seed: u64) -> Result<RunReport> {
        let q = self.plan.cluster.k();
        let mut job = self.plan.job.clone();
        job.seed = seed;

        for st in &mut self.states {
            st.reset();
        }

        // ---- Map phase. The barrier time over per-node compute rates is
        // shape-only work, computed once at plan build.
        match self.mode {
            ExecMode::Serial => self.map_serial(backend, &job, q)?,
            ExecMode::Parallel | ExecMode::Pipelined => self.map_parallel(backend, &job, q)?,
        }

        // ---- Shuffle + Reduce + verify.
        let decode_threads = match self.mode {
            ExecMode::Serial => 1,
            ExecMode::Parallel | ExecMode::Pipelined => self.effective_threads(),
        };
        let report = finish_batch(
            self.plan,
            &mut self.states,
            &mut self.net,
            backend,
            &job,
            decode_threads,
            &self.faults,
        )?;
        self.batches_run += 1;
        Ok(report)
    }

    /// Second degradation trigger (see [`Self::pipeline_degraded`]):
    /// called after each pipelined batch — retransmission rounds run
    /// serialized on the front stage, so the first batch that needed any
    /// warns once and latches.
    fn note_recovery_serialized(&mut self) {
        if self.pipeline_degraded {
            return;
        }
        if self.net.report().retransmit_rounds > 0 {
            self.pipeline_degraded = true;
            eprintln!(
                "hetcdc: warning: erasure recovery serialized retransmission \
                 round(s) on the pipelined front stage; results are identical, \
                 only the Map/Shuffle overlap of the affected batches is lost"
            );
        }
    }

    /// Execute one batch per seed, in order, returning one report per
    /// batch. [`ExecMode::Serial`] and [`ExecMode::Parallel`] loop
    /// [`Self::run_batch`]; [`ExecMode::Pipelined`] overlaps the Map of
    /// batch `i+1` with the Shuffle/Reduce of batch `i` on the two epoch
    /// banks. Per-batch results are **bit-identical** across all three
    /// modes; a backend whose [`MapBackend::worker_clone`] returns `None`
    /// (it cannot Map concurrently) degrades to the sequential loop. That
    /// degradation is no longer silent: it is noted once on stderr and
    /// latched on [`Self::pipeline_degraded`] so callers can surface it
    /// on their reports.
    ///
    /// Config fields read: `mode` (pipeline vs loop), `threads` (worker
    /// split between the Map-ahead stage and the front-batch decode), and
    /// `faults` (installed at construction; every batch meters under it).
    pub fn run_batches(
        &mut self,
        backend: &mut dyn MapBackend,
        seeds: &[u64],
    ) -> Result<Vec<RunReport>> {
        if let Some(drop) = self.faults.dropout {
            return self.run_batches_with_dropout(backend, seeds, drop);
        }
        self.run_batches_inner(backend, seeds)
    }

    /// Mid-run dropout: batches before `drop.at_batch` (counted on this
    /// executor's global [`Self::batches_run`]) finish in flight on the
    /// original plan; the remainder re-plan without the lost node
    /// ([`Plan::replan_without`]) and resume on a survivor executor with
    /// the same mode/threads/faults (minus the dropout), their reports
    /// tagged with `replanned_without`. After the switchover, this
    /// executor's [`Self::net_report`] and [`Self::iv`] still reflect the
    /// last pre-switchover batch — the survivor plan has its own shape.
    fn run_batches_with_dropout(
        &mut self,
        backend: &mut dyn MapBackend,
        seeds: &[u64],
        drop: crate::net::Dropout,
    ) -> Result<Vec<RunReport>> {
        let boundary = drop
            .at_batch
            .saturating_sub(self.batches_run)
            .min(seeds.len() as u64) as usize;
        let (before, after) = seeds.split_at(boundary);
        let mut reports = self.run_batches_inner(backend, before)?;
        if !after.is_empty() {
            let survivor = self.plan.replan_without(drop.node)?;
            let mut faults = self.faults.clone();
            faults.dropout = None;
            let cfg = ExecConfig {
                mode: self.mode,
                threads: self.threads,
                faults: Some(faults),
            };
            let mut inner = Executor::with_config(&survivor, cfg)?;
            let mut rest = inner.run_batches(backend, after)?;
            if inner.pipeline_degraded() {
                self.pipeline_degraded = true;
            }
            for r in &mut rest {
                r.replanned_without = Some(drop.node);
            }
            self.batches_run += rest.len() as u64;
            reports.append(&mut rest);
        }
        Ok(reports)
    }

    /// [`Self::run_batches`] minus the dropout handling (the fault-free /
    /// erasure / straggle flow).
    fn run_batches_inner(
        &mut self,
        backend: &mut dyn MapBackend,
        seeds: &[u64],
    ) -> Result<Vec<RunReport>> {
        if self.mode != ExecMode::Pipelined || seeds.len() < 2 {
            return seeds.iter().map(|&s| self.run_batch(backend, s)).collect();
        }
        match backend.worker_clone() {
            Some(worker) => self.run_batches_pipelined(backend, worker, seeds),
            None => {
                if !self.pipeline_degraded {
                    self.pipeline_degraded = true;
                    eprintln!(
                        "hetcdc: warning: backend '{}' cannot Map concurrently \
                         (worker_clone() returned None); pipelined run degrades \
                         to sequential batches — results are identical, only \
                         the Map/Shuffle overlap is lost",
                        backend.name()
                    );
                }
                seeds.iter().map(|&s| self.run_batch(backend, s)).collect()
            }
        }
    }

    /// The two-stage pipeline: Map of batch `i+1` (worker thread, back
    /// bank) overlaps Shuffle + Reduce of batch `i` (this thread, front
    /// bank). Requires `seeds.len() >= 2` and a concurrency-capable
    /// backend — [`Self::run_batches`] guards both.
    ///
    /// Epoch-bank lifecycle per batch `i` (see DESIGN.md for the full
    /// diagram): the front bank holds batch `i`'s Map output; the worker
    /// O(1)-resets the back bank (stale batch `i-1` state) and fills it
    /// with batch `i+1`'s Map; after both stages join, the banks swap in
    /// O(1). The network is metered *only* by the front stage — one
    /// plan-order pass per batch, exactly as in serial mode — so reports,
    /// clocks, and decoded bytes cannot drift.
    fn run_batches_pipelined(
        &mut self,
        backend: &mut dyn MapBackend,
        mut map_worker: Box<dyn MapBackend + Send>,
        seeds: &[u64],
    ) -> Result<Vec<RunReport>> {
        let k = self.plan.cluster.k();
        let q = k;
        if self.back.len() != k {
            self.back = (0..k)
                .map(|_| NodeState::new(q, self.plan.alloc.n_sub(), self.plan.job.iv_bytes()))
                .collect();
        }
        // The Map-ahead worker takes one slot of the thread budget; the
        // decode of the front batch gets the rest. Any split is
        // bit-identical — this only tunes wall-clock.
        let decode_threads = self.effective_threads().saturating_sub(1).max(1);

        // Fill stage: Map the first batch into the front bank.
        let mut job = self.plan.job.clone();
        job.seed = seeds[0];
        for st in &mut self.states {
            st.reset();
        }
        self.map_parallel(backend, &job, q)?;

        let mut reports = Vec::with_capacity(seeds.len());
        for (i, &seed) in seeds.iter().enumerate() {
            job.seed = seed;
            let next_seed = seeds.get(i + 1).copied();
            let report = {
                let Executor {
                    plan,
                    states,
                    back,
                    held,
                    net,
                    faults,
                    ..
                } = self;
                let plan: &'p Plan = *plan;
                std::thread::scope(|scope| -> Result<RunReport> {
                    // Stage A (worker thread): reset the back bank and
                    // Map batch i+1 into it.
                    let map_handle = next_seed.map(|seed| {
                        let mut next_job = plan.job.clone();
                        next_job.seed = seed;
                        let worker = &mut map_worker;
                        let back: &mut [NodeState] = back;
                        let held: &[Vec<usize>] = held;
                        scope.spawn(move || -> Result<()> {
                            for (node, st) in back.iter_mut().enumerate() {
                                st.reset();
                                let ivs = worker.map_subfiles(&next_job, q, &held[node])?;
                                store_mapped(st, &held[node], ivs)?;
                            }
                            Ok(())
                        })
                    });
                    // Stage B (this thread): Shuffle + Reduce + verify
                    // batch i on the front bank.
                    let finished =
                        finish_batch(plan, states, net, backend, &job, decode_threads, faults);
                    // Join the Map stage before propagating any error so
                    // thread::scope never re-panics over a live worker.
                    let mapped = match map_handle {
                        Some(h) => h
                            .join()
                            .map_err(|_| {
                                HetcdcError::Backend("pipelined map worker panicked".into())
                            })
                            .and_then(|r| r),
                        None => Ok(()),
                    };
                    let report = finished?;
                    mapped?;
                    Ok(report)
                })?
            };
            self.batches_run += 1;
            reports.push(report);
            self.note_recovery_serialized();
            if next_seed.is_some() {
                // O(1) bank swap: batch i+1's freshly Mapped state
                // becomes the front; batch i's drained state becomes the
                // next Map target.
                std::mem::swap(&mut self.states, &mut self.back);
            }
        }
        Ok(reports)
    }
}

/// Shuffle + Reduce + oracle-verify one already-Mapped batch — the
/// post-Map phases of a batch run, over explicit state so the pipelined
/// loop can drain the front epoch bank while a Map worker owns the back
/// one. Metering is one plan-order pass on `net` (reset here, tagging a
/// fresh ledger epoch), so the report is bit-identical across execution
/// modes and `decode_threads` values.
fn finish_batch(
    plan: &Plan,
    states: &mut [NodeState],
    net: &mut BroadcastNet,
    backend: &mut dyn MapBackend,
    job: &JobSpec,
    decode_threads: usize,
    faults: &FaultSpec,
) -> Result<RunReport> {
    let k = plan.cluster.k();
    let q = k;
    let alloc = &plan.alloc;
    let n_sub = alloc.n_sub();
    net.reset();

    // ---- Shuffle phase: replay the decode schedule proven at plan
    // build time — no re-verification, no fixpoint. Under an `erase:`
    // fault the erasure mask is keyed on the fresh ledger epoch (== the
    // batch index on this executor), so which broadcasts vanish is a pure
    // function of (spec, batch) — identical across threads and modes.
    let map_time_s = plan.predicted.map_time_s;
    let outcome = match &faults.erase {
        None => {
            if decode_threads <= 1 {
                execute_planned(&plan.shuffle, &plan.schedule, states, net)?
            } else {
                execute_planned_parallel(
                    &plan.shuffle,
                    &plan.schedule,
                    states,
                    net,
                    decode_threads,
                )?
            }
        }
        Some(er) => {
            let epoch = net.ledger().epoch();
            let erased: Vec<bool> = plan
                .shuffle
                .coords()
                .iter()
                .map(|&(r, g, b)| er.erased(epoch, r, g, b))
                .collect();
            execute_planned_erased(&plan.shuffle, alloc, states, net, &erased, decode_threads)?
        }
    };
    let shuffle_time_s = net.report().elapsed_s;

    // ---- Reduce phase + oracle verification (all groups' oracles in
    // one Map pass; per-group recomputation tripled verify cost).
    let mut verified = true;
    let mut max_abs_err = 0f64;
    let oracles = workloads::native_reduce_oracle_all(job, q, n_sub);
    for node in 0..k {
        let payloads: Vec<&[u8]> = (0..n_sub)
            .map(|sub| {
                states[node]
                    .get_full(IvId { group: node, sub })
                    .ok_or_else(|| {
                        HetcdcError::Shuffle(format!(
                            "node {node} missing IV for subfile {sub}"
                        ))
                    })
            })
            .collect::<Result<_>>()?;
        let out = backend.reduce_group(job, &payloads)?;
        let oracle = &oracles[node];
        for (a, b) in out.iter().zip(oracle) {
            let err = (a - b).abs();
            max_abs_err = max_abs_err.max(err);
            // f32 accumulation tolerance, scaled to magnitude.
            if err > 1e-2 + 1e-4 * b.abs() {
                verified = false;
            }
        }
    }

    let load_equations =
        outcome.payload_bytes as f64 / (job.iv_bytes() as f64 * alloc.sp as f64);
    Ok(RunReport {
        k,
        n_files: job.n_files,
        n_sub,
        sp: alloc.sp,
        placement: plan.placer.clone(),
        coder: plan.coder.clone(),
        mode: plan.mode,
        backend: backend.name().to_string(),
        seed: job.seed,
        load_equations,
        plan_equations: plan.predicted.load_equations,
        payload_bytes: outcome.payload_bytes,
        wire_bytes: outcome.wire_bytes,
        messages: outcome.messages,
        map_time_s,
        shuffle_time_s,
        job_time_s: map_time_s + shuffle_time_s,
        verified,
        max_abs_err,
        replanned_without: None,
    })
}

/// Validate and store one node's Map output (shared by both Map paths).
fn store_mapped(
    st: &mut NodeState,
    held: &[usize],
    ivs: Vec<Vec<Vec<u8>>>,
) -> Result<()> {
    if ivs.len() != held.len() {
        return Err(HetcdcError::Backend(format!(
            "map returned {} subfiles, expected {}",
            ivs.len(),
            held.len()
        )));
    }
    for (groups, &sub) in ivs.into_iter().zip(held) {
        for (g, payload) in groups.into_iter().enumerate() {
            st.set_full(IvId { group: g, sub }, payload);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::NativeBackend;
    use crate::engine::plan::JobBuilder;
    use crate::model::cluster::ClusterSpec;

    fn cluster(storage: &[u64]) -> ClusterSpec {
        let mut c = ClusterSpec::homogeneous(storage.len(), 1, 1000.0);
        for (node, &m) in c.nodes.iter_mut().zip(storage) {
            node.storage = m;
        }
        c
    }

    #[test]
    fn one_plan_many_batches_identical_loads() {
        let c = cluster(&[6, 7, 7]);
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        let mut be = NativeBackend;
        let mut exec = Executor::with_config(&plan, ExecConfig::default()).unwrap();
        let mut reports = Vec::new();
        for batch in 0u64..3 {
            let r = exec.run_batch(&mut be, job.seed + batch).unwrap();
            assert!(r.verified, "batch {batch} failed verification");
            reports.push(r);
        }
        assert_eq!(exec.batches_run(), 3);
        // One metering epoch per batch.
        assert_eq!(exec.net_report().epoch, 3);
        // Per-round ledger sections mirror the plan's IR, and their byte
        // totals re-sum to the phase total.
        let nr = exec.net_report();
        assert_eq!(nr.rounds.len(), plan.shuffle.round_count());
        assert_eq!(
            nr.rounds.iter().map(|s| s.bytes).sum::<u64>(),
            nr.total_bytes
        );
        for r in &reports {
            // Measured equals predicted, batch after batch.
            assert_eq!(r.load_equations, plan.predicted.load_equations);
            assert_eq!(r.payload_bytes, plan.predicted.payload_bytes);
            assert_eq!(r.wire_bytes, plan.predicted.wire_bytes);
            assert_eq!(r.messages, plan.predicted.messages);
            assert_eq!(r.shuffle_time_s, plan.predicted.shuffle_time_s);
            assert_eq!(r.map_time_s, plan.predicted.map_time_s);
        }
        // Different seeds -> different data, same loads.
        assert_ne!(reports[0].seed, reports[1].seed);
    }

    #[test]
    fn parallel_mode_matches_serial_bit_for_bit() {
        let c = cluster(&[4, 8, 12]);
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        let mut be = NativeBackend;
        let mut serial = Executor::with_config(&plan, ExecConfig::default()).unwrap();
        let mut parallel =
            Executor::with_config(&plan, ExecConfig::default().mode(ExecMode::Parallel).threads(3))
                .unwrap();
        let a = serial.run_batch(&mut be, 42).unwrap();
        let b = parallel.run_batch(&mut be, 42).unwrap();
        assert!(a.verified && b.verified);
        assert_eq!(a.payload_bytes, b.payload_bytes);
        assert_eq!(a.wire_bytes, b.wire_bytes);
        assert_eq!(a.messages, b.messages);
        assert_eq!(a.shuffle_time_s.to_bits(), b.shuffle_time_s.to_bits());
        assert_eq!(serial.net_report(), parallel.net_report());
        let n_sub = plan.alloc.n_sub();
        for node in 0..3 {
            for g in 0..3 {
                for sub in 0..n_sub {
                    let iv = IvId { group: g, sub };
                    assert_eq!(serial.iv(node, iv), parallel.iv(node, iv), "node {node} {iv:?}");
                }
            }
        }
    }

    #[test]
    fn thread_knob_never_changes_results() {
        let c = cluster(&[6, 7, 7]);
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).build().unwrap();
        let mut be = NativeBackend;
        let mut reference = Executor::with_config(&plan, ExecConfig::default()).unwrap();
        let base = reference.run_batch(&mut be, 7).unwrap();
        for threads in [1usize, 2, 3, 8] {
            let cfg = ExecConfig::default().mode(ExecMode::Parallel).threads(threads);
            let mut exec = Executor::with_config(&plan, cfg).unwrap();
            let r = exec.run_batch(&mut be, 7).unwrap();
            assert_eq!(r.payload_bytes, base.payload_bytes, "threads={threads}");
            assert_eq!(r.shuffle_time_s.to_bits(), base.shuffle_time_s.to_bits());
            assert_eq!(reference.net_report(), exec.net_report(), "threads={threads}");
        }
    }

    #[test]
    fn pipelined_batches_match_serial_bit_for_bit() {
        let c = cluster(&[4, 8, 12]);
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        let mut be = NativeBackend;
        let seeds: Vec<u64> = (0..4u64).map(|b| 0x51EDu64 + b).collect();

        let mut serial = Executor::with_config(&plan, ExecConfig::default()).unwrap();
        let rs = serial.run_batches(&mut be, &seeds).unwrap();
        let mut pipelined =
            Executor::with_config(&plan, ExecConfig::default().mode(ExecMode::Pipelined).threads(2))
                .unwrap();
        let rp = pipelined.run_batches(&mut be, &seeds).unwrap();

        assert_eq!(rs.len(), seeds.len());
        assert_eq!(rp.len(), seeds.len());
        for (a, b) in rs.iter().zip(&rp) {
            assert!(a.verified && b.verified);
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.payload_bytes, b.payload_bytes);
            assert_eq!(a.wire_bytes, b.wire_bytes);
            assert_eq!(a.messages, b.messages);
            assert_eq!(a.shuffle_time_s.to_bits(), b.shuffle_time_s.to_bits());
            assert_eq!(a.max_abs_err.to_bits(), b.max_abs_err.to_bits());
        }
        assert_eq!(serial.batches_run(), pipelined.batches_run());
        // Bit-exact NetReport of the final batch, including the epoch tag.
        assert_eq!(serial.net_report(), pipelined.net_report());
        assert_eq!(pipelined.net_report().epoch, seeds.len() as u64);
        // Final post-shuffle state agrees at every (node, group, subfile).
        let n_sub = plan.alloc.n_sub();
        for node in 0..3 {
            for g in 0..3 {
                for sub in 0..n_sub {
                    let iv = IvId { group: g, sub };
                    assert_eq!(serial.iv(node, iv), pipelined.iv(node, iv), "node {node} {iv:?}");
                }
            }
        }
    }

    #[test]
    fn pipelined_single_batch_and_empty_runs_degrade_cleanly() {
        let c = cluster(&[6, 7, 7]);
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).build().unwrap();
        let mut be = NativeBackend;
        let mut exec =
            Executor::with_config(&plan, ExecConfig::default().mode(ExecMode::Pipelined)).unwrap();
        assert!(exec.run_batches(&mut be, &[]).unwrap().is_empty());
        let one = exec.run_batches(&mut be, &[9]).unwrap();
        assert_eq!(one.len(), 1);
        assert!(one[0].verified);
        assert_eq!(exec.batches_run(), 1);
    }

    /// Delegates to [`NativeBackend`] but refuses concurrent workers, so
    /// pipelined runs must degrade to the sequential loop.
    struct NoCloneBackend(NativeBackend);

    impl MapBackend for NoCloneBackend {
        fn map_subfiles(
            &mut self,
            job: &JobSpec,
            q: usize,
            subs: &[usize],
        ) -> Result<Vec<Vec<Vec<u8>>>> {
            self.0.map_subfiles(job, q, subs)
        }

        fn reduce_group(&mut self, job: &JobSpec, payloads: &[&[u8]]) -> Result<Vec<f64>> {
            self.0.reduce_group(job, payloads)
        }

        // worker_clone: default None.

        fn name(&self) -> &'static str {
            "native-noclone"
        }
    }

    #[test]
    fn pipelined_fallback_is_latched_and_bit_identical() {
        let c = cluster(&[6, 7, 7]);
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).build().unwrap();
        let seeds = [20u64, 21, 22];

        let mut be = NativeBackend;
        let mut reference = Executor::with_config(&plan, ExecConfig::default()).unwrap();
        let expect = reference.run_batches(&mut be, &seeds).unwrap();
        assert!(!reference.pipeline_degraded());

        let mut noclone = NoCloneBackend(NativeBackend);
        let mut exec = Executor::with_config(
            &plan,
            ExecConfig::default().mode(ExecMode::Pipelined).threads(2),
        )
        .unwrap();
        let got = exec.run_batches(&mut noclone, &seeds).unwrap();
        assert!(exec.pipeline_degraded(), "fallback must be observable");
        for (a, b) in expect.iter().zip(&got) {
            assert!(b.verified);
            assert_eq!(a.payload_bytes, b.payload_bytes);
            assert_eq!(a.shuffle_time_s.to_bits(), b.shuffle_time_s.to_bits());
        }
        assert_eq!(reference.net_report(), exec.net_report());
    }

    #[test]
    fn fault_override_shifts_clocks_but_never_bytes() {
        let c = cluster(&[4, 8, 12]);
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        let mut be = NativeBackend;

        let mut base = Executor::with_config(&plan, ExecConfig::default()).unwrap();
        let clean = base.run_batch(&mut be, 42).unwrap();
        assert_eq!(base.net_report().straggler_delay_s, 0.0);

        // Amplitude large enough that the jittered Map tail dwarfs the
        // shuffle duration, so some send provably stalls.
        let faults = FaultSpec::parse("straggle:seed=0xbe7c,amp=1000").unwrap();
        let cfg = ExecConfig::default().faults(faults.clone());
        let mut slow = Executor::with_config(&plan, cfg.clone()).unwrap();
        assert_eq!(slow.faults(), &faults);
        let jittered = slow.run_batch(&mut be, 42).unwrap();

        assert!(jittered.verified);
        assert_eq!(clean.payload_bytes, jittered.payload_bytes);
        assert_eq!(clean.wire_bytes, jittered.wire_bytes);
        assert_eq!(clean.messages, jittered.messages);
        assert_eq!(clean.map_time_s.to_bits(), jittered.map_time_s.to_bits());
        assert!(jittered.shuffle_time_s > clean.shuffle_time_s);
        assert!(slow.net_report().straggler_delay_s > 0.0);

        // The override is deterministic and mode-independent: a parallel
        // run under the same config is bit-identical.
        let mut slow_par =
            Executor::with_config(&plan, cfg.mode(ExecMode::Parallel).threads(3)).unwrap();
        let jittered_par = slow_par.run_batch(&mut be, 42).unwrap();
        assert_eq!(
            jittered.shuffle_time_s.to_bits(),
            jittered_par.shuffle_time_s.to_bits()
        );
        assert_eq!(slow.net_report(), slow_par.net_report());

        // Jitter survives the per-batch reset: a second batch meters the
        // same delay.
        let again = slow.run_batch(&mut be, 43).unwrap();
        assert_eq!(
            again.shuffle_time_s.to_bits(),
            jittered.shuffle_time_s.to_bits()
        );
    }

    #[test]
    fn mid_run_dropout_replans_and_resumes_on_survivors() {
        // `drop:node=i,at_batch=b`: batches before b run on the original
        // plan, the rest re-plan without the node and resume — and the
        // whole sequence is bit-identical across all three exec modes.
        let c = cluster(&[3, 4, 5, 6]);
        let mut job = JobSpec::terasort(8);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).build().unwrap();
        let node = (0..4)
            .find(|&n| plan.replan_without(n).is_ok())
            .expect("some node must be droppable without re-placement");
        let faults = FaultSpec::parse(&format!("drop:node={node},at_batch=2")).unwrap();
        let seeds = [50u64, 51, 52, 53];

        let run = |mode: ExecMode, threads: usize| {
            let cfg = ExecConfig {
                mode,
                threads,
                faults: Some(faults.clone()),
            };
            let mut be = NativeBackend;
            let mut exec = Executor::with_config(&plan, cfg).unwrap();
            let reports = exec.run_batches(&mut be, &seeds).unwrap();
            assert_eq!(exec.batches_run(), seeds.len() as u64);
            reports
        };
        let rs = run(ExecMode::Serial, 0);
        let rp = run(ExecMode::Parallel, 3);
        let rl = run(ExecMode::Pipelined, 2);

        assert_eq!(rs.len(), seeds.len());
        for (i, r) in rs.iter().enumerate() {
            assert!(r.verified, "batch {i} failed verification");
            assert_eq!(r.seed, seeds[i]);
            if i < 2 {
                assert_eq!(r.replanned_without, None, "batch {i} ran pre-drop");
                assert_eq!(r.k, 4);
            } else {
                assert_eq!(r.replanned_without, Some(node), "batch {i} ran post-drop");
                assert_eq!(r.k, 3);
            }
        }
        for other in [&rp, &rl] {
            for (a, b) in rs.iter().zip(other.iter()) {
                assert_eq!(a.seed, b.seed);
                assert_eq!(a.replanned_without, b.replanned_without);
                assert_eq!(a.payload_bytes, b.payload_bytes);
                assert_eq!(a.wire_bytes, b.wire_bytes);
                assert_eq!(a.messages, b.messages);
                assert_eq!(a.shuffle_time_s.to_bits(), b.shuffle_time_s.to_bits());
                assert_eq!(a.max_abs_err.to_bits(), b.max_abs_err.to_bits());
            }
        }
    }

    #[test]
    fn pipelined_recovery_serialization_latches_and_stays_bit_identical() {
        // Second pipeline_degraded trigger: a fault spec that forces a
        // retransmission round serializes recovery on the front stage —
        // the pipelined run must latch the degradation, warn once, and
        // still be bit-identical to serial. Erasures the plan absorbs
        // without retransmission must NOT trip the latch.
        let c = cluster(&[4, 8, 12]);
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        let seeds = [31u64, 32];
        let mut be = NativeBackend;
        let mut triggered = false;
        for (r, g, b) in plan.shuffle.coords() {
            let faults = FaultSpec::parse(&format!("erase:list={r}.{g}.{b}")).unwrap();
            let mut serial = Executor::with_config(
                &plan,
                ExecConfig::default().faults(faults.clone()),
            )
            .unwrap();
            let rs = serial.run_batches(&mut be, &seeds).unwrap();
            let mut pipe = Executor::with_config(
                &plan,
                ExecConfig::default()
                    .mode(ExecMode::Pipelined)
                    .threads(2)
                    .faults(faults),
            )
            .unwrap();
            let rp = pipe.run_batches(&mut be, &seeds).unwrap();
            for (a, b) in rs.iter().zip(&rp) {
                assert!(a.verified && b.verified);
                assert_eq!(a.payload_bytes, b.payload_bytes);
                assert_eq!(a.wire_bytes, b.wire_bytes);
                assert_eq!(a.shuffle_time_s.to_bits(), b.shuffle_time_s.to_bits());
            }
            assert_eq!(serial.net_report(), pipe.net_report());
            if pipe.net_report().retransmit_rounds > 0 {
                assert!(
                    pipe.pipeline_degraded(),
                    "retransmission rounds must latch pipeline degradation \
                     (erased {r}.{g}.{b})"
                );
                triggered = true;
            } else {
                assert!(
                    !pipe.pipeline_degraded(),
                    "absorbed erasure {r}.{g}.{b} must not trip the latch"
                );
            }
        }
        assert!(
            triggered,
            "some single erasure on the bare plan must need a retransmission"
        );
    }

    #[test]
    fn pipelined_batches_alternate_epoch_banks_without_aliasing() {
        // Two consecutive batches must never share one NodeState bank:
        // the Map of batch i+1 writes the back bank while batch i drains
        // the front, and a swap promotes back to front each batch.
        let c = cluster(&[6, 7, 7]);
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let plan = JobBuilder::new(&c, &job).build().unwrap();
        let mut be = NativeBackend;
        let mut exec =
            Executor::with_config(&plan, ExecConfig::default().mode(ExecMode::Pipelined).threads(2))
                .unwrap();

        // First pipelined run allocates both banks (one swap for 2 batches).
        exec.run_batches(&mut be, &[10, 11]).unwrap();
        let front0 = exec.states.as_ptr();
        let back0 = exec.back.as_ptr();
        assert_eq!(exec.back.len(), exec.states.len());
        assert_ne!(front0, back0, "the two epoch banks must be distinct allocations");

        // One more 2-batch run: exactly one more swap, so the banks have
        // alternated — front is the old back and vice versa.
        exec.run_batches(&mut be, &[12, 13]).unwrap();
        assert_eq!(exec.states.as_ptr(), back0, "banks must alternate per batch");
        assert_eq!(exec.back.as_ptr(), front0);
        assert_eq!(exec.batches_run(), 4);
        assert_eq!(exec.net_report().epoch, 4);
    }
}
