//! End-to-end job orchestration: placement -> Map -> coded Shuffle ->
//! Reduce -> verification, with the phase time model of DESIGN.md §4.

use super::backend::MapBackend;
use super::exec::{execute_shuffle, NodeState};
use crate::coding::plan::{plan_greedy, plan_k3, plan_uncoded, IvId, ShufflePlan};
use crate::coding::{cdc_multicast, decoder};
use crate::model::cluster::ClusterSpec;
use crate::model::job::{JobSpec, ShuffleMode};
use crate::placement::alloc::Allocation;
use crate::placement::{homogeneous, k3, lp_general};
use crate::workloads;

/// How files are placed on nodes before the job runs.
#[derive(Clone, Debug)]
pub enum PlacementStrategy {
    /// Theorem-1 optimal placement (K=3 only).
    OptimalK3,
    /// §V LP placement (any K).
    LpGeneral,
    /// Homogeneous r-redundant placement of [2] (requires equal storage
    /// `M_k = r·N/K`; `r` derived from storage).
    Homogeneous,
    /// Storage-oblivious baseline: provisions every node to the SMALLEST
    /// storage and runs the homogeneous memory-sharing scheme — what a
    /// heterogeneity-unaware deployment does (the [13] failure mode the
    /// paper's introduction cites). Wastes surplus storage.
    Oblivious,
    /// Caller-provided allocation.
    Custom(Allocation),
}

impl PlacementStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementStrategy::OptimalK3 => "optimal-k3",
            PlacementStrategy::LpGeneral => "lp-general",
            PlacementStrategy::Homogeneous => "homogeneous",
            PlacementStrategy::Oblivious => "oblivious",
            PlacementStrategy::Custom(_) => "custom",
        }
    }
}

/// Everything measured in one run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub k: usize,
    pub n_files: u64,
    pub n_sub: usize,
    pub sp: u32,
    pub placement: String,
    pub mode: ShuffleMode,
    pub backend: String,
    /// Measured shuffle load in IV-equation units (payload bytes / T·4·sp).
    pub load_equations: f64,
    /// Plan-predicted load (should equal measured for whole-IV plans).
    pub plan_equations: f64,
    pub payload_bytes: u64,
    pub wire_bytes: u64,
    pub messages: u64,
    /// Phase time model (virtual seconds).
    pub map_time_s: f64,
    pub shuffle_time_s: f64,
    pub job_time_s: f64,
    /// Reduce outputs matched the single-node oracle.
    pub verified: bool,
    /// Max |output − oracle| over all groups (absolute).
    pub max_abs_err: f64,
}

impl RunReport {
    /// Fraction of (virtual) job time spent shuffling — §I's 33–70% story.
    pub fn shuffle_fraction(&self) -> f64 {
        if self.job_time_s == 0.0 {
            0.0
        } else {
            self.shuffle_time_s / self.job_time_s
        }
    }

    /// Machine-readable report (for `hetcdc run --json` and experiment
    /// archiving in EXPERIMENTS.md).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            m.insert(k.to_string(), v);
        };
        put("k", Json::Num(self.k as f64));
        put("n_files", Json::Num(self.n_files as f64));
        put("n_sub", Json::Num(self.n_sub as f64));
        put("sp", Json::Num(self.sp as f64));
        put("placement", Json::Str(self.placement.clone()));
        put("mode", Json::Str(format!("{:?}", self.mode)));
        put("backend", Json::Str(self.backend.clone()));
        put("load_equations", Json::Num(self.load_equations));
        put("plan_equations", Json::Num(self.plan_equations));
        put("payload_bytes", Json::Num(self.payload_bytes as f64));
        put("wire_bytes", Json::Num(self.wire_bytes as f64));
        put("messages", Json::Num(self.messages as f64));
        put("map_time_s", Json::Num(self.map_time_s));
        put("shuffle_time_s", Json::Num(self.shuffle_time_s));
        put("job_time_s", Json::Num(self.job_time_s));
        put("shuffle_fraction", Json::Num(self.shuffle_fraction()));
        put("verified", Json::Bool(self.verified));
        put("max_abs_err", Json::Num(self.max_abs_err));
        Json::Obj(m)
    }
}

/// The engine: borrows cluster, job, and a compute backend.
pub struct Engine<'a> {
    pub cluster: &'a ClusterSpec,
    pub job: &'a JobSpec,
    pub backend: &'a mut dyn MapBackend,
}

impl<'a> Engine<'a> {
    pub fn new(
        cluster: &'a ClusterSpec,
        job: &'a JobSpec,
        backend: &'a mut dyn MapBackend,
    ) -> Self {
        Engine {
            cluster,
            job,
            backend,
        }
    }

    /// Build the allocation for a strategy.
    pub fn place(&self, strategy: &PlacementStrategy) -> Result<Allocation, String> {
        let k = self.cluster.k();
        let n = self.job.n_files;
        match strategy {
            PlacementStrategy::OptimalK3 => {
                let p = self.cluster.params3(n)?;
                Ok(k3::optimal_allocation(&p))
            }
            PlacementStrategy::LpGeneral => {
                let p = self.cluster.params_k(n)?;
                let sol = lp_general::solve_general(&p, lp_general::DEFAULT_COLLECTION_CAP)
                    .map_err(|e| format!("LP: {e}"))?;
                Ok(lp_general::allocation_from_solution(&p, &sol))
            }
            PlacementStrategy::Homogeneous => {
                let storage = self.cluster.storage();
                let m0 = storage[0];
                if !storage.iter().all(|&m| m == m0) {
                    return Err("homogeneous placement needs equal storage".into());
                }
                let r = (m0 * k as u64) / n;
                if r * n != m0 * k as u64 || r == 0 {
                    return Err(format!(
                        "storage {m0} is not r·N/K for any integer r (N={n}, K={k})"
                    ));
                }
                Ok(homogeneous::symmetric_allocation(k, r as usize, n))
            }
            PlacementStrategy::Oblivious => {
                let m_min = *self.cluster.storage().iter().min().unwrap();
                let share = crate::placement::memshare::split(k, m_min, n)?;
                Ok(share.allocation())
            }
            PlacementStrategy::Custom(a) => Ok(a.clone()),
        }
    }

    /// Build the shuffle plan for an allocation.
    pub fn plan(
        &self,
        alloc: &Allocation,
        strategy: &PlacementStrategy,
        mode: ShuffleMode,
    ) -> ShufflePlan {
        match mode {
            ShuffleMode::Uncoded => plan_uncoded(alloc),
            ShuffleMode::Coded => match strategy {
                PlacementStrategy::Homogeneous => {
                    let r = alloc.holders[0].count_ones() as usize;
                    cdc_multicast::plan_homogeneous(alloc, r)
                }
                PlacementStrategy::Oblivious => {
                    let m_min = *self.cluster.storage().iter().min().unwrap();
                    match crate::placement::memshare::split(
                        alloc.k,
                        m_min,
                        self.job.n_files,
                    ) {
                        Ok(share) => share.plan(alloc),
                        Err(_) if alloc.k == 3 => plan_k3(alloc),
                        Err(_) => plan_greedy(alloc),
                    }
                }
                _ if alloc.k == 3 => plan_k3(alloc),
                _ => plan_greedy(alloc),
            },
        }
    }

    /// Run the full job. See [`RunReport`].
    pub fn run(
        &mut self,
        strategy: &PlacementStrategy,
        mode: ShuffleMode,
    ) -> Result<RunReport, String> {
        let k = self.cluster.k();
        self.job.validate(k)?;
        let q = k; // Q = K (one reduce-function group per node, as in the paper)
        let alloc = self.place(strategy)?;
        // Capacities are upper bounds at run time; optimal placements fill
        // them exactly, the oblivious baseline deliberately under-fills.
        alloc
            .validate_le(&self.cluster.storage(), self.job.n_files)
            .map_err(|e| format!("placement invalid: {e}"))?;
        let n_sub = alloc.n_sub();
        let iv_bytes = self.job.iv_bytes();

        // ---- Map phase: every node computes all groups' IVs of its
        // subfiles; the time model takes the slowest node (barrier).
        let mut states: Vec<NodeState> = (0..k)
            .map(|_| NodeState::new(q, n_sub, iv_bytes))
            .collect();
        let mut map_time_s: f64 = 0.0;
        for node in 0..k {
            let held: Vec<usize> = (0..n_sub)
                .filter(|&s| alloc.holders[s] & (1 << node) != 0)
                .collect();
            let files_equiv = held.len() as f64 / alloc.sp as f64;
            map_time_s = map_time_s
                .max(files_equiv / self.cluster.nodes[node].map_files_per_s.max(1e-9));
            let ivs = self.backend.map_subfiles(self.job, q, &held)?;
            for (pos, &sub) in held.iter().enumerate() {
                for (g, payload) in ivs[pos].iter().enumerate() {
                    states[node].set_full(IvId { group: g, sub }, payload.clone());
                }
            }
        }

        // ---- Shuffle phase.
        let plan = self.plan(&alloc, strategy, mode);
        let report = decoder::verify(&alloc, &plan);
        if !report.is_complete() {
            return Err(format!(
                "internal: plan not decodable; missing {:?}",
                report.missing
            ));
        }
        let mut net = self.cluster.network();
        let outcome = execute_shuffle(&plan, &mut states, &mut net)?;
        let shuffle_time_s = net.report().elapsed_s;

        // ---- Reduce phase + oracle verification (all groups' oracles in
        // one Map pass; per-group recomputation tripled verify cost).
        let mut verified = true;
        let mut max_abs_err = 0f64;
        let oracles = workloads::native_reduce_oracle_all(self.job, q, n_sub);
        for node in 0..k {
            let payloads: Vec<&[u8]> = (0..n_sub)
                .map(|sub| {
                    states[node]
                        .get_full(IvId { group: node, sub })
                        .ok_or_else(|| format!("node {node} missing IV for subfile {sub}"))
                })
                .collect::<Result<_, _>>()?;
            let out = self.backend.reduce_group(self.job, &payloads)?;
            let oracle = &oracles[node];
            for (a, b) in out.iter().zip(oracle) {
                let err = (a - b).abs();
                max_abs_err = max_abs_err.max(err);
                // f32 accumulation tolerance, scaled to magnitude.
                if err > 1e-2 + 1e-4 * b.abs() {
                    verified = false;
                }
            }
        }

        let load_equations = outcome.payload_bytes as f64 / (iv_bytes as f64 * alloc.sp as f64);
        Ok(RunReport {
            k,
            n_files: self.job.n_files,
            n_sub,
            sp: alloc.sp,
            placement: strategy.name().to_string(),
            mode,
            backend: self.backend.name().to_string(),
            load_equations,
            plan_equations: plan.load_equations(&alloc),
            payload_bytes: outcome.payload_bytes,
            wire_bytes: outcome.wire_bytes,
            messages: outcome.messages,
            map_time_s,
            shuffle_time_s,
            job_time_s: map_time_s + shuffle_time_s,
            verified,
            max_abs_err,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::NativeBackend;
    use crate::prop;
    use crate::theory::load::{lstar, uncoded};

    fn run_one(
        storage: [u64; 3],
        n: u64,
        job: JobSpec,
        strategy: PlacementStrategy,
        mode: ShuffleMode,
    ) -> RunReport {
        let mut cluster = ClusterSpec::homogeneous(3, 1, 1000.0);
        for (node, &m) in cluster.nodes.iter_mut().zip(storage.iter()) {
            node.storage = m;
        }
        let _ = n;
        let mut be = NativeBackend;
        let mut engine = Engine::new(&cluster, &job, &mut be);
        engine.run(&strategy, mode).unwrap()
    }

    #[test]
    fn paper_example_measured_load_is_12() {
        let job = JobSpec::wordcount(12);
        let r = run_one(
            [6, 7, 7],
            12,
            job,
            PlacementStrategy::OptimalK3,
            ShuffleMode::Coded,
        );
        assert!(r.verified, "reduce outputs mismatched oracle: {}", r.max_abs_err);
        assert_eq!(r.load_equations, 12.0);
        assert_eq!(r.plan_equations, 12.0);
    }

    #[test]
    fn paper_example_uncoded_load_is_16() {
        let job = JobSpec::wordcount(12);
        let r = run_one(
            [6, 7, 7],
            12,
            job,
            PlacementStrategy::OptimalK3,
            ShuffleMode::Uncoded,
        );
        assert!(r.verified);
        assert_eq!(r.load_equations, 16.0);
    }

    #[test]
    fn terasort_exact_verification() {
        let job = JobSpec::terasort(12);
        let r = run_one(
            [6, 7, 7],
            12,
            job,
            PlacementStrategy::OptimalK3,
            ShuffleMode::Coded,
        );
        assert!(r.verified);
        assert_eq!(r.max_abs_err, 0.0, "integer pipeline must be exact");
    }

    #[test]
    fn homogeneous_strategy_matches_li_curve() {
        let mut cluster = ClusterSpec::homogeneous(3, 8, 1000.0);
        cluster.latency_ms = 0.0;
        let job = JobSpec::terasort(12);
        let mut be = NativeBackend;
        let mut engine = Engine::new(&cluster, &job, &mut be);
        let r = engine
            .run(&PlacementStrategy::Homogeneous, ShuffleMode::Coded)
            .unwrap();
        assert!(r.verified);
        // r = MK/N = 2 -> L = N(K−r)/r = 6.
        assert!((r.load_equations - 6.0).abs() < 1e-9, "{}", r.load_equations);
    }

    #[test]
    fn shuffle_fraction_reported() {
        let job = JobSpec::wordcount(12);
        let r = run_one(
            [6, 7, 7],
            12,
            job,
            PlacementStrategy::OptimalK3,
            ShuffleMode::Uncoded,
        );
        assert!(r.shuffle_fraction() > 0.0 && r.shuffle_fraction() < 1.0);
    }

    #[test]
    fn prop_engine_measured_equals_theory_k3() {
        // End-to-end: measured coded load == L*, measured uncoded ==
        // 3N − M, outputs verified — on random K=3 instances.
        prop::run("engine == theory", 25, |g| {
            let n = g.u64_in(2..=10);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(p) = crate::theory::params::Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let mut job = JobSpec::terasort(n);
            job.t = 8;
            job.keys_per_file = 32;
            let coded = run_one(
                [m1, m2, m3],
                n,
                job.clone(),
                PlacementStrategy::OptimalK3,
                ShuffleMode::Coded,
            );
            let unc = run_one(
                [m1, m2, m3],
                n,
                job,
                PlacementStrategy::OptimalK3,
                ShuffleMode::Uncoded,
            );
            if !coded.verified || !unc.verified {
                return Err(format!("{p}: verification failed"));
            }
            prop::check(
                (coded.load_equations - lstar(&p)).abs() < 1e-9
                    && (unc.load_equations - uncoded(&p)).abs() < 1e-9,
                format!(
                    "{p}: coded {} vs L* {}; uncoded {} vs {}",
                    coded.load_equations,
                    lstar(&p),
                    unc.load_equations,
                    uncoded(&p)
                ),
            )
        });
    }

    #[test]
    fn oblivious_baseline_pays_heterogeneity_penalty() {
        // (4,8,12,12): heterogeneity-aware L* = 3N−(M1+M) = 36−28 = 8;
        // oblivious provisions all nodes to min = 4 (r = 1) -> L = 24.
        let job = JobSpec::terasort(12);
        let aware = run_one(
            [4, 8, 12],
            12,
            job.clone(),
            PlacementStrategy::OptimalK3,
            ShuffleMode::Coded,
        );
        let oblivious = run_one(
            [4, 8, 12],
            12,
            job,
            PlacementStrategy::Oblivious,
            ShuffleMode::Coded,
        );
        assert!(aware.verified && oblivious.verified);
        let p = crate::theory::params::Params3::new(4, 8, 12, 12).unwrap();
        assert_eq!(aware.load_equations, crate::theory::load::lstar(&p));
        assert_eq!(
            oblivious.load_equations,
            crate::theory::load::oblivious(&p).unwrap()
        );
        assert!(
            oblivious.load_equations > 2.0 * aware.load_equations,
            "expected a large heterogeneity penalty: {} vs {}",
            oblivious.load_equations,
            aware.load_equations
        );
    }

    #[test]
    fn lp_strategy_runs_k4() {
        let mut cluster = ClusterSpec::homogeneous(4, 5, 1000.0);
        cluster.nodes[0].storage = 3;
        cluster.nodes[1].storage = 4;
        cluster.nodes[2].storage = 5;
        cluster.nodes[3].storage = 6;
        let mut job = JobSpec::terasort(8);
        job.t = 8;
        job.keys_per_file = 32;
        let mut be = NativeBackend;
        let mut engine = Engine::new(&cluster, &job, &mut be);
        let coded = engine
            .run(&PlacementStrategy::LpGeneral, ShuffleMode::Coded)
            .unwrap();
        let unc = engine
            .run(&PlacementStrategy::LpGeneral, ShuffleMode::Uncoded)
            .unwrap();
        assert!(coded.verified && unc.verified);
        assert!(coded.load_equations <= unc.load_equations);
    }
}
