//! [`RunReport`] and the [`Engine`] facade.
//!
//! The staged pipeline ([`crate::engine::JobBuilder`] →
//! [`crate::engine::Plan`] → [`crate::engine::Executor`]) is the primary
//! API; [`Engine`] is the one-shot convenience wrapper for callers that
//! run a single batch: it builds a plan, executes it once, and returns
//! the report. Serving paths that run many batches should build the plan
//! once (or take it from a [`crate::engine::PlanCache`]) and reuse an
//! [`crate::engine::Executor`].

use super::backend::MapBackend;
use super::executor::{ExecConfig, Executor};
use super::plan::{shape_fingerprint, JobBuilder, Plan};
use crate::error::{HetcdcError, Result};
use crate::model::cluster::ClusterSpec;
use crate::model::job::{JobSpec, ShuffleMode};
use crate::placement::alloc::Allocation;

/// Everything measured in one batch run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub k: usize,
    pub n_files: u64,
    pub n_sub: usize,
    pub sp: u32,
    /// Placer registry name that produced the allocation.
    pub placement: String,
    /// Coder registry name that produced the shuffle plan.
    pub coder: String,
    pub mode: ShuffleMode,
    pub backend: String,
    /// Data seed of this batch.
    pub seed: u64,
    /// Measured shuffle load in IV-equation units (payload bytes / T·4·sp).
    pub load_equations: f64,
    /// Plan-predicted load (equals measured for the built-in coders).
    pub plan_equations: f64,
    pub payload_bytes: u64,
    pub wire_bytes: u64,
    pub messages: u64,
    /// Phase time model (virtual seconds).
    pub map_time_s: f64,
    pub shuffle_time_s: f64,
    pub job_time_s: f64,
    /// Reduce outputs matched the single-node oracle.
    pub verified: bool,
    /// Max |output − oracle| over all groups (absolute).
    pub max_abs_err: f64,
    /// Set on batches executed *after* a mid-run dropout
    /// (`drop:node=i,at_batch=b`): the index of the node the survivor
    /// plan was rebuilt without ([`crate::engine::Plan::replan_without`]).
    /// `None` on every fault-free batch, and omitted from JSON so
    /// fault-free reports stay byte-identical to pre-dropout artifacts.
    pub replanned_without: Option<usize>,
}

impl RunReport {
    /// Fraction of (virtual) job time spent shuffling — §I's 33–70% story.
    pub fn shuffle_fraction(&self) -> f64 {
        if self.job_time_s == 0.0 {
            0.0
        } else {
            self.shuffle_time_s / self.job_time_s
        }
    }

    /// Machine-readable report (for `hetcdc run --json` and experiment
    /// archiving in EXPERIMENTS.md).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            m.insert(k.to_string(), v);
        };
        put("k", Json::Num(self.k as f64));
        put("n_files", Json::Num(self.n_files as f64));
        put("n_sub", Json::Num(self.n_sub as f64));
        put("sp", Json::Num(self.sp as f64));
        put("placement", Json::Str(self.placement.clone()));
        put("coder", Json::Str(self.coder.clone()));
        put("mode", Json::Str(format!("{:?}", self.mode)));
        put("backend", Json::Str(self.backend.clone()));
        // Hex string: JSON numbers are f64 here and would round u64
        // seeds above 2^53 (see JobSpec::to_json).
        put("seed", Json::Str(format!("{:#x}", self.seed)));
        put("load_equations", Json::Num(self.load_equations));
        put("plan_equations", Json::Num(self.plan_equations));
        put("payload_bytes", Json::Num(self.payload_bytes as f64));
        put("wire_bytes", Json::Num(self.wire_bytes as f64));
        put("messages", Json::Num(self.messages as f64));
        put("map_time_s", Json::Num(self.map_time_s));
        put("shuffle_time_s", Json::Num(self.shuffle_time_s));
        put("job_time_s", Json::Num(self.job_time_s));
        put("shuffle_fraction", Json::Num(self.shuffle_fraction()));
        put("verified", Json::Bool(self.verified));
        put("max_abs_err", Json::Num(self.max_abs_err));
        if let Some(node) = self.replanned_without {
            put("replanned_without", Json::Num(node as f64));
        }
        Json::Obj(m)
    }
}

/// One-shot facade: borrows cluster, job, and a compute backend; each
/// `run_*` builds a fresh [`Plan`] and executes one batch.
pub struct Engine<'a> {
    pub cluster: &'a ClusterSpec,
    pub job: &'a JobSpec,
    pub backend: &'a mut dyn MapBackend,
}

impl<'a> Engine<'a> {
    pub fn new(
        cluster: &'a ClusterSpec,
        job: &'a JobSpec,
        backend: &'a mut dyn MapBackend,
    ) -> Self {
        Engine {
            cluster,
            job,
            backend,
        }
    }

    /// Build a plan with the named placer (see
    /// [`crate::placement::placer_by_name`]) and run one batch.
    pub fn run(&mut self, placer: &str, mode: ShuffleMode) -> Result<RunReport> {
        let plan = JobBuilder::new(self.cluster, self.job)
            .placer(placer)
            .mode(mode)
            .build()?;
        self.run_plan(&plan)
    }

    /// Like [`Engine::run`] with a caller-provided allocation.
    pub fn run_custom(&mut self, alloc: &Allocation, mode: ShuffleMode) -> Result<RunReport> {
        let plan = JobBuilder::new(self.cluster, self.job)
            .custom_allocation(alloc.clone())
            .mode(mode)
            .build()?;
        self.run_plan(&plan)
    }

    /// Execute one batch of a pre-built plan. The plan must have been
    /// built for this engine's cluster/job shape (the data seed may
    /// differ) — a plan for some other shape would silently execute its
    /// own embedded cluster and job instead.
    pub fn run_plan(&mut self, plan: &Plan) -> Result<RunReport> {
        if !plan.shape_matches(self.cluster, self.job) {
            return Err(HetcdcError::PlanMismatch(format!(
                "plan was built for shape {:016x}, which is not this engine's \
                 cluster/job shape ({:016x}); rebuild the plan",
                plan.fingerprint,
                shape_fingerprint(self.cluster, self.job)
            )));
        }
        // The engine's job picks the data batch; the plan only fixes the
        // shape (its embedded seed is whatever job first built it). The
        // default config meters under the plan's own fault spec.
        Executor::with_config(plan, ExecConfig::default())?.run_batch(self.backend, self.job.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::NativeBackend;
    use crate::prop;
    use crate::theory::load::{lstar, uncoded};

    fn run_one(
        storage: [u64; 3],
        job: JobSpec,
        placer: &str,
        mode: ShuffleMode,
    ) -> RunReport {
        let mut cluster = ClusterSpec::homogeneous(3, 1, 1000.0);
        for (node, &m) in cluster.nodes.iter_mut().zip(storage.iter()) {
            node.storage = m;
        }
        let mut be = NativeBackend;
        let mut engine = Engine::new(&cluster, &job, &mut be);
        engine.run(placer, mode).unwrap()
    }

    #[test]
    fn paper_example_measured_load_is_12() {
        let job = JobSpec::wordcount(12);
        let r = run_one([6, 7, 7], job, "optimal-k3", ShuffleMode::Coded);
        assert!(r.verified, "reduce outputs mismatched oracle: {}", r.max_abs_err);
        assert_eq!(r.load_equations, 12.0);
        assert_eq!(r.plan_equations, 12.0);
        assert_eq!(r.coder, "pairing");
    }

    #[test]
    fn paper_example_uncoded_load_is_16() {
        let job = JobSpec::wordcount(12);
        let r = run_one([6, 7, 7], job, "optimal-k3", ShuffleMode::Uncoded);
        assert!(r.verified);
        assert_eq!(r.load_equations, 16.0);
        assert_eq!(r.coder, "uncoded");
    }

    #[test]
    fn terasort_exact_verification() {
        let job = JobSpec::terasort(12);
        let r = run_one([6, 7, 7], job, "optimal-k3", ShuffleMode::Coded);
        assert!(r.verified);
        assert_eq!(r.max_abs_err, 0.0, "integer pipeline must be exact");
    }

    #[test]
    fn homogeneous_strategy_matches_li_curve() {
        let mut cluster = ClusterSpec::homogeneous(3, 8, 1000.0);
        cluster.latency_ms = 0.0;
        let job = JobSpec::terasort(12);
        let mut be = NativeBackend;
        let mut engine = Engine::new(&cluster, &job, &mut be);
        let r = engine.run("homogeneous", ShuffleMode::Coded).unwrap();
        assert!(r.verified);
        assert_eq!(r.coder, "multicast");
        // r = MK/N = 2 -> L = N(K−r)/r = 6.
        assert!((r.load_equations - 6.0).abs() < 1e-9, "{}", r.load_equations);
    }

    #[test]
    fn shuffle_fraction_reported() {
        let job = JobSpec::wordcount(12);
        let r = run_one([6, 7, 7], job, "optimal-k3", ShuffleMode::Uncoded);
        assert!(r.shuffle_fraction() > 0.0 && r.shuffle_fraction() < 1.0);
    }

    #[test]
    fn prop_engine_measured_equals_theory_k3() {
        // End-to-end: measured coded load == L*, measured uncoded ==
        // 3N − M, outputs verified — on random K=3 instances.
        prop::run("engine == theory", 25, |g| {
            let n = g.u64_in(2..=10);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(p) = crate::theory::params::Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let mut job = JobSpec::terasort(n);
            job.t = 8;
            job.keys_per_file = 32;
            let coded = run_one([m1, m2, m3], job.clone(), "optimal-k3", ShuffleMode::Coded);
            let unc = run_one([m1, m2, m3], job, "optimal-k3", ShuffleMode::Uncoded);
            if !coded.verified || !unc.verified {
                return prop::fail(format!("{p}: verification failed"));
            }
            prop::check(
                (coded.load_equations - lstar(&p)).abs() < 1e-9
                    && (unc.load_equations - uncoded(&p)).abs() < 1e-9,
                format!(
                    "{p}: coded {} vs L* {}; uncoded {} vs {}",
                    coded.load_equations,
                    lstar(&p),
                    unc.load_equations,
                    uncoded(&p)
                ),
            )
        });
    }

    #[test]
    fn oblivious_baseline_pays_heterogeneity_penalty() {
        // (4,8,12,12): heterogeneity-aware L* = 3N−(M1+M) = 36−28 = 8;
        // oblivious provisions all nodes to min = 4 (r = 1) -> L = 24.
        let job = JobSpec::terasort(12);
        let aware = run_one([4, 8, 12], job.clone(), "optimal-k3", ShuffleMode::Coded);
        let oblivious = run_one([4, 8, 12], job, "oblivious", ShuffleMode::Coded);
        assert!(aware.verified && oblivious.verified);
        let p = crate::theory::params::Params3::new(4, 8, 12, 12).unwrap();
        assert_eq!(aware.load_equations, crate::theory::load::lstar(&p));
        assert_eq!(
            oblivious.load_equations,
            crate::theory::load::oblivious(&p).unwrap()
        );
        assert!(
            oblivious.load_equations > 2.0 * aware.load_equations,
            "expected a large heterogeneity penalty: {} vs {}",
            oblivious.load_equations,
            aware.load_equations
        );
    }

    #[test]
    fn lp_strategy_runs_k4() {
        let mut cluster = ClusterSpec::homogeneous(4, 5, 1000.0);
        cluster.nodes[0].storage = 3;
        cluster.nodes[1].storage = 4;
        cluster.nodes[2].storage = 5;
        cluster.nodes[3].storage = 6;
        let mut job = JobSpec::terasort(8);
        job.t = 8;
        job.keys_per_file = 32;
        let mut be = NativeBackend;
        let mut engine = Engine::new(&cluster, &job, &mut be);
        let coded = engine.run("lp-general", ShuffleMode::Coded).unwrap();
        let unc = engine.run("lp-general", ShuffleMode::Uncoded).unwrap();
        assert!(coded.verified && unc.verified);
        assert!(coded.load_equations <= unc.load_equations);
    }

    #[test]
    fn run_plan_rejects_foreign_shape() {
        let cluster_a = ClusterSpec::homogeneous(3, 8, 1000.0);
        let cluster_b = ClusterSpec::homogeneous(3, 9, 1000.0);
        let job = JobSpec::terasort(12);
        let plan = JobBuilder::new(&cluster_a, &job).build().unwrap();
        let mut be = NativeBackend;
        let err = Engine::new(&cluster_b, &job, &mut be)
            .run_plan(&plan)
            .unwrap_err();
        assert!(matches!(err, crate::HetcdcError::PlanMismatch(_)), "{err}");
        // Same shape, different seed: runs, and the ENGINE's seed picks
        // the batch — not the seed embedded in the plan.
        let mut reseeded = job.clone();
        reseeded.seed ^= 0xFFFF;
        let r = Engine::new(&cluster_a, &reseeded, &mut be)
            .run_plan(&plan)
            .unwrap();
        assert!(r.verified);
        assert_eq!(r.seed, reseeded.seed);
    }

    #[test]
    fn custom_allocation_runs() {
        // Fig 2's sequential allocation on (6,7,7,12) codes to 13.
        let mut holders = vec![0u32; 12];
        for f in 0..6 {
            holders[f] |= 0b001;
        }
        holders[0] |= 0b010;
        for f in 6..12 {
            holders[f] |= 0b010;
        }
        for f in 1..8 {
            holders[f] |= 0b100;
        }
        let alloc = Allocation::new(3, 1, holders);
        let mut cluster = ClusterSpec::homogeneous(3, 1, 1000.0);
        for (node, m) in cluster.nodes.iter_mut().zip([6u64, 7, 7]) {
            node.storage = m;
        }
        let mut job = JobSpec::terasort(12);
        job.t = 8;
        job.keys_per_file = 32;
        let mut be = NativeBackend;
        let r = Engine::new(&cluster, &job, &mut be)
            .run_custom(&alloc, ShuffleMode::Coded)
            .unwrap();
        assert!(r.verified);
        assert_eq!(r.placement, "custom");
        assert_eq!(r.load_equations, 13.0);
    }
}
