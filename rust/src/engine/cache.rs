//! [`PlanCache`]: memoizes built [`Plan`]s by (cluster shape, job shape,
//! strategy) — the heavy-traffic path, where millions of identical job
//! shapes must not re-run the LP or re-verify decodability per request.
//!
//! Keys are the *exact* shapes (not hashes of them), so a cache hit is
//! guaranteed to be the right plan; the compact
//! [`crate::engine::plan::shape_fingerprint`] is only a display/telemetry
//! identity. Eviction is FIFO at a fixed capacity — plan reuse patterns
//! are dominated by a small working set of job shapes.

use super::plan::{JobBuilder, Plan};
use crate::error::Result;
use crate::model::cluster::ClusterSpec;
use crate::model::job::{JobSpec, ShuffleMode, WorkloadKind};
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

/// Exact cache key: everything [`JobBuilder::build`] reads except the
/// data seed. Float fields are keyed by their bit patterns.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    storage: Vec<u64>,
    uplink_bits: Vec<u64>,
    map_rate_bits: Vec<u64>,
    latency_bits: u64,
    /// Canonical topology spec string (`"shared"` by default) — a rack
    /// cluster and its shared-medium twin must never share a plan.
    topology: String,
    /// Canonical fault spec string (`"none"` by default) — a repair-f
    /// plan has extra rounds and a straggling one different clocks, so
    /// neither may share a plan with its fault-free twin.
    faults: String,
    workload: WorkloadKind,
    n_files: u64,
    t: usize,
    vocab: usize,
    keys_per_file: usize,
    placer: String,
    coder: Option<String>,
    mode: ShuffleMode,
}

impl PlanKey {
    fn new(
        cluster: &ClusterSpec,
        job: &JobSpec,
        placer: &str,
        coder: Option<&str>,
        mode: ShuffleMode,
    ) -> Self {
        PlanKey {
            storage: cluster.storage(),
            uplink_bits: cluster.nodes.iter().map(|n| n.uplink_mbps.to_bits()).collect(),
            map_rate_bits: cluster
                .nodes
                .iter()
                .map(|n| n.map_files_per_s.to_bits())
                .collect(),
            latency_bits: cluster.latency_ms.to_bits(),
            topology: cluster.topology.spec(),
            faults: cluster.faults.spec(),
            workload: job.workload,
            n_files: job.n_files,
            t: job.t,
            vocab: job.vocab,
            keys_per_file: job.keys_per_file,
            placer: placer.to_string(),
            coder: coder.map(String::from),
            mode,
        }
    }
}

/// FIFO-bounded memo of built plans. Plans are handed out as [`Arc`]s:
/// cheap to clone into per-request [`crate::engine::Executor`]s.
pub struct PlanCache {
    capacity: usize,
    map: HashMap<PlanKey, Arc<Plan>>,
    order: VecDeque<PlanKey>,
    pub hits: u64,
    pub misses: u64,
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new(64)
    }
}

impl PlanCache {
    pub fn new(capacity: usize) -> Self {
        PlanCache {
            capacity: capacity.max(1),
            map: HashMap::new(),
            order: VecDeque::new(),
            hits: 0,
            misses: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.order.clear();
    }

    /// Return the cached plan for this shape, building (and caching) it on
    /// a miss. Build errors are not cached.
    ///
    /// The data seed is deliberately not part of the key, so a hit may
    /// return a plan whose embedded `job.seed` is from the job that first
    /// built it. Run batches with an explicit seed —
    /// `Executor::run_batch(backend, my_job.seed)` — rather than the
    /// seed-implicit `Executor::run`.
    pub fn get_or_build(
        &mut self,
        cluster: &ClusterSpec,
        job: &JobSpec,
        placer: &str,
        coder: Option<&str>,
        mode: ShuffleMode,
    ) -> Result<Arc<Plan>> {
        let key = PlanKey::new(cluster, job, placer, coder, mode);
        if let Some(plan) = self.map.get(&key) {
            self.hits += 1;
            return Ok(plan.clone());
        }
        self.misses += 1;
        let mut builder = JobBuilder::new(cluster, job).placer(placer).mode(mode);
        if let Some(c) = coder {
            builder = builder.coder(c);
        }
        let plan = Arc::new(builder.build()?);
        if self.map.len() >= self.capacity {
            if let Some(oldest) = self.order.pop_front() {
                self.map.remove(&oldest);
            }
        }
        self.map.insert(key.clone(), plan.clone());
        self.order.push_back(key);
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster(storage: &[u64]) -> ClusterSpec {
        let mut c = ClusterSpec::homogeneous(storage.len(), 1, 1000.0);
        for (node, &m) in c.nodes.iter_mut().zip(storage) {
            node.storage = m;
        }
        c
    }

    #[test]
    fn hit_returns_same_plan_without_rebuild() {
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let mut cache = PlanCache::new(8);
        let a = cache
            .get_or_build(&c, &job, "optimal-k3", None, ShuffleMode::Coded)
            .unwrap();
        let b = cache
            .get_or_build(&c, &job, "optimal-k3", None, ShuffleMode::Coded)
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!((cache.hits, cache.misses), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn seed_change_still_hits_but_shape_change_misses() {
        let c = cluster(&[6, 7, 7]);
        let mut job = JobSpec::terasort(12);
        let mut cache = PlanCache::new(8);
        cache
            .get_or_build(&c, &job, "auto", None, ShuffleMode::Coded)
            .unwrap();
        job.seed = job.seed.wrapping_add(99);
        cache
            .get_or_build(&c, &job, "auto", None, ShuffleMode::Coded)
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 1));
        job.n_files = 8;
        cache
            .get_or_build(&c, &job, "auto", None, ShuffleMode::Coded)
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 2));
    }

    #[test]
    fn capacity_evicts_fifo() {
        let c = cluster(&[6, 7, 7]);
        let mut cache = PlanCache::new(2);
        for n in [12u64, 10, 8] {
            let job = JobSpec::terasort(n);
            cache
                .get_or_build(&c, &job, "auto", None, ShuffleMode::Coded)
                .unwrap();
        }
        assert_eq!(cache.len(), 2);
        // Oldest (n=12) was evicted: rebuilding it is a miss.
        let job = JobSpec::terasort(12);
        cache
            .get_or_build(&c, &job, "auto", None, ShuffleMode::Coded)
            .unwrap();
        assert_eq!(cache.misses, 4);
    }

    #[test]
    fn topology_change_is_a_different_key() {
        let c = cluster(&[6, 7, 7]);
        let rack = c
            .clone()
            .with_topology(crate::net::Topology::Rack { racks: 3, oversub: 2.0 });
        let job = JobSpec::terasort(12);
        let mut cache = PlanCache::new(8);
        cache
            .get_or_build(&c, &job, "optimal-k3", None, ShuffleMode::Coded)
            .unwrap();
        cache
            .get_or_build(&rack, &job, "optimal-k3", None, ShuffleMode::Coded)
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (0, 2));
        cache
            .get_or_build(&rack, &job, "optimal-k3", None, ShuffleMode::Coded)
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 2));
    }

    #[test]
    fn fault_spec_change_is_a_different_key() {
        let c = cluster(&[6, 7, 7]);
        let faulty = c
            .clone()
            .with_faults(crate::net::FaultSpec::parse("straggle:seed=1,amp=0.5").unwrap());
        let job = JobSpec::terasort(12);
        let mut cache = PlanCache::new(8);
        cache
            .get_or_build(&c, &job, "optimal-k3", None, ShuffleMode::Coded)
            .unwrap();
        cache
            .get_or_build(&faulty, &job, "optimal-k3", None, ShuffleMode::Coded)
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (0, 2));
        cache
            .get_or_build(&faulty, &job, "optimal-k3", None, ShuffleMode::Coded)
            .unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 2));
    }

    #[test]
    fn build_errors_propagate_and_are_not_cached() {
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let mut cache = PlanCache::new(8);
        assert!(cache
            .get_or_build(&c, &job, "homogeneous", None, ShuffleMode::Coded)
            .is_err());
        assert_eq!(cache.len(), 0);
    }
}
