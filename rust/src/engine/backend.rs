//! Map/Reduce compute backends.
//!
//! [`NativeBackend`] computes Map/Reduce in pure Rust (the oracle path,
//! always available). [`XlaBackend`] executes the AOT artifacts through
//! the PJRT runtime — the production path, where the Map hot loop runs the
//! Layer-1 Pallas kernels lowered into `artifacts/*.hlo.txt`. Integration
//! tests assert the two agree (bit-exact for TeraSort's i32 histogram,
//! to float round-off for WordCount's matmul).
//!
//! Without the `xla` cargo feature the PJRT runtime is a stub whose
//! `Runtime::load` returns [`HetcdcError::RuntimeUnavailable`];
//! [`XlaBackend`] still compiles, so callers gate on `Runtime::load` and
//! fall back to [`NativeBackend`].

use crate::error::{HetcdcError, Result};
use crate::model::job::{JobSpec, WorkloadKind};
use crate::runtime::Runtime;
use crate::workloads;

/// Compute backend: batched Map over subfiles, plus group Reduce.
pub trait MapBackend {
    /// For each subfile in `subs`: all `q` groups' IV payloads.
    fn map_subfiles(
        &mut self,
        job: &JobSpec,
        q: usize,
        subs: &[usize],
    ) -> Result<Vec<Vec<Vec<u8>>>>;

    /// Reduce one group's payloads to its final output vector.
    fn reduce_group(&mut self, job: &JobSpec, payloads: &[&[u8]]) -> Result<Vec<f64>>;

    /// A fresh, independent backend for one parallel Map worker, or
    /// `None` when this backend cannot be used concurrently (the PJRT
    /// runtime owns device state) — the executor then falls back to a
    /// serial Map, and the pipelined executor degrades to sequential
    /// batches (it needs a worker backend to Map batch `i+1` while batch
    /// `i` shuffles). Map output depends only on `(job, q, subfiles)`, so
    /// worker backends must produce byte-identical IVs to `self`.
    fn worker_clone(&self) -> Option<Box<dyn MapBackend + Send>> {
        None
    }

    fn name(&self) -> &'static str;
}

/// Pure-Rust backend (oracle; no artifacts needed).
#[derive(Default)]
pub struct NativeBackend;

impl MapBackend for NativeBackend {
    fn map_subfiles(
        &mut self,
        job: &JobSpec,
        q: usize,
        subs: &[usize],
    ) -> Result<Vec<Vec<Vec<u8>>>> {
        Ok(subs
            .iter()
            .map(|&sub| workloads::native_map(job, q, sub))
            .collect())
    }

    fn reduce_group(&mut self, job: &JobSpec, payloads: &[&[u8]]) -> Result<Vec<f64>> {
        let mut acc = vec![0f64; job.t];
        for p in payloads {
            for (a, v) in acc.iter_mut().zip(workloads::decode_payload(job, p)) {
                *a += v;
            }
        }
        Ok(acc)
    }

    fn worker_clone(&self) -> Option<Box<dyn MapBackend + Send>> {
        Some(Box::new(NativeBackend))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// PJRT backend: Map (and f32 Reduce) through the XLA artifacts.
pub struct XlaBackend<'r> {
    rt: &'r mut Runtime,
}

impl<'r> XlaBackend<'r> {
    pub fn new(rt: &'r mut Runtime) -> Self {
        Self { rt }
    }

    /// The artifacts bake static shapes; the job must match them.
    pub fn check_job(&self, job: &JobSpec, q: usize) -> Result<()> {
        let m = &self.rt.manifest;
        if q != m.q || job.t != m.t {
            return Err(HetcdcError::PlanMismatch(format!(
                "job (q={q}, t={}) does not match artifacts (q={}, t={}); \
                 re-run `make artifacts` with matching flags",
                job.t, m.q, m.t
            )));
        }
        match job.workload {
            WorkloadKind::WordCount if job.vocab != m.vocab => {
                Err(HetcdcError::PlanMismatch(format!(
                    "vocab {} != artifact vocab {}",
                    job.vocab, m.vocab
                )))
            }
            WorkloadKind::TeraSort if job.keys_per_file != m.keys_per_file => {
                Err(HetcdcError::PlanMismatch(format!(
                    "keys_per_file {} != artifact {}",
                    job.keys_per_file, m.keys_per_file
                )))
            }
            _ => Ok(()),
        }
    }

    fn map_wordcount(
        &mut self,
        job: &JobSpec,
        q: usize,
        subs: &[usize],
    ) -> Result<Vec<Vec<Vec<u8>>>> {
        let b = self.rt.manifest.map_batch;
        let (qt, v) = (q * job.t, job.vocab);
        // Shared, cached projection (see workloads::wordcount::projection).
        let w = crate::workloads::wordcount::projection(job, q);
        let w_lit = Runtime::lit_f32(&w, &[qt, v])?;
        // Reusable input pair: slot 0 keeps W across chunks (deep Literal
        // clones per chunk showed in the profile — EXPERIMENTS.md §Perf).
        let zero = vec![0f32; v * b];
        let mut inputs = [w_lit, Runtime::lit_f32(&zero, &[v, b])?];
        let mut out = Vec::with_capacity(subs.len());
        for chunk in subs.chunks(b) {
            // counts matrix [V, B], zero-padded tail columns.
            let mut data = vec![0f32; v * b];
            for (col, &sub) in chunk.iter().enumerate() {
                let c = crate::workloads::wordcount::counts(job, sub);
                for (row, &val) in c.iter().enumerate() {
                    data[row * b + col] = val;
                }
            }
            inputs[1] = Runtime::lit_f32(&data, &[v, b])?;
            let ivs = self.rt.execute_to_f32("map_project", &inputs)?;
            // ivs shape [QT, B] row-major.
            for (col, _) in chunk.iter().enumerate() {
                let mut groups = Vec::with_capacity(q);
                for g in 0..q {
                    let mut payload = Vec::with_capacity(job.t * 4);
                    for row in 0..job.t {
                        let val = ivs[(g * job.t + row) * b + col];
                        payload.extend_from_slice(&val.to_le_bytes());
                    }
                    groups.push(payload);
                }
                out.push(groups);
            }
        }
        Ok(out)
    }

    fn map_terasort(
        &mut self,
        job: &JobSpec,
        q: usize,
        subs: &[usize],
    ) -> Result<Vec<Vec<Vec<u8>>>> {
        let b = self.rt.manifest.map_batch;
        let d = job.keys_per_file;
        let qt = q * job.t;
        let bounds: Vec<i32> = crate::workloads::terasort::bounds(job, q)
            .into_iter()
            .map(|x| x as i32)
            .collect();
        // Reusable input pair: slot 1 keeps the bounds across chunks (no
        // per-chunk deep Literal clones).
        let pad = vec![-1i32; b * d];
        let mut inputs = [
            Runtime::lit_i32(&pad, &[b, d])?,
            Runtime::lit_i32(&bounds, &[qt + 1])?,
        ];
        let mut out = Vec::with_capacity(subs.len());
        for chunk in subs.chunks(b) {
            // keys matrix [B, D]; pad tail rows with -1 (below all bounds,
            // so they count in no bucket).
            let mut data = vec![-1i32; b * d];
            for (row, &sub) in chunk.iter().enumerate() {
                for (col, key) in crate::workloads::terasort::keys(job, sub)
                    .into_iter()
                    .enumerate()
                {
                    data[row * d + col] = key as i32;
                }
            }
            inputs[0] = Runtime::lit_i32(&data, &[b, d])?;
            let counts = self.rt.execute_to_i32("map_histogram", &inputs)?;
            // counts shape [B, QT] row-major.
            for (row, _) in chunk.iter().enumerate() {
                let mut groups = Vec::with_capacity(q);
                for g in 0..q {
                    let mut payload = Vec::with_capacity(job.t * 4);
                    for j in 0..job.t {
                        let val = counts[row * qt + g * job.t + j];
                        payload.extend_from_slice(&val.to_le_bytes());
                    }
                    groups.push(payload);
                }
                out.push(groups);
            }
        }
        Ok(out)
    }
}

impl<'r> MapBackend for XlaBackend<'r> {
    fn map_subfiles(
        &mut self,
        job: &JobSpec,
        q: usize,
        subs: &[usize],
    ) -> Result<Vec<Vec<Vec<u8>>>> {
        self.check_job(job, q)?;
        match job.workload {
            WorkloadKind::WordCount => self.map_wordcount(job, q, subs),
            WorkloadKind::TeraSort => self.map_terasort(job, q, subs),
        }
    }

    fn reduce_group(&mut self, job: &JobSpec, payloads: &[&[u8]]) -> Result<Vec<f64>> {
        match job.workload {
            // f32 partial sums through the reduce_sum artifact.
            WorkloadKind::WordCount => {
                let rb = self.rt.manifest.reduce_batch;
                let t = job.t;
                let mut acc = vec![0f32; t];
                for chunk in payloads.chunks(rb) {
                    let mut data = vec![0f32; rb * t];
                    for (row, p) in chunk.iter().enumerate() {
                        for (col, bytes) in p.chunks_exact(4).enumerate() {
                            data[row * t + col] = f32::from_le_bytes(bytes.try_into().unwrap());
                        }
                    }
                    let lit = Runtime::lit_f32(&data, &[rb, t])?;
                    let partial = self.rt.execute_to_f32("reduce_sum", &[lit])?;
                    for (a, v) in acc.iter_mut().zip(partial) {
                        *a += v;
                    }
                }
                Ok(acc.into_iter().map(|x| x as f64).collect())
            }
            // i32 merge is exact integer work; stay native.
            WorkloadKind::TeraSort => NativeBackend.reduce_group(job, payloads),
        }
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_map_shapes() {
        let job = JobSpec::wordcount(4);
        let mut be = NativeBackend;
        let out = be.map_subfiles(&job, 3, &[0, 1, 5]).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].len(), 3);
        assert!(out[0].iter().all(|p| p.len() == job.iv_bytes()));
    }

    #[test]
    fn native_reduce_matches_oracle() {
        let job = JobSpec::terasort(4);
        let mut be = NativeBackend;
        let maps = be.map_subfiles(&job, 3, &[0, 1, 2, 3]).unwrap();
        let g = 1usize;
        let payloads: Vec<&[u8]> = maps.iter().map(|m| m[g].as_slice()).collect();
        let got = be.reduce_group(&job, &payloads).unwrap();
        let want = crate::workloads::native_reduce_oracle(&job, 3, g, 4);
        assert_eq!(got, want);
    }
}
