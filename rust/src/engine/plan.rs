//! The staged pipeline's artifact: [`JobBuilder`] → [`Plan`] →
//! [`crate::engine::Executor`].
//!
//! A [`Plan`] bundles everything that depends only on cluster shape and
//! job *shape* — the [`Allocation`], the [`ShufflePlan`], the decode
//! schedule, and the predicted loads/times — so the expensive work
//! (Theorem-1 construction or the §V LP, shuffle planning, symbolic
//! decode verification) happens exactly once and is reused across data
//! batches — serially, shard-parallel, or batch-pipelined: the plan is
//! immutable and shared, so any number of in-flight batch epochs can
//! replay its decode schedule concurrently. Plans are immutable once built, validated at build time
//! (execution never re-verifies decodability), and serializable to JSON
//! (`hetcdc plan` emits them; `hetcdc run --plan` consumes them; schema
//! in DESIGN.md).

use super::exec::broadcast_sizes;
use crate::coding::coder::{coder_by_name, ShuffleCoder};
use crate::coding::decoder::{self, DecodeSchedule};
use crate::coding::plan::ShufflePlan;
use crate::error::{HetcdcError, Result};
use crate::model::cluster::ClusterSpec;
use crate::model::job::{JobSpec, ShuffleMode};
use crate::net::{FaultSpec, Topology};
use crate::placement::alloc::Allocation;
use crate::placement::placer::{placer_by_name_cfg, Placer, PlacerConfig};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Resolve a worker-thread request for plan building: `0` = auto-detect
/// via [`std::thread::available_parallelism`] (falling back to 1 when
/// the host will not say), anything else is taken literally. Plan builds
/// are bit-identical at every thread count, so auto-detection cannot
/// change an artifact — only its wall-clock.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    }
}

/// Per-node straggler readiness times under the cluster's fault spec:
/// seconds past the *nominal* Map barrier before each node may start
/// sending in the Shuffle. `None` when no straggle is configured.
///
/// The shuffle clock's zero is the fault-free barrier
/// `B0 = max_n base_t_n` (the exact `map_time_s` fold of
/// [`PredictedLoads`], bit for bit — `map_time_s` stays nominal and all
/// straggle delay appears as shuffle-schedule waits, so
/// `map_time_s + shuffle_time_s` remains the job makespan). Node `n`
/// with slowdown `s_n` finishes Mapping at `s_n · base_t_n` and is ready
/// `max(0, s_n · base_t_n − B0)` seconds late. Deterministic in
/// `(seed, node)` alone ([`FaultSpec::slowdowns`]), so every batch,
/// thread count, and execution mode replays the same readiness times.
pub fn straggler_ready(cluster: &ClusterSpec, alloc: &Allocation) -> Option<Vec<f64>> {
    cluster.faults.straggle?;
    let slow = cluster.faults.slowdowns(cluster.k());
    let base: Vec<f64> = cluster
        .nodes
        .iter()
        .enumerate()
        .map(|(node, spec)| {
            let files_equiv = alloc.node_count(node) as f64 / alloc.sp as f64;
            files_equiv / spec.map_files_per_s.max(1e-9)
        })
        .collect();
    let b0 = base.iter().fold(0f64, |acc, &t| acc.max(t));
    Some(
        base.iter()
            .zip(&slow)
            .map(|(&t, &s)| (s * t - b0).max(0.0))
            .collect(),
    )
}

/// Build-time predictions, exact for the deterministic simulator: a
/// verified [`crate::engine::RunReport`] reproduces these numbers.
#[derive(Clone, Debug, PartialEq)]
pub struct PredictedLoads {
    /// Shuffle load in IV-equation units (the paper's metric).
    pub load_equations: f64,
    /// Shuffle load in subfile units (`load_equations · sp`).
    pub load_units: f64,
    /// Uncoded baseline for the same allocation, IV-equation units.
    pub uncoded_equations: f64,
    pub messages: u64,
    /// Shuffle rounds of the plan's IR (multicast stages).
    pub rounds: u64,
    pub payload_bytes: u64,
    pub wire_bytes: u64,
    /// Map barrier time under the per-node compute rates (virtual s).
    /// Always the **nominal** barrier: straggler slowdowns surface as
    /// shuffle-schedule waits (`straggler_delay_s`), never here.
    pub map_time_s: f64,
    /// Serialized broadcast time on the simulated network (virtual s),
    /// including any straggler waits.
    pub shuffle_time_s: f64,
    /// Time the shuffle schedule sat waiting for straggling senders
    /// (see [`crate::net::NetReport::straggler_delay_s`]); 0 when the
    /// cluster has no straggle spec, and omitted from JSON then.
    pub straggler_delay_s: f64,
}

impl PredictedLoads {
    fn compute(
        cluster: &ClusterSpec,
        job: &JobSpec,
        alloc: &Allocation,
        shuffle: &ShufflePlan,
    ) -> Result<Self> {
        let iv_bytes = job.iv_bytes();
        let mut payload_bytes = 0u64;
        let mut wire_bytes = 0u64;
        let mut net = cluster.network()?;
        if let Some(ready) = straggler_ready(cluster, alloc) {
            net.set_straggle(&ready)?;
        }
        // Same round-sectioned, group-flagged, flat-order metering pass
        // as the executor (same `round_start_flags` /
        // `group_start_masks` encoding — see engine/exec.rs), so
        // predicted and measured accounting — including the per-round
        // NetReport sections and the switched-topology schedule —
        // cannot drift.
        let starts_round = shuffle.round_start_flags();
        let group_starts = shuffle.group_start_masks();
        for (bi, b) in shuffle.iter_broadcasts().enumerate() {
            if starts_round[bi] {
                net.begin_round();
            }
            if let Some(members) = group_starts[bi] {
                net.begin_group(members);
            }
            let (payload, wire) = broadcast_sizes(b, iv_bytes);
            payload_bytes += payload as u64;
            wire_bytes += wire as u64;
            net.broadcast(b.sender(), wire);
        }
        let mut map_time_s = 0f64;
        for (node, spec) in cluster.nodes.iter().enumerate() {
            let files_equiv = alloc.node_count(node) as f64 / alloc.sp as f64;
            map_time_s = map_time_s.max(files_equiv / spec.map_files_per_s.max(1e-9));
        }
        let report = net.report();
        Ok(PredictedLoads {
            load_equations: shuffle.load_equations(alloc),
            load_units: shuffle.load_units(),
            uncoded_equations: alloc.uncoded_units() as f64 / alloc.sp as f64,
            messages: shuffle.n_broadcasts() as u64,
            rounds: shuffle.round_count() as u64,
            payload_bytes,
            wire_bytes,
            map_time_s,
            shuffle_time_s: report.elapsed_s,
            straggler_delay_s: report.straggler_delay_s,
        })
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("load_equations".into(), Json::Num(self.load_equations));
        m.insert("load_units".into(), Json::Num(self.load_units));
        m.insert("uncoded_equations".into(), Json::Num(self.uncoded_equations));
        m.insert("messages".into(), Json::Num(self.messages as f64));
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("payload_bytes".into(), Json::Num(self.payload_bytes as f64));
        m.insert("wire_bytes".into(), Json::Num(self.wire_bytes as f64));
        m.insert("map_time_s".into(), Json::Num(self.map_time_s));
        m.insert("shuffle_time_s".into(), Json::Num(self.shuffle_time_s));
        // Omitted when zero: fault-free artifacts stay byte-identical to
        // the pre-fault schema (same contract as the topology key).
        if self.straggler_delay_s > 0.0 {
            m.insert("straggler_delay_s".into(), Json::Num(self.straggler_delay_s));
        }
        Json::Obj(m)
    }
}

/// FNV-1a over the cluster shape and job shape (everything that affects
/// plan construction; the data seed is deliberately excluded — one plan
/// serves many batches). Display-friendly cache/plan identity; the
/// [`crate::engine::PlanCache`] keys on the exact shapes, not this hash.
pub fn shape_fingerprint(cluster: &ClusterSpec, job: &JobSpec) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat(&(cluster.k() as u64).to_le_bytes());
    for n in &cluster.nodes {
        eat(&n.storage.to_le_bytes());
        eat(&n.uplink_mbps.to_bits().to_le_bytes());
        eat(&n.map_files_per_s.to_bits().to_le_bytes());
    }
    eat(&cluster.latency_ms.to_bits().to_le_bytes());
    // The topology is eaten only when switched, so every pre-topology
    // shape keeps its historical fingerprint (Shared is the default and
    // is omitted from serialized clusters for the same reason).
    if !cluster.topology.is_shared() {
        eat(cluster.topology.spec().as_bytes());
    }
    // Same omit-when-default contract for the fault model: fault-free
    // shapes keep their historical fingerprint.
    if !cluster.faults.is_none() {
        eat(cluster.faults.spec().as_bytes());
    }
    eat(&[match job.workload {
        crate::model::job::WorkloadKind::WordCount => 1u8,
        crate::model::job::WorkloadKind::TeraSort => 2u8,
    }]);
    eat(&job.n_files.to_le_bytes());
    eat(&(job.t as u64).to_le_bytes());
    eat(&(job.vocab as u64).to_le_bytes());
    eat(&(job.keys_per_file as u64).to_le_bytes());
    h
}

/// An immutable, validated, serializable execution plan. Construct via
/// [`JobBuilder`] (or deserialize with [`Plan::from_json_str`], which
/// re-validates). Fields are public for inspection; treat them as
/// read-only — the decode schedule and predictions are only correct for
/// the exact allocation and shuffle plan they were built from.
#[derive(Clone, Debug)]
pub struct Plan {
    pub cluster: ClusterSpec,
    pub job: JobSpec,
    /// Placer registry name that produced the allocation.
    pub placer: String,
    /// Coder registry name that produced the shuffle plan.
    pub coder: String,
    pub mode: ShuffleMode,
    pub alloc: Allocation,
    pub shuffle: ShufflePlan,
    /// Decode order proven at build time; execution replays it verbatim.
    pub schedule: DecodeSchedule,
    pub predicted: PredictedLoads,
    /// Perfect collections the placer's enumeration cap dropped, as
    /// `(subsystem j, count)` — non-empty only for the §V LP when
    /// Remark 7's cap truncated (the exact path drops nothing when it
    /// certifies). Surfaced by the CLI as a warning; informational in
    /// serialized artifacts.
    pub dropped_collections: Vec<(usize, usize)>,
    /// Deterministic work counters from the exact §V LP solve
    /// ([`crate::placement::lp_general::LpWorkStats`]) — `None` for
    /// every other placer. Serialized as the `lp_solver` object;
    /// informational (not validated on deserialization, like
    /// `dropped_collections`).
    pub lp_stats: Option<crate::placement::lp_general::LpWorkStats>,
    /// [`shape_fingerprint`] of (cluster, job shape).
    pub fingerprint: u64,
}

impl Plan {
    /// Validate and assemble a plan from its parts: checks the job, the
    /// allocation (against capacities as upper bounds), and decodability
    /// — the single validation gate for built *and* deserialized plans.
    #[allow(clippy::too_many_arguments)]
    pub fn assemble(
        cluster: ClusterSpec,
        job: JobSpec,
        placer: String,
        coder: String,
        mode: ShuffleMode,
        alloc: Allocation,
        shuffle: ShufflePlan,
        dropped_collections: Vec<(usize, usize)>,
    ) -> Result<Plan> {
        Plan::assemble_threaded(
            cluster,
            job,
            placer,
            coder,
            mode,
            alloc,
            shuffle,
            dropped_collections,
            1,
        )
    }

    /// [`Plan::assemble`] with the decode-schedule verification sharded
    /// across `threads` workers ([`decoder::schedule_threaded`]); the
    /// schedule — and therefore the plan — is identical for every thread
    /// count. The metering pass stays serial (the virtual network clock
    /// is an order-sensitive float fold).
    #[allow(clippy::too_many_arguments)]
    pub fn assemble_threaded(
        cluster: ClusterSpec,
        job: JobSpec,
        placer: String,
        coder: String,
        mode: ShuffleMode,
        alloc: Allocation,
        shuffle: ShufflePlan,
        dropped_collections: Vec<(usize, usize)>,
        threads: usize,
    ) -> Result<Plan> {
        job.validate(cluster.k())?;
        if alloc.k != cluster.k() {
            return Err(HetcdcError::PlanMismatch(format!(
                "allocation is for K={}, cluster has K={}",
                alloc.k,
                cluster.k()
            )));
        }
        alloc.validate_le(&cluster.storage(), job.n_files)?;
        shuffle.validate(alloc.k, alloc.n_sub())?;
        let schedule = decoder::schedule_threaded(&alloc, &shuffle, threads)?;
        // Degraded-decode gate: a plan whose cluster claims `repair:f=N`
        // must actually tolerate every loss pattern up to N — built *and*
        // deserialized artifacts prove it here (a tampered artifact that
        // dropped a repair round fails typed).
        if cluster.faults.repair > 0 {
            decoder::verify_loss_patterns(&alloc, &shuffle, cluster.faults.repair)?;
        }
        let predicted = PredictedLoads::compute(&cluster, &job, &alloc, &shuffle)?;
        let fingerprint = shape_fingerprint(&cluster, &job);
        Ok(Plan {
            cluster,
            job,
            placer,
            coder,
            mode,
            alloc,
            shuffle,
            schedule,
            predicted,
            dropped_collections,
            // Informational; callers that have counters (JobBuilder,
            // from_json) set them after assembly.
            lp_stats: None,
            fingerprint,
        })
    }

    /// Exact shape equality against a (cluster, job) pair: everything
    /// [`shape_fingerprint`] covers, compared field-by-field (node names
    /// and data seeds excluded). Use this — not the fingerprint, which is
    /// a non-collision-resistant display identity — to gate execution.
    pub fn shape_matches(&self, cluster: &ClusterSpec, job: &JobSpec) -> bool {
        let a = &self.cluster;
        let cluster_eq = a.k() == cluster.k()
            && a.latency_ms.to_bits() == cluster.latency_ms.to_bits()
            && a.topology == cluster.topology
            && a.faults == cluster.faults
            && a.nodes.iter().zip(&cluster.nodes).all(|(x, y)| {
                x.storage == y.storage
                    && x.uplink_mbps.to_bits() == y.uplink_mbps.to_bits()
                    && x.map_files_per_s.to_bits() == y.map_files_per_s.to_bits()
            });
        let b = &self.job;
        cluster_eq
            && b.workload == job.workload
            && b.n_files == job.n_files
            && b.t == job.t
            && b.vocab == job.vocab
            && b.keys_per_file == job.keys_per_file
    }

    /// Re-plan after losing `node` (dropout recovery): the surviving
    /// nodes keep their subfile placement — each holder mask is
    /// compacted by deleting the lost node's bit — and the shuffle is
    /// re-coded for the K−1 survivors with this plan's own coder
    /// (falling back to the any-K `pairing` coder when that coder
    /// cannot serve the reduced shape). Typed
    /// [`HetcdcError::InvalidPlacement`] when some subfile was held
    /// *only* by the dropped node: recovery then needs re-placement
    /// (data movement), which re-coding cannot express.
    ///
    /// Recovery cost is the delta between the two plans' predictions
    /// (wire bytes, rounds, `map + shuffle` makespan); the bench suite's
    /// dropout scenarios meter exactly that.
    pub fn replan_without(&self, node: usize) -> Result<Plan> {
        let k = self.cluster.k();
        if node >= k {
            return Err(HetcdcError::InvalidParams(format!(
                "replan_without: node {node} out of range [0, {k})"
            )));
        }
        if k <= 2 {
            return Err(HetcdcError::InvalidParams(
                "replan_without needs at least 3 nodes to lose one".into(),
            ));
        }
        let mut cluster = self.cluster.clone();
        cluster.nodes.remove(node);
        cluster.topology.validate(cluster.k())?;
        let low = (1u64 << node) - 1;
        let mut holders = Vec::with_capacity(self.alloc.holders.len());
        for (sub, &h) in self.alloc.holders.iter().enumerate() {
            let h = h as u64;
            let compacted = ((h & low) | ((h >> (node + 1)) << node)) as u32;
            if compacted == 0 {
                return Err(HetcdcError::InvalidPlacement(format!(
                    "subfile {sub} was held only by dropped node {node}; \
                     recovery needs re-placement, not re-coding"
                )));
            }
            holders.push(compacted);
        }
        let alloc = Allocation::new(cluster.k(), self.alloc.sp, holders);
        let build = |coder: &str| {
            JobBuilder::new(&cluster, &self.job)
                .custom_allocation(alloc.clone())
                .coder(coder)
                .mode(self.mode)
                .build()
        };
        match build(&self.coder) {
            Ok(plan) => Ok(plan),
            // The original coder may be shape-bound (K=3-only, grid
            // designs); the greedy pairing coder serves any allocation.
            Err(_) if self.coder != "pairing" => build("pairing"),
            Err(e) => Err(e),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("version".into(), Json::Num(2.0));
        m.insert("placer".into(), Json::Str(self.placer.clone()));
        m.insert("coder".into(), Json::Str(self.coder.clone()));
        m.insert("mode".into(), Json::Str(self.mode.as_str().into()));
        m.insert("fingerprint".into(), Json::Str(format!("{:016x}", self.fingerprint)));
        m.insert("cluster".into(), self.cluster.to_json());
        m.insert("job".into(), self.job.to_json());
        m.insert("allocation".into(), self.alloc.to_json());
        m.insert("shuffle".into(), self.shuffle.to_json());
        m.insert("predicted".into(), self.predicted.to_json());
        if !self.dropped_collections.is_empty() {
            m.insert(
                "dropped_collections".into(),
                Json::Arr(
                    self.dropped_collections
                        .iter()
                        .map(|&(j, d)| {
                            Json::Arr(vec![Json::Num(j as f64), Json::Num(d as f64)])
                        })
                        .collect(),
                ),
            );
        }
        if let Some(stats) = self.lp_stats {
            m.insert("lp_solver".into(), stats.to_json());
        }
        Json::Obj(m)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Deserialize and **re-validate**: the decode schedule and the
    /// predictions are recomputed from the parsed allocation and shuffle
    /// plan, so a tampered or stale artifact fails with a typed error
    /// instead of executing. Accepts schema version 2 (round-structured
    /// shuffle IR) and legacy version 1 (flat broadcast list — read as a
    /// single-round plan; see DESIGN.md "Shuffle IR v2").
    pub fn from_json(j: &Json) -> Result<Plan> {
        let bad = |f: &str| HetcdcError::Json(format!("plan: missing or invalid '{f}'"));
        if let Some(v) = j.get("version") {
            if !matches!(v.as_usize(), Some(1) | Some(2)) {
                return Err(HetcdcError::Json(format!(
                    "plan: unsupported version {v}"
                )));
            }
        }
        let cluster = ClusterSpec::from_json(j.get("cluster").ok_or_else(|| bad("cluster"))?)?;
        let job = JobSpec::from_json(j.get("job").ok_or_else(|| bad("job"))?)?;
        let mode = ShuffleMode::parse(
            j.get("mode").and_then(|v| v.as_str()).ok_or_else(|| bad("mode"))?,
        )?;
        let placer = j
            .get("placer")
            .and_then(|v| v.as_str())
            .unwrap_or("custom")
            .to_string();
        let coder = j
            .get("coder")
            .and_then(|v| v.as_str())
            .unwrap_or("unknown")
            .to_string();
        let alloc = Allocation::from_json(j.get("allocation").ok_or_else(|| bad("allocation"))?)?;
        let shuffle = ShufflePlan::from_json(j.get("shuffle").ok_or_else(|| bad("shuffle"))?)?;
        // Informational diagnostics: absent in v1 artifacts, lenient here.
        let dropped = j
            .get("dropped_collections")
            .and_then(|v| v.as_arr())
            .map(|arr| {
                arr.iter()
                    .filter_map(|pair| {
                        let p = pair.as_arr()?;
                        Some((p.first()?.as_usize()?, p.get(1)?.as_usize()?))
                    })
                    .collect()
            })
            .unwrap_or_default();
        // Informational like `dropped_collections`: absent in pre-exact
        // artifacts and for non-LP placers; malformed objects read as None.
        let lp_stats = j.get("lp_solver").and_then(|v| {
            let num = |key: &str| v.get(key).and_then(Json::as_f64);
            Some(crate::placement::lp_general::LpWorkStats {
                pivots: num("pivots")? as u64,
                eta_applications: num("eta_applications")? as u64,
                dense_cells: num("dense_cells")? as u64,
                reinversions: num("reinversions")? as u64,
                exact_rounds: num("exact_rounds")? as u64,
                enumerated_collections: num("enumerated_collections")? as u64,
                grown_subsystems: num("grown_subsystems")? as u64,
                z_exact: num("z_exact")?,
                certified: v.get("certified").and_then(Json::as_bool)?,
            })
        });
        let mut plan =
            Plan::assemble(cluster, job, placer, coder, mode, alloc, shuffle, dropped)?;
        plan.lp_stats = lp_stats;
        Ok(plan)
    }

    pub fn from_json_str(text: &str) -> Result<Plan> {
        Plan::from_json(&Json::parse(text)?)
    }
}

/// Entry point of the staged pipeline: collect cluster/job and strategy
/// choices, then [`JobBuilder::build`] a validated [`Plan`].
///
/// ```no_run
/// use hetcdc::engine::{ExecConfig, Executor, JobBuilder, NativeBackend};
/// use hetcdc::model::cluster::ClusterSpec;
/// use hetcdc::model::job::JobSpec;
///
/// let cluster = ClusterSpec::ec2_like_3node(12);
/// let job = JobSpec::terasort(12);
/// let plan = JobBuilder::new(&cluster, &job).placer("optimal-k3").build().unwrap();
/// let mut backend = NativeBackend;
/// let mut exec = Executor::with_config(&plan, ExecConfig::default()).unwrap();
/// for batch in 0u64..3 {
///     let report = exec.run_batch(&mut backend, job.seed + batch).unwrap();
///     assert!(report.verified);
/// }
/// ```
pub struct JobBuilder<'a> {
    cluster: &'a ClusterSpec,
    job: &'a JobSpec,
    placer: String,
    coder: Option<String>,
    mode: ShuffleMode,
    custom: Option<Allocation>,
    /// Worker threads for plan construction (1 = serial, 0 = auto).
    threads: usize,
    /// Override of the §V LP's Remark-7 enumeration cap.
    lp_cap: Option<usize>,
    /// Network-topology override applied to the cluster before building.
    topology: Option<Topology>,
    /// Fault-model override applied to the cluster before building.
    faults: Option<FaultSpec>,
}

impl<'a> JobBuilder<'a> {
    pub fn new(cluster: &'a ClusterSpec, job: &'a JobSpec) -> Self {
        JobBuilder {
            cluster,
            job,
            placer: "auto".to_string(),
            coder: None,
            mode: ShuffleMode::Coded,
            custom: None,
            threads: 1,
            lp_cap: None,
            topology: None,
            faults: None,
        }
    }

    /// Pick a placer by registry name (default `"auto"`: Theorem 1 for
    /// K=3, the §V LP otherwise).
    pub fn placer(mut self, name: &str) -> Self {
        self.placer = name.to_string();
        self
    }

    /// Pick a shuffle coder by registry name (default: the placer's
    /// [`crate::placement::Placer::default_coder`]; ignored for
    /// [`ShuffleMode::Uncoded`]).
    pub fn coder(mut self, name: &str) -> Self {
        self.coder = Some(name.to_string());
        self
    }

    pub fn mode(mut self, mode: ShuffleMode) -> Self {
        self.mode = mode;
        self
    }

    /// Use a caller-provided allocation (e.g. from a custom
    /// [`crate::placement::Placer`] impl) instead of a registry placer.
    pub fn custom_allocation(mut self, alloc: Allocation) -> Self {
        self.custom = Some(alloc);
        self
    }

    /// Worker threads for **plan construction** (default 1 = serial;
    /// 0 = auto-detect). Threads shard the parallelizable build stages —
    /// the §V LP's per-subsystem enumeration and pricing scan, the
    /// combinatorial coder's group/round construction, and the decode-
    /// schedule verification — and the built plan is **bit-identical**
    /// for every value: serializing the same shape at `--threads 1` and
    /// `--threads 8` yields byte-equal JSON. (Execution threading is a
    /// separate knob: [`crate::engine::ExecConfig::threads`].)
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Override the §V LP's Remark-7 perfect-collection cap (default
    /// [`crate::placement::lp_general::DEFAULT_COLLECTION_CAP`]). Only
    /// the `lp-general` placer reads it; raising it trades build time
    /// for placement quality, and any truncation still lands on
    /// [`Plan::dropped_collections`].
    pub fn lp_cap(mut self, cap: usize) -> Self {
        self.lp_cap = Some(cap);
        self
    }

    /// Override the cluster's network [`Topology`] for this build (CLI
    /// `--topology`). The topology changes the predicted shuffle
    /// *schedule* (makespan, per-link metering), never the placement or
    /// the byte/round counts; it is part of the plan's shape — the
    /// fingerprint and [`crate::engine::PlanCache`] key include it.
    pub fn topology(mut self, topology: Topology) -> Self {
        self.topology = Some(topology);
        self
    }

    /// Override the cluster's [`FaultSpec`] for this build (CLI
    /// `--faults`). A straggle clause changes the predicted shuffle
    /// *schedule* (`straggler_delay_s`, makespan) but never the
    /// placement or the byte/round counts; a repair clause appends
    /// verified repair rounds to the shuffle IR
    /// ([`crate::coding::plan::with_repair_rounds`]), which does add
    /// bytes and rounds — that is the recovery budget being bought. The
    /// fault spec is part of the plan's shape: fingerprint and
    /// [`crate::engine::PlanCache`] key include it.
    pub fn faults(mut self, faults: FaultSpec) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Place, code, verify, predict — everything that does not depend on
    /// the data batch.
    pub fn build(self) -> Result<Plan> {
        // `Plan::assemble` is the validation gate for deserialized plans
        // and re-checks job and allocation; the early checks here exist so
        // placers and coders never observe a malformed job (n_files = 0
        // would divide-by-zero in the homogeneous placer) or allocation.
        // Resolve the topology/fault overrides up front so everything —
        // the network validation inside prediction, the serialized
        // cluster, the fingerprint — sees one consistent cluster spec.
        let overridden;
        let cluster: &ClusterSpec = if self.topology.is_some() || self.faults.is_some() {
            let mut c = self.cluster.clone();
            if let Some(t) = self.topology {
                c.topology = t;
            }
            if let Some(f) = self.faults {
                c.faults = f;
            }
            overridden = c;
            &overridden
        } else {
            self.cluster
        };
        cluster.topology.validate(cluster.k())?;
        cluster.faults.validate(cluster.k())?;
        self.job.validate(cluster.k())?;
        let threads = resolve_threads(self.threads);
        let cfg = PlacerConfig {
            lp_cap: self.lp_cap.unwrap_or(crate::placement::lp_general::DEFAULT_COLLECTION_CAP),
            threads,
        };
        let (placer_name, placement, default_coder) = match self.custom {
            Some(a) => (
                "custom".to_string(),
                crate::placement::Placement::exact(a),
                "pairing",
            ),
            None => {
                let placer = placer_by_name_cfg(&self.placer, cluster, &cfg)?;
                (
                    placer.name().to_string(),
                    placer.place_report(cluster, self.job)?,
                    placer.default_coder(),
                )
            }
        };
        let lp_stats = placement.lp_stats;
        let alloc = placement.alloc;
        alloc.validate_le(&cluster.storage(), self.job.n_files)?;
        let coder_name = match self.mode {
            ShuffleMode::Uncoded => "uncoded".to_string(),
            ShuffleMode::Coded => self.coder.unwrap_or_else(|| default_coder.to_string()),
        };
        let coder = coder_by_name(&coder_name)?;
        let mut shuffle = coder.plan_threaded(cluster, self.job, &alloc, threads)?;
        // Degraded-decode mode: append repair rounds so the plan
        // tolerates `repair:f=N` lost broadcasts; `Plan::assemble`
        // then proves every loss pattern up to N still decodes.
        if cluster.faults.repair > 0 {
            shuffle = crate::coding::plan::with_repair_rounds(
                &shuffle,
                &alloc,
                cluster.faults.repair,
            )?;
        }
        let mut plan = Plan::assemble_threaded(
            cluster.clone(),
            self.job.clone(),
            placer_name,
            coder.name().to_string(),
            self.mode,
            alloc,
            shuffle,
            placement.dropped_collections,
            threads,
        )?;
        plan.lp_stats = lp_stats;
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::theory::load;
    use crate::theory::params::Params3;

    fn cluster(storage: &[u64]) -> ClusterSpec {
        let mut c = ClusterSpec::homogeneous(storage.len(), 1, 1000.0);
        for (node, &m) in c.nodes.iter_mut().zip(storage) {
            node.storage = m;
        }
        c
    }

    #[test]
    fn build_paper_example_predicts_lstar() {
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let plan = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        let p = Params3::new(6, 7, 7, 12).unwrap();
        assert_eq!(plan.predicted.load_equations, load::lstar(&p));
        assert_eq!(plan.predicted.uncoded_equations, load::uncoded(&p));
        assert_eq!(plan.placer, "optimal-k3");
        assert_eq!(plan.coder, "pairing");
        assert!(plan.predicted.shuffle_time_s > 0.0);
        assert!(plan.predicted.map_time_s > 0.0);
        assert!(plan.predicted.wire_bytes > plan.predicted.payload_bytes);
    }

    #[test]
    fn uncoded_mode_overrides_coder() {
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let plan = JobBuilder::new(&c, &job)
            .placer("optimal-k3")
            .coder("pairing")
            .mode(ShuffleMode::Uncoded)
            .build()
            .unwrap();
        assert_eq!(plan.coder, "uncoded");
        let p = Params3::new(6, 7, 7, 12).unwrap();
        assert_eq!(plan.predicted.load_equations, load::uncoded(&p));
    }

    #[test]
    fn auto_placer_resolves_by_k() {
        let c3 = cluster(&[6, 7, 7]);
        let job3 = JobSpec::terasort(12);
        assert_eq!(
            JobBuilder::new(&c3, &job3).build().unwrap().placer,
            "optimal-k3"
        );
        let c4 = cluster(&[3, 4, 5, 6]);
        let job4 = JobSpec::terasort(8);
        assert_eq!(
            JobBuilder::new(&c4, &job4).build().unwrap().placer,
            "lp-general"
        );
    }

    #[test]
    fn invalid_job_is_typed_error() {
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(0);
        assert!(matches!(
            JobBuilder::new(&c, &job).build().unwrap_err(),
            HetcdcError::InvalidJob(_)
        ));
    }

    #[test]
    fn threaded_build_emits_byte_identical_plan_json() {
        // The builder-level determinism contract: same shape, any thread
        // budget, byte-equal serialized artifact.
        let c = cluster(&[3, 4, 5, 6]);
        let job = JobSpec::terasort(8);
        let reference = JobBuilder::new(&c, &job).build().unwrap().to_json_string();
        for threads in [0usize, 2, 8] {
            let built = JobBuilder::new(&c, &job)
                .threads(threads)
                .build()
                .unwrap()
                .to_json_string();
            assert_eq!(reference, built, "threads={threads}");
        }
    }

    #[test]
    fn lp_cap_override_reaches_the_placer_and_the_plan() {
        // A deliberately tight cap truncates the K=4 enumeration on the
        // legacy capped route; the dropped count must surface on the
        // built plan. The exact default outgrows the same cap, certifies,
        // and drops nothing — and its work counters land on the plan.
        let c = cluster(&[3, 4, 5, 6]);
        let job = JobSpec::terasort(8);
        let plan = JobBuilder::new(&c, &job)
            .placer("lp-capped")
            .lp_cap(1)
            .build()
            .unwrap();
        assert!(
            plan.dropped_collections.iter().any(|&(j, d)| j == 2 && d > 0),
            "cap=1 should truncate, got {:?}",
            plan.dropped_collections
        );
        assert!(plan.lp_stats.is_none(), "capped route carries no counters");
        let plan = JobBuilder::new(&c, &job).lp_cap(1).build().unwrap();
        assert!(plan.dropped_collections.is_empty());
        let stats = plan.lp_stats.expect("exact route records counters");
        assert!(stats.certified);
        let plan = JobBuilder::new(&c, &job).build().unwrap();
        assert!(plan.dropped_collections.is_empty());
    }

    #[test]
    fn resolve_threads_auto_never_returns_zero() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(7), 7);
    }

    #[test]
    fn json_roundtrip_revalidates() {
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::wordcount(12);
        let plan = JobBuilder::new(&c, &job).build().unwrap();
        let text = plan.to_json_string();
        let back = Plan::from_json_str(&text).unwrap();
        assert_eq!(back.placer, plan.placer);
        assert_eq!(back.coder, plan.coder);
        assert_eq!(back.mode, plan.mode);
        assert_eq!(back.alloc, plan.alloc);
        assert_eq!(back.shuffle, plan.shuffle);
        assert_eq!(back.schedule, plan.schedule);
        assert_eq!(back.predicted, plan.predicted);
        assert_eq!(back.dropped_collections, plan.dropped_collections);
        assert_eq!(back.lp_stats, plan.lp_stats);
        assert_eq!(back.fingerprint, plan.fingerprint);
    }

    #[test]
    fn lp_solver_counters_roundtrip_through_json() {
        // An exact-LP plan serializes its `lp_solver` object and the
        // counters survive deserialization bit-for-bit; non-LP plans
        // omit the key entirely.
        let c = cluster(&[3, 4, 5, 6]);
        let job = JobSpec::terasort(8);
        let plan = JobBuilder::new(&c, &job).placer("lp-general").build().unwrap();
        let stats = plan.lp_stats.expect("exact route records counters");
        assert!(stats.certified);
        let text = plan.to_json_string();
        assert!(text.contains("\"lp_solver\""));
        let back = Plan::from_json_str(&text).unwrap();
        assert_eq!(back.lp_stats, plan.lp_stats);

        let c3 = cluster(&[6, 7, 7]);
        let job3 = JobSpec::terasort(12);
        let p3 = JobBuilder::new(&c3, &job3).placer("optimal-k3").build().unwrap();
        assert!(p3.lp_stats.is_none());
        assert!(!p3.to_json_string().contains("\"lp_solver\""));
    }

    #[test]
    fn legacy_v1_flat_plan_artifact_still_loads() {
        // A v1 artifact (flat "broadcasts" list, version 1) must load via
        // the legacy-read shim as a single-round plan with identical
        // loads. Build a v2 plan and down-convert its JSON to v1 shape.
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let plan = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        let mut j = plan.to_json();
        let Json::Obj(m) = &mut j else { panic!("plan json is an object") };
        m.insert("version".into(), Json::Num(1.0));
        let shuffle = m.get("shuffle").unwrap().clone();
        let mut flat = Vec::new();
        for round in shuffle.get("rounds").unwrap().as_arr().unwrap() {
            for group in round.get("groups").unwrap().as_arr().unwrap() {
                for b in group.get("broadcasts").unwrap().as_arr().unwrap() {
                    flat.push(b.clone());
                }
            }
        }
        let mut sm = BTreeMap::new();
        sm.insert("k".into(), Json::Num(plan.shuffle.k as f64));
        sm.insert("broadcasts".into(), Json::Arr(flat));
        m.insert("shuffle".into(), Json::Obj(sm));

        let back = Plan::from_json(&j).unwrap();
        assert_eq!(back.shuffle.round_count(), 1, "legacy plans read as one round");
        assert_eq!(back.shuffle.n_broadcasts(), plan.shuffle.n_broadcasts());
        assert_eq!(back.predicted.payload_bytes, plan.predicted.payload_bytes);
        assert_eq!(back.predicted.load_equations, plan.predicted.load_equations);
        assert_eq!(back.predicted.rounds, 1);
    }

    #[test]
    fn predicted_rounds_track_the_ir() {
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let plan = JobBuilder::new(&c, &job).build().unwrap();
        assert_eq!(plan.predicted.rounds, plan.shuffle.round_count() as u64);
        assert!(plan.predicted.rounds >= 1);
    }

    #[test]
    fn tampered_plan_fails_validation() {
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let mut plan = JobBuilder::new(&c, &job).build().unwrap();
        // Drop one broadcast: the JSON still parses but no longer decodes.
        plan.shuffle.pop_broadcast();
        let text = plan.to_json_string();
        assert!(matches!(
            Plan::from_json_str(&text).unwrap_err(),
            HetcdcError::Undecodable { .. }
        ));
    }

    #[test]
    fn hostile_plan_sender_fails_typed_not_panicking() {
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let plan = JobBuilder::new(&c, &job).build().unwrap();
        // Corrupt a sender id beyond K in the serialized form.
        let text = plan.to_json_string().replacen("\"sender\": 0", "\"sender\": 40", 1);
        match Plan::from_json_str(&text) {
            Err(HetcdcError::PlanMismatch(_)) | Err(HetcdcError::Undecodable { .. }) => {}
            other => panic!("expected typed rejection, got {other:?}"),
        }
    }

    #[test]
    fn straggle_build_changes_schedule_fields_only() {
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let base = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        // Amplitude large enough that the jittered Map tail dwarfs the
        // shuffle duration, so some send provably stalls.
        let faults = FaultSpec::parse("straggle:seed=0xbe7c,amp=1000").unwrap();
        let slow = JobBuilder::new(&c, &job)
            .placer("optimal-k3")
            .faults(faults)
            .build()
            .unwrap();
        // Byte/message/round counts and the nominal Map barrier are
        // untouched; only the shuffle schedule stretches.
        assert_eq!(slow.predicted.payload_bytes, base.predicted.payload_bytes);
        assert_eq!(slow.predicted.wire_bytes, base.predicted.wire_bytes);
        assert_eq!(slow.predicted.messages, base.predicted.messages);
        assert_eq!(slow.predicted.rounds, base.predicted.rounds);
        assert_eq!(slow.predicted.map_time_s.to_bits(), base.predicted.map_time_s.to_bits());
        assert!(slow.predicted.straggler_delay_s > 0.0);
        assert!(slow.predicted.shuffle_time_s > base.predicted.shuffle_time_s);
        assert_eq!(base.predicted.straggler_delay_s, 0.0);
        // The fault spec is part of the shape.
        assert_ne!(slow.fingerprint, base.fingerprint);
        assert!(!slow.shape_matches(&c, &job));
        assert!(base.shape_matches(&c, &job));
        // Fault-free artifacts never carry the fault keys.
        assert!(!base.to_json_string().contains("straggler_delay_s"));
        assert!(!base.to_json_string().contains("faults"));
        assert!(slow.to_json_string().contains("straggler_delay_s"));
        // Fault plans roundtrip (re-validated, predictions recomputed).
        let back = Plan::from_json_str(&slow.to_json_string()).unwrap();
        assert_eq!(back.predicted, slow.predicted);
        assert_eq!(back.fingerprint, slow.fingerprint);
    }

    #[test]
    fn straggler_ready_is_zero_at_the_barrier_and_scales_past_it() {
        let mut c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let plan = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        assert!(straggler_ready(&c, &plan.alloc).is_none());
        c.faults = FaultSpec::parse("straggle:seed=0x1,amp=1.5").unwrap();
        let ready = straggler_ready(&c, &plan.alloc).unwrap();
        assert_eq!(ready.len(), 3);
        let slow = c.faults.slowdowns(3);
        for (node, &r) in ready.iter().enumerate() {
            assert!(r >= 0.0);
            let files = plan.alloc.node_count(node) as f64 / plan.alloc.sp as f64;
            let base = files / c.nodes[node].map_files_per_s.max(1e-9);
            let b0 = plan.predicted.map_time_s;
            assert_eq!(r.to_bits(), (slow[node] * base - b0).max(0.0).to_bits());
        }
        // amp=0 jitters nothing: every node still makes the barrier.
        c.faults = FaultSpec::parse("straggle:seed=0x1,amp=0").unwrap();
        assert_eq!(straggler_ready(&c, &plan.alloc).unwrap(), vec![0.0; 3]);
    }

    #[test]
    fn repair_build_appends_verified_rounds() {
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let base = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        let plan = JobBuilder::new(&c, &job)
            .placer("optimal-k3")
            .faults(FaultSpec::parse("repair:f=1").unwrap())
            .build()
            .unwrap();
        assert!(plan.shuffle.n_broadcasts() > base.shuffle.n_broadcasts());
        assert_eq!(plan.shuffle.round_count(), base.shuffle.round_count() + 1);
        assert!(plan.predicted.wire_bytes > base.predicted.wire_bytes);
        // The artifact roundtrips — the loss-pattern gate re-proves it.
        let back = Plan::from_json_str(&plan.to_json_string()).unwrap();
        assert_eq!(back.shuffle, plan.shuffle);
        // Tampering a repair round away fails the gate typed.
        let mut broken = plan.clone();
        broken.shuffle.pop_broadcast();
        assert!(Plan::from_json_str(&broken.to_json_string()).is_err());
    }

    #[test]
    fn replan_without_drops_a_node_and_meters_recovery() {
        let c = cluster(&[3, 4, 5, 6]);
        let job = JobSpec::terasort(8);
        let plan = JobBuilder::new(&c, &job).build().unwrap();
        for node in 0..4 {
            let re = match plan.replan_without(node) {
                Ok(re) => re,
                // A node that solely held some subfile is a typed error.
                Err(HetcdcError::InvalidPlacement(_)) => continue,
                Err(e) => panic!("unexpected: {e}"),
            };
            assert_eq!(re.cluster.k(), 3);
            assert_eq!(re.alloc.n_sub(), plan.alloc.n_sub());
            // Survivors keep their subfile sets: mask bits shift down.
            for (sub, &h) in plan.alloc.holders.iter().enumerate() {
                for old in 0..4usize {
                    if old == node {
                        continue;
                    }
                    let new = if old > node { old - 1 } else { old };
                    assert_eq!(
                        h & (1 << old) != 0,
                        re.alloc.holders[sub] & (1 << new) != 0,
                        "node {old} subfile {sub}"
                    );
                }
            }
            // The replanned artifact is fully valid on its own.
            assert!(Plan::from_json_str(&re.to_json_string()).is_ok());
        }
        assert!(plan.replan_without(9).is_err());
    }

    #[test]
    fn replan_without_rejects_solely_held_subfiles() {
        // Hand-build an allocation where node 0 is the only holder of
        // subfile 0.
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let plan = JobBuilder::new(&c, &job).placer("optimal-k3").build().unwrap();
        let solely = plan
            .alloc
            .holders
            .iter()
            .position(|&h| h.count_ones() == 1)
            .expect("the K=3 optimal placement has single-held subfiles");
        let node = plan.alloc.holders[solely].trailing_zeros() as usize;
        assert!(matches!(
            plan.replan_without(node),
            Err(HetcdcError::InvalidPlacement(_))
        ));
    }

    #[test]
    fn fingerprint_ignores_seed_but_not_shape() {
        let c = cluster(&[6, 7, 7]);
        let mut a = JobSpec::terasort(12);
        let mut b = a.clone();
        b.seed = a.seed.wrapping_add(1);
        assert_eq!(shape_fingerprint(&c, &a), shape_fingerprint(&c, &b));
        a.n_files = 10;
        assert_ne!(shape_fingerprint(&c, &a), shape_fingerprint(&c, &b));
        let c2 = cluster(&[6, 7, 8]);
        assert_ne!(shape_fingerprint(&c, &b), shape_fingerprint(&c2, &b));
    }
}
