//! The MapReduce engine: the staged `JobBuilder` → [`Plan`] →
//! [`Executor`] pipeline over the simulated broadcast network, with
//! byte-exact load accounting and oracle-verified outputs.
//!
//! * [`plan`] — build and serialize validated execution plans.
//! * [`executor`] — run many data batches against one plan: serial,
//!   shard-parallel within a batch, or batch-pipelined (Map of batch
//!   `i+1` overlapped with Shuffle of batch `i`), all bit-identical.
//! * [`cache`] — [`PlanCache`], the heavy-traffic memo of built plans.
//! * [`engine`] — [`Engine`], the one-shot facade, and [`RunReport`].
//! * [`exec`] — byte-level shuffle execution primitives.
//! * [`backend`] — native and PJRT compute backends.

pub mod backend;
pub mod cache;
#[allow(clippy::module_inception)]
pub mod engine;
pub mod exec;
pub mod executor;
pub mod plan;

pub use backend::{MapBackend, NativeBackend, XlaBackend};
pub use cache::{PlanCache, PlanKey};
pub use engine::{Engine, RunReport};
pub use executor::{ExecConfig, ExecMode, Executor};
pub use plan::{
    resolve_threads, shape_fingerprint, straggler_ready, JobBuilder, Plan, PredictedLoads,
};
