//! The MapReduce engine: Map -> coded Shuffle -> Reduce over the simulated
//! broadcast network, with byte-exact load accounting and oracle-verified
//! outputs.

pub mod backend;
pub mod exec;
#[allow(clippy::module_inception)]
pub mod engine;

pub use backend::{MapBackend, NativeBackend, XlaBackend};
pub use engine::{Engine, PlacementStrategy, RunReport};
