//! Shuffle plans: the group-structured multicast IR of the Shuffle phase.
//!
//! A [`ShufflePlan`] is a sequence of [`ShuffleRound`]s; each round is a
//! set of [`MulticastGroup`]s, and each group carries the broadcasts of
//! one cooperating node subset (the paper's multicast groups — the
//! (r+1)-subsets `A` of [2]'s scheme, the pair/triple sets of Lemma 1,
//! the grid transversals of the combinatorial design). Per broadcast, the
//! IR records the sender and the XOR of IV *parts* it carries (a part is
//! a `seg/nseg` fraction of one IV payload; `nseg = 1` for whole-IV XOR
//! pairs, `nseg = r` for the homogeneous multicast of [2]).
//!
//! Rounds are the sequential stages of the Shuffle: the engine meters and
//! decodes round by round (per-round sections in
//! [`crate::net::NetReport`]), and groups within one round are pairwise
//! structured so a future non-shared medium could run them concurrently.
//! Plans are independent of payload bytes — the engine executes them
//! against real IVs, and [`crate::coding::decoder`] verifies them
//! symbolically over the flattened broadcast order (round-major,
//! group-major; all broadcast *indices* refer to that order).
//!
//! With `Q = K`, intermediate value `(g, f)` is "the IV of node `g`'s
//! reduce-function group on subfile `f`"; node `g` needs it iff it does
//! not hold `f`.

use super::xor; // used by doc references; keep module coupling explicit
use crate::error::{HetcdcError, Result};
use crate::placement::alloc::{Allocation, NodeMask};
use crate::placement::lemma1::{pairing_counts, PAIR_MASKS};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Identifies one intermediate value: reduce group `group` (== destination
/// node under Q=K) on subfile `sub`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct IvId {
    pub group: usize,
    pub sub: usize,
}

/// One summand of a coded broadcast: segment `seg` of `nseg` equal splits
/// of IV `iv`'s payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Part {
    pub iv: IvId,
    pub seg: u32,
    pub nseg: u32,
}

impl Part {
    pub fn whole(iv: IvId) -> Self {
        Part { iv, seg: 0, nseg: 1 }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Broadcast {
    /// Plain IV broadcast (destination(s) implied by who lacks `iv.sub`).
    Uncoded { sender: usize, iv: IvId },
    /// XOR of `parts` (all the same `nseg`).
    Coded { sender: usize, parts: Vec<Part> },
}

impl Broadcast {
    /// Transmission size in IV units: 1 for uncoded/whole XOR, 1/nseg for
    /// segment XOR. Returned as (num, den).
    pub fn units(&self) -> (u64, u64) {
        match self {
            Broadcast::Uncoded { .. } => (1, 1),
            Broadcast::Coded { parts, .. } => {
                let nseg = parts.first().map(|p| p.nseg).unwrap_or(1);
                debug_assert!(parts.iter().all(|p| p.nseg == nseg));
                (1, nseg as u64)
            }
        }
    }

    pub fn sender(&self) -> usize {
        match self {
            Broadcast::Uncoded { sender, .. } | Broadcast::Coded { sender, .. } => *sender,
        }
    }
}

/// One multicast group of a round: the broadcasts through which the node
/// subset `members` exchanges IVs. `members` covers every sender of the
/// group's broadcasts plus the decoding destinations — informational
/// structure for reports and round scheduling, not consulted by the
/// decoder (decodability is a property of the broadcasts alone).
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct MulticastGroup {
    /// Bitmask of the cooperating nodes.
    pub members: NodeMask,
    pub broadcasts: Vec<Broadcast>,
}

/// One sequential stage of the Shuffle phase.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct ShuffleRound {
    pub groups: Vec<MulticastGroup>,
}

impl ShuffleRound {
    pub fn n_broadcasts(&self) -> usize {
        self.groups.iter().map(|g| g.broadcasts.len()).sum()
    }
}

#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShufflePlan {
    pub k: usize,
    pub rounds: Vec<ShuffleRound>,
}

impl ShufflePlan {
    /// Empty plan for a K-node job.
    pub fn new(k: usize) -> Self {
        ShufflePlan { k, rounds: Vec::new() }
    }

    /// Wrap a flat broadcast list (the pre-IR legacy form) as a
    /// single-round plan, one group per broadcast with `members` set to
    /// the sender alone. Used by the legacy-JSON read shim and by ad-hoc
    /// plans in tests/benches.
    pub fn from_broadcasts(k: usize, broadcasts: Vec<Broadcast>) -> Self {
        if broadcasts.is_empty() {
            return ShufflePlan::new(k);
        }
        let groups = broadcasts
            .into_iter()
            .map(|b| MulticastGroup {
                members: 1u32 << b.sender(),
                broadcasts: vec![b],
            })
            .collect();
        ShufflePlan {
            k,
            rounds: vec![ShuffleRound { groups }],
        }
    }

    /// Append a round (empty rounds are dropped — they carry no
    /// broadcasts and would only pad the round count).
    pub fn push_round(&mut self, round: ShuffleRound) {
        if !round.groups.is_empty() {
            self.rounds.push(round);
        }
    }

    /// Append one broadcast as its own group to the last round (creating
    /// a round when the plan has none).
    pub fn push_broadcast(&mut self, members: NodeMask, b: Broadcast) {
        if self.rounds.is_empty() {
            self.rounds.push(ShuffleRound::default());
        }
        self.rounds
            .last_mut()
            .unwrap()
            .groups
            .push(MulticastGroup { members, broadcasts: vec![b] });
    }

    /// Remove and return the plan's final broadcast (flattened order),
    /// pruning any group/round it empties. For tamper tests.
    pub fn pop_broadcast(&mut self) -> Option<Broadcast> {
        loop {
            let round = self.rounds.last_mut()?;
            match round.groups.last_mut() {
                None => {
                    self.rounds.pop();
                }
                Some(group) => match group.broadcasts.pop() {
                    Some(b) => {
                        if group.broadcasts.is_empty() {
                            round.groups.pop();
                            if round.groups.is_empty() {
                                self.rounds.pop();
                            }
                        }
                        return Some(b);
                    }
                    None => {
                        round.groups.pop();
                        if round.groups.is_empty() {
                            self.rounds.pop();
                        }
                    }
                },
            }
        }
    }

    pub fn round_count(&self) -> usize {
        self.rounds.len()
    }

    /// Multicast groups across all rounds (the bench artifact's
    /// `plan_build` section reports this next to rounds and broadcasts).
    pub fn group_count(&self) -> usize {
        self.rounds.iter().map(|r| r.groups.len()).sum()
    }

    pub fn n_broadcasts(&self) -> usize {
        self.rounds.iter().map(|r| r.n_broadcasts()).sum()
    }

    /// Broadcasts in flattened (round-major, group-major) order — the
    /// canonical transmission order every index in a
    /// [`crate::coding::decoder::DecodeSchedule`] refers to.
    pub fn iter_broadcasts(&self) -> impl Iterator<Item = &Broadcast> {
        self.rounds
            .iter()
            .flat_map(|r| r.groups.iter())
            .flat_map(|g| g.broadcasts.iter())
    }

    /// Flat index at which each round starts (length = round count). The
    /// executor calls [`crate::net::BroadcastNet::begin_round`] at these
    /// indices so the ledger records per-round sections.
    pub fn round_starts(&self) -> Vec<usize> {
        let mut starts = Vec::with_capacity(self.rounds.len());
        let mut at = 0usize;
        for r in &self.rounds {
            starts.push(at);
            at += r.n_broadcasts();
        }
        starts
    }

    /// Broadcast count per round, in order (bench artifacts diff this to
    /// catch coders silently degrading to one giant round).
    pub fn round_sizes(&self) -> Vec<usize> {
        self.rounds.iter().map(|r| r.n_broadcasts()).collect()
    }

    /// `flags[bi]` = flat index `bi` is the first broadcast of a round —
    /// the single encoding of the round-boundary invariant every metering
    /// pass shares: call
    /// [`crate::net::BroadcastNet::begin_round`] exactly where a flag is
    /// set and the per-round ledger sections mirror the IR in every
    /// execution mode.
    pub fn round_start_flags(&self) -> Vec<bool> {
        let mut flags = vec![false; self.n_broadcasts()];
        for s in self.round_starts() {
            if let Some(f) = flags.get_mut(s) {
                *f = true;
            }
        }
        flags
    }

    /// `masks[bi]` = `Some(members)` when flat index `bi` is the first
    /// broadcast of a multicast group (carrying that group's member
    /// mask), `None` inside a group. The metering passes call
    /// [`crate::net::BroadcastNet::begin_group`] exactly where a mask is
    /// present — the group-boundary counterpart of
    /// [`Self::round_start_flags`], and the only structural input the
    /// switched-topology scheduler needs (groups of a round run
    /// concurrently when their links are disjoint).
    pub fn group_start_masks(&self) -> Vec<Option<NodeMask>> {
        let mut masks = Vec::with_capacity(self.n_broadcasts());
        for round in &self.rounds {
            for group in &round.groups {
                for (i, _) in group.broadcasts.iter().enumerate() {
                    masks.push(if i == 0 { Some(group.members) } else { None });
                }
            }
        }
        masks
    }

    /// `(round, group, broadcast)` coordinates of every broadcast in
    /// flattened order: `coords()[bi]` names flat index `bi` by its round
    /// index, within-round group index, and within-group broadcast index.
    /// This is the addressing scheme both erasure forms use —
    /// `erase:list=r.g.b` matches these triples literally, and the seeded
    /// model keys its per-broadcast hash on them — so the same broadcast
    /// is erased no matter which exec mode or thread count replays the
    /// plan.
    pub fn coords(&self) -> Vec<(usize, usize, usize)> {
        let mut coords = Vec::with_capacity(self.n_broadcasts());
        for (r, round) in self.rounds.iter().enumerate() {
            for (g, group) in round.groups.iter().enumerate() {
                for b in 0..group.broadcasts.len() {
                    coords.push((r, g, b));
                }
            }
        }
        coords
    }

    /// Total load in subfile units (exact rational; integral when all
    /// broadcasts are whole-IV).
    pub fn load_units(&self) -> f64 {
        let mut num = 0u64;
        let mut frac = 0.0f64;
        for b in self.iter_broadcasts() {
            let (n, d) = b.units();
            if d == 1 {
                num += n;
            } else {
                frac += n as f64 / d as f64;
            }
        }
        num as f64 + frac
    }

    /// Load in IV-equation units, given the allocation's subpacketization.
    pub fn load_equations(&self, alloc: &Allocation) -> f64 {
        self.load_units() / alloc.sp as f64
    }

    /// Coding ratio: fraction of broadcasts that are coded.
    pub fn coded_fraction(&self) -> f64 {
        let total = self.n_broadcasts();
        if total == 0 {
            return 0.0;
        }
        let coded = self
            .iter_broadcasts()
            .filter(|b| matches!(b, Broadcast::Coded { .. }))
            .count();
        coded as f64 / total as f64
    }

    /// Structural bounds check against a K-node, `n_sub`-subfile job:
    /// senders/groups within `[0, K)`, subfiles within `[0, n_sub)`,
    /// segment indices within a sane `nseg`, uniform `nseg` per
    /// broadcast, and every group's `members` a non-empty in-range mask
    /// containing its senders. Deserialized plans go through this before
    /// the symbolic decoder touches them, so hostile artifacts fail typed
    /// instead of panicking an executor.
    pub fn validate(&self, k: usize, n_sub: usize) -> Result<()> {
        let bad = |i: usize, m: String| {
            HetcdcError::PlanMismatch(format!("broadcast {i}: {m}"))
        };
        let check_iv = |i: usize, iv: &IvId| -> Result<()> {
            if iv.group >= k {
                return Err(bad(i, format!("group {} out of range [0, {k})", iv.group)));
            }
            if iv.sub >= n_sub {
                return Err(bad(i, format!("subfile {} out of range [0, {n_sub})", iv.sub)));
            }
            Ok(())
        };
        if self.k != k {
            return Err(HetcdcError::PlanMismatch(format!(
                "shuffle plan is for K={}, expected K={k}",
                self.k
            )));
        }
        let full: NodeMask = if k == 32 { u32::MAX } else { (1u32 << k) - 1 };
        let mut i = 0usize; // flat broadcast index, for error messages
        for (ri, round) in self.rounds.iter().enumerate() {
            // Empty rounds/groups never come out of a builder (push_round
            // prunes them) but can arrive via deserialized artifacts, and
            // they would desync the per-round metering sections from the
            // round count — reject at the validation gate.
            if round.groups.is_empty() {
                return Err(HetcdcError::PlanMismatch(format!(
                    "round {ri}: empty round (no multicast groups)"
                )));
            }
            for group in &round.groups {
                if group.broadcasts.is_empty() {
                    return Err(HetcdcError::PlanMismatch(format!(
                        "round {ri}: multicast group with no broadcasts"
                    )));
                }
                if group.members == 0 || group.members & !full != 0 {
                    return Err(HetcdcError::PlanMismatch(format!(
                        "round {ri}: group members {:#b} invalid for K={k}",
                        group.members
                    )));
                }
                for b in &group.broadcasts {
                    if b.sender() >= k {
                        return Err(bad(i, format!("sender {} out of range [0, {k})", b.sender())));
                    }
                    if group.members & (1 << b.sender()) == 0 {
                        return Err(bad(
                            i,
                            format!("sender {} not a member of its group", b.sender()),
                        ));
                    }
                    match b {
                        Broadcast::Uncoded { iv, .. } => check_iv(i, iv)?,
                        Broadcast::Coded { parts, .. } => {
                            let nseg = match parts.first() {
                                Some(p) => p.nseg,
                                None => return Err(bad(i, "coded broadcast with no parts".into())),
                            };
                            if nseg == 0 || nseg > 64 {
                                return Err(bad(i, format!("nseg {nseg} out of range [1, 64]")));
                            }
                            for p in parts {
                                if p.nseg != nseg {
                                    return Err(bad(i, "mixed nseg within one broadcast".into()));
                                }
                                if p.seg >= nseg {
                                    return Err(bad(i, format!("segment {} >= nseg {nseg}", p.seg)));
                                }
                                check_iv(i, &p.iv)?;
                            }
                        }
                    }
                    i += 1;
                }
            }
        }
        Ok(())
    }

    /// Clone of the plan with the broadcast at `flat_index` (flattened
    /// round-major, group-major order) removed, pruning any group or
    /// round the removal empties; an out-of-range index returns an
    /// unmodified clone. Loss-pattern verification builds "plan minus
    /// the lost broadcasts" this way — flat indices after `flat_index`
    /// shift down by one, so the result is for completeness checks
    /// ([`crate::coding::decoder::verify`]), not for reusing a
    /// [`crate::coding::decoder::DecodeSchedule`] built on `self`.
    pub fn without_broadcast(&self, flat_index: usize) -> ShufflePlan {
        let mut out = ShufflePlan::new(self.k);
        let mut at = 0usize;
        for round in &self.rounds {
            let mut new_round = ShuffleRound::default();
            for group in &round.groups {
                let mut copy =
                    MulticastGroup { members: group.members, broadcasts: Vec::new() };
                for b in &group.broadcasts {
                    if at != flat_index {
                        copy.broadcasts.push(b.clone());
                    }
                    at += 1;
                }
                if !copy.broadcasts.is_empty() {
                    new_round.groups.push(copy);
                }
            }
            out.push_round(new_round);
        }
        out
    }

    /// JSON form used inside serialized [`crate::engine::Plan`] artifacts
    /// (Shuffle IR v2; schema in DESIGN.md).
    pub fn to_json(&self) -> Json {
        let rounds: Vec<Json> = self
            .rounds
            .iter()
            .map(|round| {
                let groups: Vec<Json> = round
                    .groups
                    .iter()
                    .map(|group| {
                        let mut gm = BTreeMap::new();
                        gm.insert("members".into(), Json::Num(group.members as f64));
                        gm.insert(
                            "broadcasts".into(),
                            Json::Arr(group.broadcasts.iter().map(broadcast_to_json).collect()),
                        );
                        Json::Obj(gm)
                    })
                    .collect();
                let mut rm = BTreeMap::new();
                rm.insert("groups".into(), Json::Arr(groups));
                Json::Obj(rm)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("version".into(), Json::Num(2.0));
        m.insert("k".into(), Json::Num(self.k as f64));
        m.insert("rounds".into(), Json::Arr(rounds));
        Json::Obj(m)
    }

    /// Parse the v2 round/group form, or — legacy-read shim — a v1 flat
    /// `"broadcasts"` list, which becomes a single-round plan via
    /// [`ShufflePlan::from_broadcasts`].
    pub fn from_json(j: &Json) -> Result<Self> {
        let bad = |f: &str| HetcdcError::Json(format!("shuffle plan: missing or invalid '{f}'"));
        let k = j.get("k").and_then(|v| v.as_usize()).ok_or_else(|| bad("k"))?;
        if let Some(rounds_json) = j.get("rounds").and_then(|v| v.as_arr()) {
            let mut plan = ShufflePlan::new(k);
            for round_json in rounds_json {
                let mut round = ShuffleRound::default();
                for group_json in round_json
                    .get("groups")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| bad("groups"))?
                {
                    let members = group_json
                        .get("members")
                        .and_then(|v| v.as_usize())
                        .filter(|&m| m <= u32::MAX as usize)
                        .ok_or_else(|| bad("members"))? as u32;
                    let mut group = MulticastGroup { members, broadcasts: Vec::new() };
                    for b in group_json
                        .get("broadcasts")
                        .and_then(|v| v.as_arr())
                        .ok_or_else(|| bad("broadcasts"))?
                    {
                        group.broadcasts.push(broadcast_from_json(b)?);
                    }
                    round.groups.push(group);
                }
                plan.rounds.push(round);
            }
            return Ok(plan);
        }
        // Legacy v1: flat broadcast list, no round/group structure.
        let mut broadcasts = Vec::new();
        for b in j
            .get("broadcasts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| bad("rounds"))?
        {
            broadcasts.push(broadcast_from_json(b)?);
        }
        Ok(ShufflePlan::from_broadcasts(k, broadcasts))
    }
}

fn broadcast_to_json(b: &Broadcast) -> Json {
    let mut m = BTreeMap::new();
    match b {
        Broadcast::Uncoded { sender, iv } => {
            m.insert("type".into(), Json::Str("uncoded".into()));
            m.insert("sender".into(), Json::Num(*sender as f64));
            m.insert("group".into(), Json::Num(iv.group as f64));
            m.insert("sub".into(), Json::Num(iv.sub as f64));
        }
        Broadcast::Coded { sender, parts } => {
            m.insert("type".into(), Json::Str("coded".into()));
            m.insert("sender".into(), Json::Num(*sender as f64));
            let parts: Vec<Json> = parts
                .iter()
                .map(|p| {
                    let mut pm = BTreeMap::new();
                    pm.insert("group".into(), Json::Num(p.iv.group as f64));
                    pm.insert("sub".into(), Json::Num(p.iv.sub as f64));
                    pm.insert("seg".into(), Json::Num(p.seg as f64));
                    pm.insert("nseg".into(), Json::Num(p.nseg as f64));
                    Json::Obj(pm)
                })
                .collect();
            m.insert("parts".into(), Json::Arr(parts));
        }
    }
    Json::Obj(m)
}

fn broadcast_from_json(b: &Json) -> Result<Broadcast> {
    let bad = |f: &str| HetcdcError::Json(format!("shuffle plan: missing or invalid '{f}'"));
    let get_usize = |o: &Json, f: &'static str| -> Result<usize> {
        o.get(f).and_then(|v| v.as_usize()).ok_or_else(|| bad(f))
    };
    let sender = get_usize(b, "sender")?;
    match b.get("type").and_then(|v| v.as_str()) {
        Some("uncoded") => Ok(Broadcast::Uncoded {
            sender,
            iv: IvId {
                group: get_usize(b, "group")?,
                sub: get_usize(b, "sub")?,
            },
        }),
        Some("coded") => {
            let mut parts = Vec::new();
            for p in b
                .get("parts")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| bad("parts"))?
            {
                let nseg = get_usize(p, "nseg")? as u32;
                if nseg == 0 {
                    return Err(bad("nseg"));
                }
                parts.push(Part {
                    iv: IvId {
                        group: get_usize(p, "group")?,
                        sub: get_usize(p, "sub")?,
                    },
                    seg: get_usize(p, "seg")? as u32,
                    nseg,
                });
            }
            if parts.is_empty() {
                return Err(bad("parts"));
            }
            Ok(Broadcast::Coded { sender, parts })
        }
        _ => Err(bad("type")),
    }
}

/// Group members of an uncoded delivery of subfile `sub`: the sender plus
/// every node lacking the subfile (they all decode the broadcast).
fn uncoded_members(alloc: &Allocation, sender: usize, sub: usize) -> NodeMask {
    (1u32 << sender) | (alloc.full_mask() & !alloc.holders[sub])
}

/// Exact Lemma-1 plan for K=3 allocations (achieves `L_M` of eq. (3)),
/// expressed on the round IR as three stages: single-held subfiles
/// (uncoded), the XOR pairings of eqs. (8)–(10), and uncoded leftovers.
///
/// Node k XOR-pairs the two pair-sets it holds (the evidently-intended
/// reading of eqs. (8)–(10); see DESIGN.md §9): with pair-sets
/// `S12, S13, S23` and optimal counts `(alpha, beta, gamma)` from
/// [`pairing_counts`], node 0 sends `alpha` XORs over `S12 × S13`, node 1
/// `beta` over `S12 × S23`, node 2 `gamma` over `S13 × S23`; leftovers and
/// single-held subfiles go uncoded.
pub fn plan_k3(alloc: &Allocation) -> ShufflePlan {
    assert_eq!(alloc.k, 3, "plan_k3 requires K=3");
    let mut plan = ShufflePlan::new(3);

    // Round 1 — singles: holder broadcasts both other groups' IVs; one
    // group per single-held subfile (all three nodes participate).
    let mut singles = ShuffleRound::default();
    for (mask, holder) in [(0b001u32, 0usize), (0b010, 1), (0b100, 2)] {
        for sub in alloc.subfiles_with_mask(mask) {
            let mut group = MulticastGroup { members: 0b111, broadcasts: Vec::new() };
            for dest in 0..3 {
                if dest != holder {
                    group.broadcasts.push(Broadcast::Uncoded {
                        sender: holder,
                        iv: IvId { group: dest, sub },
                    });
                }
            }
            singles.groups.push(group);
        }
    }
    plan.push_round(singles);

    // Pair sets: S12 (mask 011, missing node 2), S13 (101, missing 1),
    // S23 (110, missing 0).
    let s12 = alloc.subfiles_with_mask(PAIR_MASKS[0]);
    let s13 = alloc.subfiles_with_mask(PAIR_MASKS[1]);
    let s23 = alloc.subfiles_with_mask(PAIR_MASKS[2]);
    let (alpha, beta, gamma) =
        pairing_counts(s12.len() as u64, s13.len() as u64, s23.len() as u64);
    let (alpha, beta, gamma) = (alpha as usize, beta as usize, gamma as usize);

    let missing = |pair_idx: usize| -> usize {
        match pair_idx {
            0 => 2, // S12 -> node 2 lacks it
            1 => 1, // S13 -> node 1
            2 => 0, // S23 -> node 0
            _ => unreachable!(),
        }
    };

    // Round 2 — the XOR pairings; every group is the full triple.
    let mut coded = ShuffleRound::default();
    let push_xor = |round: &mut ShuffleRound, sender: usize, a: (usize, usize), b: (usize, usize)| {
        round.groups.push(MulticastGroup {
            members: 0b111,
            broadcasts: vec![Broadcast::Coded {
                sender,
                parts: vec![
                    Part::whole(IvId { group: a.0, sub: a.1 }),
                    Part::whole(IvId { group: b.0, sub: b.1 }),
                ],
            }],
        });
    };
    // alpha XORs at node 0 over (S12, S13); consume prefixes.
    for i in 0..alpha {
        push_xor(&mut coded, 0, (missing(0), s12[i]), (missing(1), s13[i]));
    }
    // beta XORs at node 1 over (S12, S23).
    for i in 0..beta {
        push_xor(&mut coded, 1, (missing(0), s12[alpha + i]), (missing(2), s23[i]));
    }
    // gamma XORs at node 2 over (S13, S23).
    for i in 0..gamma {
        push_xor(&mut coded, 2, (missing(1), s13[alpha + i]), (missing(2), s23[beta + i]));
    }
    plan.push_round(coded);

    // Round 3 — leftover pair subfiles go uncoded from their lowest holder.
    let mut leftovers = ShuffleRound::default();
    for (list, consumed, pair_idx, sender) in [
        (&s12, alpha + beta, 0usize, 0usize),
        (&s13, alpha + gamma, 1, 0),
        (&s23, beta + gamma, 2, 1),
    ] {
        for &sub in &list[consumed..] {
            leftovers.groups.push(MulticastGroup {
                members: uncoded_members(alloc, sender, sub),
                broadcasts: vec![Broadcast::Uncoded {
                    sender,
                    iv: IvId { group: missing(pair_idx), sub },
                }],
            });
        }
    }
    plan.push_round(leftovers);
    plan
}

/// Greedy pairing coder for arbitrary K: pairs deliveries `(d1, f1)` and
/// `(d2, f2)` into one XOR when a common sender holds both subfiles and
/// each destination holds the *other* subfile (so it can cancel). Emits
/// two rounds: the XOR pairs (one `{sender, d1, d2}` group each), then
/// the unpaired leftovers uncoded. A valid achievable scheme for any
/// allocation; exactly optimal pair-coding for K=3 is provided by
/// [`plan_k3`] instead.
pub fn plan_greedy(alloc: &Allocation) -> ShufflePlan {
    let k = alloc.k;
    let full = alloc.full_mask();
    // Deliveries: (dest, sub) for every node lacking the subfile.
    let mut deliveries: Vec<(usize, usize)> = Vec::new();
    for (sub, &h) in alloc.holders.iter().enumerate() {
        if h == full {
            continue;
        }
        for dest in 0..k {
            if h & (1 << dest) == 0 {
                deliveries.push((dest, sub));
            }
        }
    }

    let mut used = vec![false; deliveries.len()];
    let mut coded = ShuffleRound::default();
    let mut leftovers = ShuffleRound::default();

    // Bucket deliveries by destination for faster partner search.
    let mut by_dest: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (i, &(d, _)) in deliveries.iter().enumerate() {
        by_dest[d].push(i);
    }

    for i in 0..deliveries.len() {
        if used[i] {
            continue;
        }
        let (d1, f1) = deliveries[i];
        let h1 = alloc.holders[f1];
        let mut matched = false;
        // Partner must be destined to a node that holds f1.
        'outer: for d2 in 0..k {
            if d2 == d1 || h1 & (1 << d2) == 0 {
                continue;
            }
            for &j in &by_dest[d2] {
                if used[j] || j == i {
                    continue;
                }
                let (_, f2) = deliveries[j];
                let h2 = alloc.holders[f2];
                // d1 must hold f2; a sender must hold both (not d1/d2).
                if h2 & (1 << d1) == 0 {
                    continue;
                }
                let senders = h1 & h2 & !(1 << d1) & !(1 << d2);
                if senders == 0 {
                    continue;
                }
                let sender = senders.trailing_zeros() as usize;
                used[i] = true;
                used[j] = true;
                coded.groups.push(MulticastGroup {
                    members: (1 << sender) | (1 << d1) | (1 << d2),
                    broadcasts: vec![Broadcast::Coded {
                        sender,
                        parts: vec![
                            Part::whole(IvId { group: d1, sub: f1 }),
                            Part::whole(IvId { group: d2, sub: f2 }),
                        ],
                    }],
                });
                matched = true;
                break 'outer;
            }
        }
        if !matched {
            used[i] = true;
            let sender = alloc.holders[f1].trailing_zeros() as usize;
            leftovers.groups.push(MulticastGroup {
                members: uncoded_members(alloc, sender, f1),
                broadcasts: vec![Broadcast::Uncoded {
                    sender,
                    iv: IvId { group: d1, sub: f1 },
                }],
            });
        }
    }
    let mut plan = ShufflePlan::new(k);
    plan.push_round(coded);
    plan.push_round(leftovers);
    plan
}

/// Fully-uncoded baseline plan: every delivery as a plain broadcast, one
/// round, one group per subfile (sender plus all receivers).
pub fn plan_uncoded(alloc: &Allocation) -> ShufflePlan {
    let k = alloc.k;
    let full = alloc.full_mask();
    let mut round = ShuffleRound::default();
    for (sub, &h) in alloc.holders.iter().enumerate() {
        if h == full {
            continue;
        }
        let sender = h.trailing_zeros() as usize;
        let mut group = MulticastGroup {
            members: uncoded_members(alloc, sender, sub),
            broadcasts: Vec::new(),
        };
        for dest in 0..k {
            if h & (1 << dest) == 0 {
                group.broadcasts.push(Broadcast::Uncoded {
                    sender,
                    iv: IvId { group: dest, sub },
                });
            }
        }
        round.groups.push(group);
    }
    let mut plan = ShufflePlan::new(k);
    plan.push_round(round);
    plan
}

/// Degraded-decode construction (`repair:f=N` in a
/// [`crate::net::FaultSpec`]): append repair rounds so the returned plan
/// tolerates any `f` lost broadcasts.
///
/// - `f == 1`: a single loss can only break decode through a broadcast
///   whose individual removal makes the base plan incomplete (call it
///   *critical*). One repair round duplicates exactly the critical
///   broadcasts, mirroring their original group members; losing the
///   duplicate instead is harmless because the base stays intact.
/// - `f >= 2`: joint losses can break decode through broadcasts that are
///   individually non-critical, so pruning is unsound — `f` full-copy
///   rounds are appended (`f + 1` copies of every broadcast survive any
///   `f` losses).
///
/// Duplicates are decoder-safe: a copy's unknown-part counter reaches
/// zero once the original decodes, so it never enters a
/// [`crate::coding::decoder::DecodeSchedule`] twice. The builder calls
/// [`crate::coding::decoder::verify_loss_patterns`] on the result, so
/// the tolerance claim is proved, not assumed.
pub fn with_repair_rounds(
    base: &ShufflePlan,
    alloc: &Allocation,
    f: usize,
) -> Result<ShufflePlan> {
    if f == 0 {
        return Ok(base.clone());
    }
    if !super::decoder::verify(alloc, base).is_complete() {
        return Err(HetcdcError::PlanMismatch(
            "repair rounds need a base plan that already decodes completely".into(),
        ));
    }
    let mut out = base.clone();
    if f == 1 {
        let critical: Vec<bool> = (0..base.n_broadcasts())
            .map(|bi| {
                !super::decoder::verify(alloc, &base.without_broadcast(bi)).is_complete()
            })
            .collect();
        let mut round = ShuffleRound::default();
        let mut at = 0usize;
        for r in &base.rounds {
            for group in &r.groups {
                let mut copy =
                    MulticastGroup { members: group.members, broadcasts: Vec::new() };
                for b in &group.broadcasts {
                    if critical[at] {
                        copy.broadcasts.push(b.clone());
                    }
                    at += 1;
                }
                if !copy.broadcasts.is_empty() {
                    round.groups.push(copy);
                }
            }
        }
        // No critical broadcasts => the empty round is dropped and the
        // base already tolerates one loss for free.
        out.push_round(round);
    } else {
        for _ in 0..f {
            let mut round = ShuffleRound::default();
            for r in &base.rounds {
                round.groups.extend(r.groups.iter().cloned());
            }
            out.push_round(round);
        }
    }
    Ok(out)
}

// Re-export for doc link resolution.
#[allow(unused_imports)]
use xor as _xor_doc;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::k3::optimal_allocation;
    use crate::placement::lemma1::load_units;
    use crate::prop;
    use crate::theory::load::{lstar_half, uncoded_half};
    use crate::theory::params::Params3;

    #[test]
    fn plan_k3_load_matches_lemma1_on_paper_example() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let plan = plan_k3(&alloc);
        assert_eq!(plan.load_units() as u64, load_units(&alloc));
        assert_eq!(plan.load_equations(&alloc), 12.0);
    }

    #[test]
    fn plan_uncoded_load_matches_theory() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let plan = plan_uncoded(&alloc);
        assert_eq!(plan.load_units() as u64, alloc.uncoded_units());
        assert_eq!(
            plan.load_equations(&alloc),
            uncoded_half(&p) as f64 / 2.0
        );
        // Single-round IR: one group per partially-held subfile.
        assert_eq!(plan.round_count(), 1);
    }

    #[test]
    fn no_sender_transmits_unheld_data() {
        let p = Params3::new(5, 8, 11, 12).unwrap();
        let alloc = optimal_allocation(&p);
        for plan in [plan_k3(&alloc), plan_greedy(&alloc), plan_uncoded(&alloc)] {
            for b in plan.iter_broadcasts() {
                match b {
                    Broadcast::Uncoded { sender, iv } => {
                        assert!(alloc.holders[iv.sub] & (1 << sender) != 0);
                    }
                    Broadcast::Coded { sender, parts } => {
                        for part in parts {
                            assert!(
                                alloc.holders[part.iv.sub] & (1 << sender) != 0,
                                "sender {sender} lacks subfile {}",
                                part.iv.sub
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn every_group_member_mask_covers_its_senders() {
        let p = Params3::new(5, 8, 11, 12).unwrap();
        let alloc = optimal_allocation(&p);
        for plan in [plan_k3(&alloc), plan_greedy(&alloc), plan_uncoded(&alloc)] {
            for round in &plan.rounds {
                for group in &round.groups {
                    assert!(!group.broadcasts.is_empty(), "empty multicast group");
                    for b in &group.broadcasts {
                        assert!(
                            group.members & (1 << b.sender()) != 0,
                            "sender {} outside group members {:#b}",
                            b.sender(),
                            group.members
                        );
                    }
                }
            }
            assert!(plan.validate(3, alloc.n_sub()).is_ok());
        }
    }

    #[test]
    fn group_start_masks_mirror_the_flattened_group_structure() {
        let p = Params3::new(5, 8, 11, 12).unwrap();
        let alloc = optimal_allocation(&p);
        for plan in [plan_k3(&alloc), plan_greedy(&alloc), plan_uncoded(&alloc)] {
            let masks = plan.group_start_masks();
            assert_eq!(masks.len(), plan.n_broadcasts());
            // One Some per group, carrying that group's member mask, at
            // the group's first flat index.
            let mut want = Vec::new();
            for round in &plan.rounds {
                for group in &round.groups {
                    want.push(Some(group.members));
                    want.extend(std::iter::repeat(None).take(group.broadcasts.len() - 1));
                }
            }
            assert_eq!(masks, want);
            // Every round start is also a group start.
            for (bi, is_start) in plan.round_start_flags().iter().enumerate() {
                if *is_start {
                    assert!(masks[bi].is_some(), "round start {bi} opens no group");
                }
            }
        }
    }

    #[test]
    fn coords_name_every_flat_index_by_round_group_broadcast() {
        let p = Params3::new(5, 8, 11, 12).unwrap();
        let alloc = optimal_allocation(&p);
        for plan in [plan_k3(&alloc), plan_greedy(&alloc), plan_uncoded(&alloc)] {
            let coords = plan.coords();
            assert_eq!(coords.len(), plan.n_broadcasts());
            // Strictly increasing: the coords walk the same round-major,
            // group-major order as iter_broadcasts, with no duplicates.
            assert!(coords.windows(2).all(|w| w[0] < w[1]));
            let flat: Vec<&Broadcast> = plan.iter_broadcasts().collect();
            for (bi, &(r, g, b)) in coords.iter().enumerate() {
                assert!(r < plan.round_count());
                let group = &plan.rounds[r].groups[g];
                assert!(b < group.broadcasts.len(), "flat {bi} out of group");
                // Indexing by the coordinate recovers the flat broadcast.
                assert!(std::ptr::eq(flat[bi], &group.broadcasts[b]));
            }
            // Round boundaries agree with round_start_flags.
            for (bi, is_start) in plan.round_start_flags().iter().enumerate() {
                assert_eq!(
                    *is_start,
                    coords[bi].1 == 0 && coords[bi].2 == 0,
                    "flat {bi} round-start disagreement"
                );
            }
        }
    }

    #[test]
    fn prop_plan_k3_achieves_lstar_on_optimal_allocations() {
        prop::run("plan_k3 load == L*", 400, |g| {
            let n = g.u64_in(1..=25);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(p) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let alloc = optimal_allocation(&p);
            let plan = plan_k3(&alloc);
            prop::check(
                plan.load_units() as u64 == lstar_half(&p),
                format!("{p}: plan {} != {}", plan.load_units(), lstar_half(&p)),
            )
        });
    }

    #[test]
    fn prop_greedy_between_optimal_and_uncoded() {
        prop::run("greedy plan sane", 200, |g| {
            let n_sub = g.usize_in(1..=30);
            let k = g.usize_in(2..=5);
            let full = (1u32 << k) - 1;
            let holders: Vec<u32> = (0..n_sub)
                .map(|_| (g.u64_in(1..=full as u64)) as u32)
                .collect();
            let alloc = Allocation::new(k, 1, holders);
            let greedy = plan_greedy(&alloc);
            let unc = plan_uncoded(&alloc);
            let lower = (unc.load_units() / 2.0).ceil();
            prop::check(
                greedy.load_units() <= unc.load_units()
                    && greedy.load_units() >= lower,
                format!(
                    "k={k}: greedy {} uncoded {}",
                    greedy.load_units(),
                    unc.load_units()
                ),
            )
        });
    }

    #[test]
    fn plan_k3_never_double_consumes_a_delivery() {
        // Regression guard for the prefix-consumption bookkeeping: every
        // (dest, subfile) delivery appears in exactly one broadcast.
        let cases = [(6u64, 7, 7, 12u64), (5, 8, 11, 12), (4, 5, 6, 12), (10, 10, 10, 12)];
        for (m1, m2, m3, n) in cases {
            let p = Params3::new(m1, m2, m3, n).unwrap();
            let alloc = optimal_allocation(&p);
            let plan = plan_k3(&alloc);
            let mut seen = std::collections::HashSet::new();
            for b in plan.iter_broadcasts() {
                let ivs: Vec<IvId> = match b {
                    Broadcast::Uncoded { iv, .. } => vec![*iv],
                    Broadcast::Coded { parts, .. } => parts.iter().map(|p| p.iv).collect(),
                };
                for iv in ivs {
                    assert!(seen.insert(iv), "delivery {iv:?} scheduled twice");
                    // The destination must actually lack the subfile.
                    assert_eq!(alloc.holders[iv.sub] & (1 << iv.group), 0);
                }
            }
        }
    }

    #[test]
    fn validate_rejects_out_of_range_references() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let mut plan = plan_k3(&alloc);
        assert!(plan.validate(3, alloc.n_sub()).is_ok());
        plan.push_broadcast(0b001, Broadcast::Uncoded {
            sender: 7,
            iv: IvId { group: 0, sub: 0 },
        });
        assert!(plan.validate(3, alloc.n_sub()).is_err());
        plan.pop_broadcast();
        plan.push_broadcast(0b001, Broadcast::Uncoded {
            sender: 0,
            iv: IvId { group: 0, sub: 10_000 },
        });
        assert!(plan.validate(3, alloc.n_sub()).is_err());
        plan.pop_broadcast();
        plan.push_broadcast(0b001, Broadcast::Coded { sender: 0, parts: vec![] });
        assert!(plan.validate(3, alloc.n_sub()).is_err());
        plan.pop_broadcast();
        // A group whose members exclude its sender is malformed.
        plan.push_broadcast(0b010, Broadcast::Uncoded {
            sender: 0,
            iv: IvId { group: 1, sub: 0 },
        });
        assert!(plan.validate(3, alloc.n_sub()).is_err());
        // Out-of-range member bits too.
        plan.pop_broadcast();
        plan.push_broadcast(0b1001, Broadcast::Uncoded {
            sender: 0,
            iv: IvId { group: 1, sub: 0 },
        });
        assert!(plan.validate(3, alloc.n_sub()).is_err());
    }

    #[test]
    fn shuffle_plan_json_roundtrip() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        for plan in [plan_k3(&alloc), plan_uncoded(&alloc)] {
            let text = plan.to_json().to_string_pretty();
            let back = ShufflePlan::from_json(&crate::util::json::Json::parse(&text).unwrap())
                .unwrap();
            assert_eq!(back, plan, "round/group structure must survive serialization");
        }
        assert!(ShufflePlan::from_json(&Json::Obj(Default::default())).is_err());
    }

    #[test]
    fn legacy_flat_broadcast_json_still_parses() {
        // v1 artifacts carried a flat "broadcasts" list; the read shim
        // wraps them in a single round, one group per broadcast.
        let text = r#"{
            "k": 3,
            "broadcasts": [
                {"type": "uncoded", "sender": 0, "group": 1, "sub": 4},
                {"type": "coded", "sender": 1, "parts": [
                    {"group": 2, "sub": 6, "seg": 0, "nseg": 1},
                    {"group": 0, "sub": 9, "seg": 0, "nseg": 1}
                ]}
            ]
        }"#;
        let plan = ShufflePlan::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(plan.k, 3);
        assert_eq!(plan.round_count(), 1);
        assert_eq!(plan.n_broadcasts(), 2);
        let flat: Vec<&Broadcast> = plan.iter_broadcasts().collect();
        assert!(matches!(flat[0], Broadcast::Uncoded { sender: 0, .. }));
        assert!(matches!(flat[1], Broadcast::Coded { sender: 1, .. }));
        // Each legacy broadcast becomes its own sender-only group.
        assert_eq!(plan.rounds[0].groups.len(), 2);
        assert_eq!(plan.rounds[0].groups[0].members, 0b001);
        assert_eq!(plan.rounds[0].groups[1].members, 0b010);
    }

    #[test]
    fn validate_rejects_empty_rounds_and_groups() {
        // Deserialized v2 artifacts can carry zero-broadcast rounds or
        // groups that no builder produces; they would desync the
        // per-round ledger sections from round_count, so validation
        // rejects them.
        let empty_round = r#"{"k": 3, "rounds": [
            {"groups": []},
            {"groups": [{"members": 3, "broadcasts": [
                {"type": "uncoded", "sender": 0, "group": 1, "sub": 0}
            ]}]}
        ]}"#;
        let plan = ShufflePlan::from_json(&Json::parse(empty_round).unwrap()).unwrap();
        assert!(plan.validate(3, 4).is_err());

        let empty_group = r#"{"k": 3, "rounds": [
            {"groups": [{"members": 1, "broadcasts": []}]}
        ]}"#;
        let plan = ShufflePlan::from_json(&Json::parse(empty_group).unwrap()).unwrap();
        assert!(plan.validate(3, 4).is_err());
    }

    #[test]
    fn round_starts_and_sizes_tile_the_flat_order() {
        let p = Params3::new(5, 8, 11, 12).unwrap();
        let alloc = optimal_allocation(&p);
        for plan in [plan_k3(&alloc), plan_greedy(&alloc), plan_uncoded(&alloc)] {
            let starts = plan.round_starts();
            let sizes = plan.round_sizes();
            assert_eq!(starts.len(), plan.round_count());
            assert_eq!(sizes.len(), plan.round_count());
            let mut at = 0usize;
            for (s, z) in starts.iter().zip(&sizes) {
                assert_eq!(*s, at);
                assert!(*z > 0, "empty rounds must have been dropped");
                at += z;
            }
            assert_eq!(at, plan.n_broadcasts());
        }
    }

    #[test]
    fn push_pop_broadcast_roundtrips() {
        let mut plan = ShufflePlan::new(3);
        assert!(plan.pop_broadcast().is_none());
        let b = Broadcast::Uncoded { sender: 1, iv: IvId { group: 0, sub: 2 } };
        plan.push_broadcast(0b011, b.clone());
        assert_eq!(plan.n_broadcasts(), 1);
        assert_eq!(plan.pop_broadcast(), Some(b));
        assert_eq!(plan.n_broadcasts(), 0);
        assert_eq!(plan.round_count(), 0, "emptied rounds are pruned");
    }

    #[test]
    fn without_broadcast_removes_one_flat_index_and_prunes() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let plan = plan_k3(&alloc);
        let flat: Vec<Broadcast> = plan.iter_broadcasts().cloned().collect();
        for bi in 0..plan.n_broadcasts() {
            let pruned = plan.without_broadcast(bi);
            assert_eq!(pruned.n_broadcasts(), plan.n_broadcasts() - 1);
            let mut want = flat.clone();
            want.remove(bi);
            let got: Vec<Broadcast> = pruned.iter_broadcasts().cloned().collect();
            assert_eq!(got, want, "removal at {bi} shifted the wrong index");
            assert!(pruned.validate(3, alloc.n_sub()).is_ok());
        }
        // Out-of-range = unmodified clone.
        assert_eq!(plan.without_broadcast(plan.n_broadcasts()), plan);
        // Pruning: a plan of one single-broadcast group loses the round.
        let mut tiny = ShufflePlan::new(3);
        tiny.push_broadcast(
            0b001,
            Broadcast::Uncoded { sender: 0, iv: IvId { group: 1, sub: 0 } },
        );
        assert_eq!(tiny.without_broadcast(0).round_count(), 0);
    }

    #[test]
    fn repair_rounds_duplicate_critical_broadcasts_at_f1() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let base = plan_uncoded(&alloc);
        // Every uncoded delivery is critical: dropping any one loses an IV.
        let repaired = with_repair_rounds(&base, &alloc, 1).unwrap();
        assert_eq!(repaired.round_count(), base.round_count() + 1);
        assert_eq!(repaired.n_broadcasts(), 2 * base.n_broadcasts());
        assert!(repaired.validate(3, alloc.n_sub()).is_ok());
        // The repair round mirrors the original group member masks.
        let orig: Vec<NodeMask> =
            base.rounds[0].groups.iter().map(|g| g.members).collect();
        let rep: Vec<NodeMask> = repaired.rounds.last().unwrap().groups.iter()
            .map(|g| g.members)
            .collect();
        assert_eq!(rep, orig);
        // f=0 is the identity; f on an incomplete base is a typed error.
        assert_eq!(with_repair_rounds(&base, &alloc, 0).unwrap(), base);
        let mut broken = base.clone();
        broken.pop_broadcast();
        assert!(matches!(
            with_repair_rounds(&broken, &alloc, 1),
            Err(HetcdcError::PlanMismatch(_))
        ));
    }

    #[test]
    fn repair_rounds_full_copy_at_f2() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let base = plan_k3(&alloc);
        let repaired = with_repair_rounds(&base, &alloc, 2).unwrap();
        assert_eq!(repaired.round_count(), base.round_count() + 2);
        assert_eq!(repaired.n_broadcasts(), 3 * base.n_broadcasts());
        assert!(repaired.validate(3, alloc.n_sub()).is_ok());
        // The two appended rounds are byte-for-byte copies of the base's
        // flattened broadcast order.
        let flat: Vec<Broadcast> = base.iter_broadcasts().cloned().collect();
        for round in &repaired.rounds[base.round_count()..] {
            let copy: Vec<Broadcast> = round
                .groups
                .iter()
                .flat_map(|g| g.broadcasts.iter().cloned())
                .collect();
            assert_eq!(copy, flat);
        }
    }

    #[test]
    fn uncoded_plan_covers_every_delivery_exactly_once() {
        let p = Params3::new(5, 8, 11, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let plan = plan_uncoded(&alloc);
        let mut need = std::collections::HashSet::new();
        for (sub, &h) in alloc.holders.iter().enumerate() {
            for dest in 0..3 {
                if h & (1 << dest) == 0 {
                    need.insert(IvId { group: dest, sub });
                }
            }
        }
        let mut seen = std::collections::HashSet::new();
        for b in plan.iter_broadcasts() {
            if let Broadcast::Uncoded { iv, .. } = b {
                assert!(seen.insert(*iv));
            }
        }
        assert_eq!(need, seen);
    }
}
