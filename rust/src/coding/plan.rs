//! Shuffle plans: the concrete broadcast schedule of the Shuffle phase.
//!
//! A [`ShufflePlan`] lists, per broadcast, the sender and the XOR of IV
//! *parts* it carries (a part is a `seg/nseg` fraction of one IV payload;
//! `nseg = 1` for whole-IV XOR pairs, `nseg = r` for the homogeneous
//! multicast of [2]). Plans are independent of payload bytes — the engine
//! executes them against real IVs, and [`crate::coding::decoder`] verifies
//! them symbolically.
//!
//! With `Q = K`, intermediate value `(g, f)` is "the IV of node `g`'s
//! reduce-function group on subfile `f`"; node `g` needs it iff it does
//! not hold `f`.

use super::xor; // used by doc references; keep module coupling explicit
use crate::error::{HetcdcError, Result};
use crate::placement::alloc::Allocation;
use crate::placement::lemma1::{pairing_counts, PAIR_MASKS};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Identifies one intermediate value: reduce group `group` (== destination
/// node under Q=K) on subfile `sub`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct IvId {
    pub group: usize,
    pub sub: usize,
}

/// One summand of a coded broadcast: segment `seg` of `nseg` equal splits
/// of IV `iv`'s payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Part {
    pub iv: IvId,
    pub seg: u32,
    pub nseg: u32,
}

impl Part {
    pub fn whole(iv: IvId) -> Self {
        Part { iv, seg: 0, nseg: 1 }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Broadcast {
    /// Plain IV broadcast (destination(s) implied by who lacks `iv.sub`).
    Uncoded { sender: usize, iv: IvId },
    /// XOR of `parts` (all the same `nseg`).
    Coded { sender: usize, parts: Vec<Part> },
}

impl Broadcast {
    /// Transmission size in IV units: 1 for uncoded/whole XOR, 1/nseg for
    /// segment XOR. Returned as (num, den).
    pub fn units(&self) -> (u64, u64) {
        match self {
            Broadcast::Uncoded { .. } => (1, 1),
            Broadcast::Coded { parts, .. } => {
                let nseg = parts.first().map(|p| p.nseg).unwrap_or(1);
                debug_assert!(parts.iter().all(|p| p.nseg == nseg));
                (1, nseg as u64)
            }
        }
    }

    pub fn sender(&self) -> usize {
        match self {
            Broadcast::Uncoded { sender, .. } | Broadcast::Coded { sender, .. } => *sender,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct ShufflePlan {
    pub k: usize,
    pub broadcasts: Vec<Broadcast>,
}

impl ShufflePlan {
    /// Total load in subfile units (exact rational; integral when all
    /// broadcasts are whole-IV).
    pub fn load_units(&self) -> f64 {
        let mut num = 0u64;
        let mut frac = 0.0f64;
        for b in &self.broadcasts {
            let (n, d) = b.units();
            if d == 1 {
                num += n;
            } else {
                frac += n as f64 / d as f64;
            }
        }
        num as f64 + frac
    }

    /// Load in IV-equation units, given the allocation's subpacketization.
    pub fn load_equations(&self, alloc: &Allocation) -> f64 {
        self.load_units() / alloc.sp as f64
    }

    /// Coding ratio: fraction of broadcast units that are coded.
    pub fn coded_fraction(&self) -> f64 {
        if self.broadcasts.is_empty() {
            return 0.0;
        }
        let coded = self
            .broadcasts
            .iter()
            .filter(|b| matches!(b, Broadcast::Coded { .. }))
            .count();
        coded as f64 / self.broadcasts.len() as f64
    }

    /// Structural bounds check against a K-node, `n_sub`-subfile job:
    /// senders/groups within `[0, K)`, subfiles within `[0, n_sub)`,
    /// segment indices within a sane `nseg`, and uniform `nseg` per
    /// broadcast. Deserialized plans go through this before the symbolic
    /// decoder touches them, so hostile artifacts fail typed instead of
    /// panicking an executor.
    pub fn validate(&self, k: usize, n_sub: usize) -> Result<()> {
        let bad = |i: usize, m: String| {
            HetcdcError::PlanMismatch(format!("broadcast {i}: {m}"))
        };
        let check_iv = |i: usize, iv: &IvId| -> Result<()> {
            if iv.group >= k {
                return Err(bad(i, format!("group {} out of range [0, {k})", iv.group)));
            }
            if iv.sub >= n_sub {
                return Err(bad(i, format!("subfile {} out of range [0, {n_sub})", iv.sub)));
            }
            Ok(())
        };
        if self.k != k {
            return Err(HetcdcError::PlanMismatch(format!(
                "shuffle plan is for K={}, expected K={k}",
                self.k
            )));
        }
        for (i, b) in self.broadcasts.iter().enumerate() {
            if b.sender() >= k {
                return Err(bad(i, format!("sender {} out of range [0, {k})", b.sender())));
            }
            match b {
                Broadcast::Uncoded { iv, .. } => check_iv(i, iv)?,
                Broadcast::Coded { parts, .. } => {
                    let nseg = match parts.first() {
                        Some(p) => p.nseg,
                        None => return Err(bad(i, "coded broadcast with no parts".into())),
                    };
                    if nseg == 0 || nseg > 64 {
                        return Err(bad(i, format!("nseg {nseg} out of range [1, 64]")));
                    }
                    for p in parts {
                        if p.nseg != nseg {
                            return Err(bad(i, "mixed nseg within one broadcast".into()));
                        }
                        if p.seg >= nseg {
                            return Err(bad(i, format!("segment {} >= nseg {nseg}", p.seg)));
                        }
                        check_iv(i, &p.iv)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// JSON form used inside serialized [`crate::engine::Plan`] artifacts
    /// (schema in DESIGN.md).
    pub fn to_json(&self) -> Json {
        let broadcasts: Vec<Json> = self
            .broadcasts
            .iter()
            .map(|b| {
                let mut m = BTreeMap::new();
                match b {
                    Broadcast::Uncoded { sender, iv } => {
                        m.insert("type".into(), Json::Str("uncoded".into()));
                        m.insert("sender".into(), Json::Num(*sender as f64));
                        m.insert("group".into(), Json::Num(iv.group as f64));
                        m.insert("sub".into(), Json::Num(iv.sub as f64));
                    }
                    Broadcast::Coded { sender, parts } => {
                        m.insert("type".into(), Json::Str("coded".into()));
                        m.insert("sender".into(), Json::Num(*sender as f64));
                        let parts: Vec<Json> = parts
                            .iter()
                            .map(|p| {
                                let mut pm = BTreeMap::new();
                                pm.insert("group".into(), Json::Num(p.iv.group as f64));
                                pm.insert("sub".into(), Json::Num(p.iv.sub as f64));
                                pm.insert("seg".into(), Json::Num(p.seg as f64));
                                pm.insert("nseg".into(), Json::Num(p.nseg as f64));
                                Json::Obj(pm)
                            })
                            .collect();
                        m.insert("parts".into(), Json::Arr(parts));
                    }
                }
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("k".into(), Json::Num(self.k as f64));
        m.insert("broadcasts".into(), Json::Arr(broadcasts));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let bad = |f: &str| HetcdcError::Json(format!("shuffle plan: missing or invalid '{f}'"));
        let k = j.get("k").and_then(|v| v.as_usize()).ok_or_else(|| bad("k"))?;
        let get_usize = |o: &Json, f: &'static str| -> Result<usize> {
            o.get(f).and_then(|v| v.as_usize()).ok_or_else(|| bad(f))
        };
        let mut broadcasts = Vec::new();
        for b in j
            .get("broadcasts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| bad("broadcasts"))?
        {
            let sender = get_usize(b, "sender")?;
            match b.get("type").and_then(|v| v.as_str()) {
                Some("uncoded") => broadcasts.push(Broadcast::Uncoded {
                    sender,
                    iv: IvId {
                        group: get_usize(b, "group")?,
                        sub: get_usize(b, "sub")?,
                    },
                }),
                Some("coded") => {
                    let mut parts = Vec::new();
                    for p in b
                        .get("parts")
                        .and_then(|v| v.as_arr())
                        .ok_or_else(|| bad("parts"))?
                    {
                        let nseg = get_usize(p, "nseg")? as u32;
                        if nseg == 0 {
                            return Err(bad("nseg"));
                        }
                        parts.push(Part {
                            iv: IvId {
                                group: get_usize(p, "group")?,
                                sub: get_usize(p, "sub")?,
                            },
                            seg: get_usize(p, "seg")? as u32,
                            nseg,
                        });
                    }
                    if parts.is_empty() {
                        return Err(bad("parts"));
                    }
                    broadcasts.push(Broadcast::Coded { sender, parts });
                }
                _ => return Err(bad("type")),
            }
        }
        Ok(ShufflePlan { k, broadcasts })
    }
}

/// Exact Lemma-1 plan for K=3 allocations (achieves `L_M` of eq. (3)).
///
/// Node k XOR-pairs the two pair-sets it holds (the evidently-intended
/// reading of eqs. (8)–(10); see DESIGN.md §9): with pair-sets
/// `S12, S13, S23` and optimal counts `(alpha, beta, gamma)` from
/// [`pairing_counts`], node 0 sends `alpha` XORs over `S12 × S13`, node 1
/// `beta` over `S12 × S23`, node 2 `gamma` over `S13 × S23`; leftovers and
/// single-held subfiles go uncoded.
pub fn plan_k3(alloc: &Allocation) -> ShufflePlan {
    assert_eq!(alloc.k, 3, "plan_k3 requires K=3");
    let mut plan = ShufflePlan {
        k: 3,
        broadcasts: Vec::new(),
    };

    // Singles: holder broadcasts both other groups' IVs.
    for (mask, holder) in [(0b001u32, 0usize), (0b010, 1), (0b100, 2)] {
        for sub in alloc.subfiles_with_mask(mask) {
            for dest in 0..3 {
                if dest != holder {
                    plan.broadcasts.push(Broadcast::Uncoded {
                        sender: holder,
                        iv: IvId { group: dest, sub },
                    });
                }
            }
        }
    }

    // Pair sets: S12 (mask 011, missing node 2), S13 (101, missing 1),
    // S23 (110, missing 0).
    let s12 = alloc.subfiles_with_mask(PAIR_MASKS[0]);
    let s13 = alloc.subfiles_with_mask(PAIR_MASKS[1]);
    let s23 = alloc.subfiles_with_mask(PAIR_MASKS[2]);
    let (alpha, beta, gamma) =
        pairing_counts(s12.len() as u64, s13.len() as u64, s23.len() as u64);
    let (alpha, beta, gamma) = (alpha as usize, beta as usize, gamma as usize);

    let missing = |pair_idx: usize| -> usize {
        match pair_idx {
            0 => 2, // S12 -> node 2 lacks it
            1 => 1, // S13 -> node 1
            2 => 0, // S23 -> node 0
            _ => unreachable!(),
        }
    };

    // alpha XORs at node 0 over (S12, S13); consume prefixes.
    for i in 0..alpha {
        plan.broadcasts.push(Broadcast::Coded {
            sender: 0,
            parts: vec![
                Part::whole(IvId { group: missing(0), sub: s12[i] }),
                Part::whole(IvId { group: missing(1), sub: s13[i] }),
            ],
        });
    }
    // beta XORs at node 1 over (S12, S23).
    for i in 0..beta {
        plan.broadcasts.push(Broadcast::Coded {
            sender: 1,
            parts: vec![
                Part::whole(IvId { group: missing(0), sub: s12[alpha + i] }),
                Part::whole(IvId { group: missing(2), sub: s23[i] }),
            ],
        });
    }
    // gamma XORs at node 2 over (S13, S23).
    for i in 0..gamma {
        plan.broadcasts.push(Broadcast::Coded {
            sender: 2,
            parts: vec![
                Part::whole(IvId { group: missing(1), sub: s13[alpha + i] }),
                Part::whole(IvId { group: missing(2), sub: s23[beta + i] }),
            ],
        });
    }
    // Leftover pair subfiles go uncoded from their lowest holder.
    for (list, consumed, pair_idx, sender) in [
        (&s12, alpha + beta, 0usize, 0usize),
        (&s13, alpha + gamma, 1, 0),
        (&s23, beta + gamma, 2, 1),
    ] {
        for &sub in &list[consumed..] {
            plan.broadcasts.push(Broadcast::Uncoded {
                sender,
                iv: IvId { group: missing(pair_idx), sub },
            });
        }
    }
    plan
}

/// Greedy pairing coder for arbitrary K: pairs deliveries `(d1, f1)` and
/// `(d2, f2)` into one XOR when a common sender holds both subfiles and
/// each destination holds the *other* subfile (so it can cancel). A valid
/// achievable scheme for any allocation; exactly optimal pair-coding for
/// K=3 is provided by [`plan_k3`] instead.
pub fn plan_greedy(alloc: &Allocation) -> ShufflePlan {
    let k = alloc.k;
    let full = alloc.full_mask();
    // Deliveries: (dest, sub) for every node lacking the subfile.
    let mut deliveries: Vec<(usize, usize)> = Vec::new();
    for (sub, &h) in alloc.holders.iter().enumerate() {
        if h == full {
            continue;
        }
        for dest in 0..k {
            if h & (1 << dest) == 0 {
                deliveries.push((dest, sub));
            }
        }
    }

    let mut used = vec![false; deliveries.len()];
    let mut plan = ShufflePlan {
        k,
        broadcasts: Vec::new(),
    };

    // Bucket deliveries by destination for faster partner search.
    let mut by_dest: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (i, &(d, _)) in deliveries.iter().enumerate() {
        by_dest[d].push(i);
    }

    for i in 0..deliveries.len() {
        if used[i] {
            continue;
        }
        let (d1, f1) = deliveries[i];
        let h1 = alloc.holders[f1];
        let mut matched = false;
        // Partner must be destined to a node that holds f1.
        'outer: for d2 in 0..k {
            if d2 == d1 || h1 & (1 << d2) == 0 {
                continue;
            }
            for &j in &by_dest[d2] {
                if used[j] || j == i {
                    continue;
                }
                let (_, f2) = deliveries[j];
                let h2 = alloc.holders[f2];
                // d1 must hold f2; a sender must hold both (not d1/d2).
                if h2 & (1 << d1) == 0 {
                    continue;
                }
                let senders = h1 & h2 & !(1 << d1) & !(1 << d2);
                if senders == 0 {
                    continue;
                }
                let sender = senders.trailing_zeros() as usize;
                used[i] = true;
                used[j] = true;
                plan.broadcasts.push(Broadcast::Coded {
                    sender,
                    parts: vec![
                        Part::whole(IvId { group: d1, sub: f1 }),
                        Part::whole(IvId { group: d2, sub: f2 }),
                    ],
                });
                matched = true;
                break 'outer;
            }
        }
        if !matched {
            used[i] = true;
            let sender = alloc.holders[f1].trailing_zeros() as usize;
            plan.broadcasts.push(Broadcast::Uncoded {
                sender,
                iv: IvId { group: d1, sub: f1 },
            });
        }
    }
    plan
}

/// Fully-uncoded baseline plan: every delivery as a plain broadcast.
pub fn plan_uncoded(alloc: &Allocation) -> ShufflePlan {
    let k = alloc.k;
    let full = alloc.full_mask();
    let mut plan = ShufflePlan {
        k,
        broadcasts: Vec::new(),
    };
    for (sub, &h) in alloc.holders.iter().enumerate() {
        if h == full {
            continue;
        }
        let sender = h.trailing_zeros() as usize;
        for dest in 0..k {
            if h & (1 << dest) == 0 {
                plan.broadcasts.push(Broadcast::Uncoded {
                    sender,
                    iv: IvId { group: dest, sub },
                });
            }
        }
    }
    plan
}

// Re-export for doc link resolution.
#[allow(unused_imports)]
use xor as _xor_doc;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::k3::optimal_allocation;
    use crate::placement::lemma1::load_units;
    use crate::prop;
    use crate::theory::load::{lstar_half, uncoded_half};
    use crate::theory::params::Params3;

    #[test]
    fn plan_k3_load_matches_lemma1_on_paper_example() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let plan = plan_k3(&alloc);
        assert_eq!(plan.load_units() as u64, load_units(&alloc));
        assert_eq!(plan.load_equations(&alloc), 12.0);
    }

    #[test]
    fn plan_uncoded_load_matches_theory() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let plan = plan_uncoded(&alloc);
        assert_eq!(plan.load_units() as u64, alloc.uncoded_units());
        assert_eq!(
            plan.load_equations(&alloc),
            uncoded_half(&p) as f64 / 2.0
        );
    }

    #[test]
    fn no_sender_transmits_unheld_data() {
        let p = Params3::new(5, 8, 11, 12).unwrap();
        let alloc = optimal_allocation(&p);
        for plan in [plan_k3(&alloc), plan_greedy(&alloc), plan_uncoded(&alloc)] {
            for b in &plan.broadcasts {
                match b {
                    Broadcast::Uncoded { sender, iv } => {
                        assert!(alloc.holders[iv.sub] & (1 << sender) != 0);
                    }
                    Broadcast::Coded { sender, parts } => {
                        for part in parts {
                            assert!(
                                alloc.holders[part.iv.sub] & (1 << sender) != 0,
                                "sender {sender} lacks subfile {}",
                                part.iv.sub
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn prop_plan_k3_achieves_lstar_on_optimal_allocations() {
        prop::run("plan_k3 load == L*", 400, |g| {
            let n = g.u64_in(1..=25);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(p) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let alloc = optimal_allocation(&p);
            let plan = plan_k3(&alloc);
            prop::check(
                plan.load_units() as u64 == lstar_half(&p),
                format!("{p}: plan {} != {}", plan.load_units(), lstar_half(&p)),
            )
        });
    }

    #[test]
    fn prop_greedy_between_optimal_and_uncoded() {
        prop::run("greedy plan sane", 200, |g| {
            let n_sub = g.usize_in(1..=30);
            let k = g.usize_in(2..=5);
            let full = (1u32 << k) - 1;
            let holders: Vec<u32> = (0..n_sub)
                .map(|_| (g.u64_in(1..=full as u64)) as u32)
                .collect();
            let alloc = Allocation::new(k, 1, holders);
            let greedy = plan_greedy(&alloc);
            let unc = plan_uncoded(&alloc);
            let lower = (unc.load_units() / 2.0).ceil();
            prop::check(
                greedy.load_units() <= unc.load_units()
                    && greedy.load_units() >= lower,
                format!(
                    "k={k}: greedy {} uncoded {}",
                    greedy.load_units(),
                    unc.load_units()
                ),
            )
        });
    }

    #[test]
    fn plan_k3_never_double_consumes_a_delivery() {
        // Regression guard for the prefix-consumption bookkeeping: every
        // (dest, subfile) delivery appears in exactly one broadcast.
        for (m1, m2, m3, n) in [(6u64, 7, 7, 12u64), (5, 8, 11, 12), (4, 5, 6, 12), (10, 10, 10, 12)] {
            let p = Params3::new(m1, m2, m3, n).unwrap();
            let alloc = optimal_allocation(&p);
            let plan = plan_k3(&alloc);
            let mut seen = std::collections::HashSet::new();
            for b in &plan.broadcasts {
                let ivs: Vec<IvId> = match b {
                    Broadcast::Uncoded { iv, .. } => vec![*iv],
                    Broadcast::Coded { parts, .. } => parts.iter().map(|p| p.iv).collect(),
                };
                for iv in ivs {
                    assert!(seen.insert(iv), "delivery {iv:?} scheduled twice");
                    // The destination must actually lack the subfile.
                    assert_eq!(alloc.holders[iv.sub] & (1 << iv.group), 0);
                }
            }
        }
    }

    #[test]
    fn validate_rejects_out_of_range_references() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let mut plan = plan_k3(&alloc);
        assert!(plan.validate(3, alloc.n_sub()).is_ok());
        plan.broadcasts.push(Broadcast::Uncoded {
            sender: 7,
            iv: IvId { group: 0, sub: 0 },
        });
        assert!(plan.validate(3, alloc.n_sub()).is_err());
        plan.broadcasts.pop();
        plan.broadcasts.push(Broadcast::Uncoded {
            sender: 0,
            iv: IvId { group: 0, sub: 10_000 },
        });
        assert!(plan.validate(3, alloc.n_sub()).is_err());
        plan.broadcasts.pop();
        plan.broadcasts.push(Broadcast::Coded { sender: 0, parts: vec![] });
        assert!(plan.validate(3, alloc.n_sub()).is_err());
    }

    #[test]
    fn shuffle_plan_json_roundtrip() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        for plan in [plan_k3(&alloc), plan_uncoded(&alloc)] {
            let text = plan.to_json().to_string_pretty();
            let back = ShufflePlan::from_json(&crate::util::json::Json::parse(&text).unwrap())
                .unwrap();
            assert_eq!(back.k, plan.k);
            assert_eq!(back.broadcasts, plan.broadcasts);
        }
        assert!(ShufflePlan::from_json(&Json::Obj(Default::default())).is_err());
    }

    #[test]
    fn uncoded_plan_covers_every_delivery_exactly_once() {
        let p = Params3::new(5, 8, 11, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let plan = plan_uncoded(&alloc);
        let mut need = std::collections::HashSet::new();
        for (sub, &h) in alloc.holders.iter().enumerate() {
            for dest in 0..3 {
                if h & (1 << dest) == 0 {
                    need.insert(IvId { group: dest, sub });
                }
            }
        }
        let mut seen = std::collections::HashSet::new();
        for b in &plan.broadcasts {
            if let Broadcast::Uncoded { iv, .. } = b {
                assert!(seen.insert(*iv));
            }
        }
        assert_eq!(need, seen);
    }
}
