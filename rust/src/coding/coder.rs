//! The [`ShuffleCoder`] trait: pluggable coded-shuffle constructions
//! behind one interface.
//!
//! A coder turns an [`Allocation`] into a [`ShufflePlan`] — the concrete
//! broadcast schedule. Like placers, coders are pure functions of cluster
//! and job *shape*; their output is verified by the symbolic decoder at
//! plan-build time, so execution never re-checks decodability.

use super::cdc_multicast;
use super::combinatorial;
use super::plan::{plan_greedy, plan_k3, plan_uncoded, ShufflePlan};
use crate::error::{HetcdcError, Result};
use crate::model::cluster::ClusterSpec;
use crate::model::job::JobSpec;
use crate::placement::alloc::Allocation;
use crate::placement::memshare;

/// A coded-shuffle construction.
pub trait ShuffleCoder {
    /// Registry name (stable; appears in reports and serialized plans).
    fn name(&self) -> &'static str;

    /// Build the broadcast schedule delivering every missing IV.
    fn plan(
        &self,
        cluster: &ClusterSpec,
        job: &JobSpec,
        alloc: &Allocation,
    ) -> Result<ShufflePlan>;

    /// Like [`ShuffleCoder::plan`], but allowed to shard construction
    /// across up to `threads` worker threads. The emitted plan must be
    /// **identical** for every thread count (plans are serialized and
    /// diffed byte-for-byte across `--threads` values). The default
    /// ignores the budget; coders with parallel constructions (the
    /// combinatorial grid) override it.
    fn plan_threaded(
        &self,
        cluster: &ClusterSpec,
        job: &JobSpec,
        alloc: &Allocation,
        _threads: usize,
    ) -> Result<ShufflePlan> {
        self.plan(cluster, job, alloc)
    }
}

/// Fully-uncoded baseline: every delivery as a plain broadcast.
#[derive(Clone, Copy, Debug, Default)]
pub struct Uncoded;

impl ShuffleCoder for Uncoded {
    fn name(&self) -> &'static str {
        "uncoded"
    }

    fn plan(&self, _c: &ClusterSpec, _j: &JobSpec, alloc: &Allocation) -> Result<ShufflePlan> {
        Ok(plan_uncoded(alloc))
    }
}

/// XOR pair-coding: the exact Lemma-1 plan for K=3, greedy pairing for
/// any other K. Works on arbitrary allocations.
#[derive(Clone, Copy, Debug, Default)]
pub struct Pairing;

impl ShuffleCoder for Pairing {
    fn name(&self) -> &'static str {
        "pairing"
    }

    fn plan(&self, _c: &ClusterSpec, _j: &JobSpec, alloc: &Allocation) -> Result<ShufflePlan> {
        if alloc.k == 3 {
            Ok(plan_k3(alloc))
        } else {
            Ok(plan_greedy(alloc))
        }
    }
}

/// Greedy pairing for any K (kept addressable on its own so K=3 plans can
/// be compared against the exact Lemma-1 coder).
#[derive(Clone, Copy, Debug, Default)]
pub struct Greedy;

impl ShuffleCoder for Greedy {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn plan(&self, _c: &ClusterSpec, _j: &JobSpec, alloc: &Allocation) -> Result<ShufflePlan> {
        Ok(plan_greedy(alloc))
    }
}

/// True when every size-`r` holder subset stores the same number of
/// subfiles — the symmetry [2]'s multicast (and its `debug_assert`)
/// requires. Subfiles whose holder-set size differs from `r` are ignored.
fn symmetric_at_r(alloc: &Allocation, r: usize) -> bool {
    let sizes = alloc.subset_sizes();
    let mut expected: Option<u64> = None;
    for mask in 1u32..(1u32 << alloc.k) {
        if mask.count_ones() as usize != r {
            continue;
        }
        let c = sizes[mask as usize];
        match expected {
            None => expected = Some(c),
            Some(e) if e == c => {}
            Some(_) => return false,
        }
    }
    true
}

/// The homogeneous (r+1)-group multicast of [2]. Requires a symmetric
/// r-regular allocation (every subfile held by exactly `r` nodes, every
/// r-subset holding equally many).
#[derive(Clone, Copy, Debug, Default)]
pub struct Multicast;

impl ShuffleCoder for Multicast {
    fn name(&self) -> &'static str {
        "multicast"
    }

    fn plan(&self, _c: &ClusterSpec, _j: &JobSpec, alloc: &Allocation) -> Result<ShufflePlan> {
        let r = alloc
            .holders
            .first()
            .map(|h| h.count_ones() as usize)
            .ok_or_else(|| HetcdcError::InvalidPlacement("allocation has no subfiles".into()))?;
        if r == 0 || r > alloc.k {
            return Err(HetcdcError::InvalidPlacement(format!(
                "redundancy {r} out of range [1, K={}]",
                alloc.k
            )));
        }
        if !alloc.holders.iter().all(|h| h.count_ones() as usize == r) {
            return Err(HetcdcError::Unsupported {
                strategy: "multicast coder",
                reason: "allocation is not r-regular".into(),
            });
        }
        if !symmetric_at_r(alloc, r) {
            return Err(HetcdcError::Unsupported {
                strategy: "multicast coder",
                reason: "allocation is not symmetric across r-subsets".into(),
            });
        }
        Ok(cdc_multicast::plan_homogeneous(alloc, r))
    }
}

/// Memory-sharing coder for the storage-oblivious baseline: the two
/// r-regular sub-instances each run [2]'s multicast. Falls back to pair
/// coding when the min-storage split does not apply to this allocation.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemShare;

impl ShuffleCoder for MemShare {
    fn name(&self) -> &'static str {
        "memshare"
    }

    fn plan(
        &self,
        cluster: &ClusterSpec,
        job: &JobSpec,
        alloc: &Allocation,
    ) -> Result<ShufflePlan> {
        let m_min = *cluster.storage().iter().min().ok_or_else(|| {
            HetcdcError::InvalidParams("cluster has no nodes".into())
        })?;
        let fallback = |alloc: &Allocation| {
            if alloc.k == 3 {
                plan_k3(alloc)
            } else {
                plan_greedy(alloc)
            }
        };
        let share = match memshare::split(alloc.k, m_min, job.n_files) {
            Ok(share) => share,
            Err(_) => return Ok(fallback(alloc)),
        };
        // The two-regime multicast only serves allocations shaped like the
        // memory-sharing design: every subfile at redundancy r_lo, r_hi,
        // or K (fully replicated needs no shuffle), each regime symmetric.
        // Anything else gets the always-valid pairing coder instead of a
        // silently incomplete plan.
        let shaped = alloc.holders.iter().all(|h| {
            let r = h.count_ones() as u64;
            r == share.r_lo || r == share.r_hi || r == alloc.k as u64
        }) && symmetric_at_r(alloc, share.r_lo as usize)
            && symmetric_at_r(alloc, share.r_hi as usize);
        if !shaped {
            return Ok(fallback(alloc));
        }
        Ok(share.plan(alloc))
    }
}

/// The combinatorial grid-transversal multicast
/// ([`crate::coding::combinatorial`]): multi-round, multi-group schedules
/// with coding gain `r − 1` built in closed form from the grid structure —
/// no perfect-collection enumeration, no cap, any K. Requires a grid
/// allocation (the [`crate::placement::combinatorial`] placer's output, or
/// anything [`combinatorial::detect_grid`] recognizes).
#[derive(Clone, Copy, Debug, Default)]
pub struct Combinatorial;

impl ShuffleCoder for Combinatorial {
    fn name(&self) -> &'static str {
        "combinatorial"
    }

    fn plan(&self, _c: &ClusterSpec, _j: &JobSpec, alloc: &Allocation) -> Result<ShufflePlan> {
        let grid = combinatorial::detect_grid(alloc)?;
        Ok(combinatorial::plan_grid(alloc, &grid))
    }

    /// Grid construction is embarrassingly parallel: groups and rounds
    /// are pure functions of their lattice/round index, so the sharded
    /// build emits the identical plan at any thread count.
    fn plan_threaded(
        &self,
        _c: &ClusterSpec,
        _j: &JobSpec,
        alloc: &Allocation,
        threads: usize,
    ) -> Result<ShufflePlan> {
        let grid = combinatorial::detect_grid(alloc)?;
        Ok(combinatorial::plan_grid_threaded(alloc, &grid, threads))
    }
}

/// Resolve a registry name to a coder.
pub fn coder_by_name(name: &str) -> Result<Box<dyn ShuffleCoder>> {
    match name {
        "uncoded" => Ok(Box::new(Uncoded)),
        "pairing" => Ok(Box::new(Pairing)),
        "greedy" => Ok(Box::new(Greedy)),
        "multicast" => Ok(Box::new(Multicast)),
        "memshare" => Ok(Box::new(MemShare)),
        "combinatorial" => Ok(Box::new(Combinatorial)),
        other => Err(HetcdcError::UnknownStrategy {
            kind: "coder",
            name: other.to_string(),
        }),
    }
}

/// All built-in coded (non-baseline) coders, for sweeps and property
/// tests. `uncoded` is excluded: it is the baseline every coder must beat.
pub fn builtin_coders() -> Vec<Box<dyn ShuffleCoder>> {
    vec![
        Box::new(Pairing),
        Box::new(Greedy),
        Box::new(Multicast),
        Box::new(MemShare),
        Box::new(Combinatorial),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::decoder;
    use crate::placement::k3::optimal_allocation;
    use crate::theory::params::Params3;

    fn cluster(storage: &[u64]) -> ClusterSpec {
        let mut c = ClusterSpec::homogeneous(storage.len(), 1, 1000.0);
        for (node, &m) in c.nodes.iter_mut().zip(storage) {
            node.storage = m;
        }
        c
    }

    #[test]
    fn pairing_matches_plan_k3_on_k3() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let c = cluster(&[6, 7, 7]);
        let job = JobSpec::terasort(12);
        let plan = Pairing.plan(&c, &job, &alloc).unwrap();
        assert_eq!(plan.load_units(), plan_k3(&alloc).load_units());
        assert!(decoder::verify(&alloc, &plan).is_complete());
    }

    #[test]
    fn multicast_rejects_irregular_allocation() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let c = cluster(&[6, 7, 7]);
        let err = Multicast
            .plan(&c, &JobSpec::terasort(12), &alloc)
            .unwrap_err();
        assert!(matches!(err, HetcdcError::Unsupported { .. }));
    }

    #[test]
    fn multicast_empty_allocation_is_typed_error_not_panic() {
        let alloc = Allocation::new(3, 1, vec![]);
        let c = cluster(&[6, 7, 7]);
        let err = Multicast
            .plan(&c, &JobSpec::terasort(12), &alloc)
            .unwrap_err();
        assert!(matches!(err, HetcdcError::InvalidPlacement(_)));
    }

    #[test]
    fn registry_resolves_all_names() {
        for name in [
            "uncoded",
            "pairing",
            "greedy",
            "multicast",
            "memshare",
            "combinatorial",
        ] {
            assert_eq!(coder_by_name(name).unwrap().name(), name);
        }
        assert!(coder_by_name("rs-code").is_err());
    }

    #[test]
    fn combinatorial_coder_rejects_non_grid_allocations() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let c = cluster(&[6, 7, 7]);
        let err = Combinatorial
            .plan(&c, &JobSpec::terasort(12), &alloc)
            .unwrap_err();
        assert!(matches!(err, HetcdcError::Unsupported { .. }));
    }
}
