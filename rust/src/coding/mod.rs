//! Coded-shuffle construction and verification.
//!
//! * [`xor`] — the byte-level XOR combiner (hot path).
//! * [`plan`] — [`plan::ShufflePlan`]: the group-structured multi-round
//!   shuffle IR (rounds of multicast groups of XOR broadcasts); exact
//!   Lemma-1 plans for K=3 ([`plan::plan_k3`]) and a greedy pairing coder
//!   for any K ([`plan::plan_greedy`]).
//! * [`cdc_multicast`] — the homogeneous (r+1)-group multicast of [2]
//!   (baseline, and the j-subsystem building block of §V).
//! * [`combinatorial`] — the grid-transversal multicast of the
//!   combinatorial design: large-K multi-group schedules with no
//!   perfect-collection enumeration.
//! * [`decoder`] — symbolic decoder proving every plan delivers every
//!   needed IV to every node (the correctness oracle for all plans), and
//!   the decode schedules baked into [`crate::engine::Plan`] artifacts.
//! * [`coder`] — the [`coder::ShuffleCoder`] trait putting every
//!   construction behind one interface.

pub mod cdc_multicast;
pub mod coder;
pub mod combinatorial;
pub mod decoder;
pub mod plan;
pub mod xor;

pub use coder::{builtin_coders, coder_by_name, ShuffleCoder};
pub use decoder::verify_loss_patterns;
pub use plan::{
    with_repair_rounds, Broadcast, IvId, MulticastGroup, Part, ShufflePlan, ShuffleRound,
};
