//! Combinatorial multi-group multicast coder for grid placements — the
//! shuffle half of the hypercube/grid design
//! ([`crate::placement::combinatorial`]).
//!
//! The grid structure makes the multicast schedule *constructive*: the
//! multicast groups are the `q^r` transversals (one node per dimension),
//! known in closed form — no perfect-collection enumeration, no cap, so
//! plan-build cost is `O(K · N_sub)` at any K.
//!
//! **Exchange.** Fix a transversal group `A = {X_1[J_1], …, X_r[J_r]}`.
//! Member `j = X_d[J_d]` needs the IVs of every lattice point that agrees
//! with `J` outside dimension `d` and differs at `d` — `(q−1)·per`
//! subfiles, each held by all of `A\{j}` (they agree on the other
//! coordinates) and by one off-group node. So the group runs the [2]-style
//! segmented exchange at effective redundancy `r − 1`: in slot `t`, each
//! member `k ∈ A` broadcasts the XOR over `j ∈ A\{k}` of *its* segment
//! (`nseg = r − 1`) of `v_{j, f_j(t)}`; each receiver cancels the other
//! summands from its Map knowledge and collects its `r − 1` segments from
//! the `r − 1` senders. Per slot: `r` broadcasts of `1/(r−1)` IV units
//! serving `r` deliveries — coding gain `r − 1` over uncoded, for the
//! whole plan (every delivery is covered by exactly one group).
//!
//! **Rounds.** Transversals split into *diagonal classes*
//! `{J + c·(1,…,1) mod q : c ∈ [q]}` — each class is `q` pairwise
//! node-disjoint groups covering every node exactly once. One
//! [`ShuffleRound`] per (slot, class): `q` disjoint groups of `r`
//! broadcasts, a schedule a non-shared medium could run concurrently.

use super::plan::{Broadcast, IvId, MulticastGroup, Part, ShufflePlan, ShuffleRound};
use crate::error::{HetcdcError, Result};
use crate::placement::alloc::{Allocation, NodeMask};
use std::collections::BTreeMap;

fn unsupported(reason: String) -> HetcdcError {
    HetcdcError::Unsupported {
        strategy: "combinatorial coder",
        reason,
    }
}

/// The grid structure recovered from an allocation: `r` dimensions of `q`
/// nodes, every subfile a uniform-multiplicity transversal.
#[derive(Clone, Debug)]
pub struct GridStructure {
    pub q: usize,
    pub r: usize,
    /// `dims[d]` = node ids of dimension `d`, ascending; dimensions
    /// ordered by smallest member.
    pub dims: Vec<Vec<usize>>,
    /// `node_pos[node]` = (dimension, index within it).
    pub node_pos: Vec<(usize, usize)>,
    /// Subfiles per lattice point.
    pub per: usize,
}

/// Recover the grid from an allocation, or a typed error when the
/// allocation is not a uniform transversal design. Two nodes belong to
/// the same dimension iff they never co-hold a subfile (in a grid,
/// same-dimension nodes are mutually exclusive holders and cross-dimension
/// nodes always share `q^{r−2}·per >= 1` subfiles), so the dimension
/// partition is the clique partition of the never-co-hold graph.
pub fn detect_grid(alloc: &Allocation) -> Result<GridStructure> {
    let k = alloc.k;
    let first = alloc
        .holders
        .first()
        .ok_or_else(|| unsupported("allocation has no subfiles".into()))?;
    let r = first.count_ones() as usize;
    if r < 2 {
        return Err(unsupported(format!("redundancy {r} < 2: no multicast gain")));
    }
    if !alloc.holders.iter().all(|h| h.count_ones() as usize == r) {
        return Err(unsupported("allocation is not r-regular".into()));
    }
    if k % r != 0 || k / r < 2 {
        return Err(unsupported(format!(
            "K={k} does not factor as q·{r} with q >= 2"
        )));
    }
    let q = k / r;

    // Co-holder mask per node.
    let mut cohold: Vec<NodeMask> = vec![0; k];
    for &h in &alloc.holders {
        let mut rest = h;
        while rest != 0 {
            let node = rest.trailing_zeros() as usize;
            rest &= rest - 1;
            cohold[node] |= h & !(1 << node);
        }
    }

    // Greedy clique partition of the never-co-hold graph.
    let mut dims: Vec<Vec<usize>> = Vec::new();
    let mut dim_masks: Vec<NodeMask> = Vec::new();
    let mut node_pos: Vec<(usize, usize)> = vec![(0, 0); k];
    for node in 0..k {
        let mut placed = false;
        for (d, mask) in dim_masks.iter_mut().enumerate() {
            if cohold[node] & *mask == 0 {
                node_pos[node] = (d, dims[d].len());
                dims[d].push(node);
                *mask |= 1 << node;
                placed = true;
                break;
            }
        }
        if !placed {
            node_pos[node] = (dims.len(), 0);
            dims.push(vec![node]);
            dim_masks.push(1 << node);
        }
    }
    if dims.len() != r || dims.iter().any(|d| d.len() != q) {
        return Err(unsupported(format!(
            "nodes do not partition into {r} dimensions of {q}: got sizes {:?}",
            dims.iter().map(|d| d.len()).collect::<Vec<_>>()
        )));
    }

    // Every holder set must be a transversal: one node per dimension.
    for (sub, &h) in alloc.holders.iter().enumerate() {
        for (d, mask) in dim_masks.iter().enumerate() {
            if (h & mask).count_ones() != 1 {
                return Err(unsupported(format!(
                    "subfile {sub} holder set {h:#b} is not a transversal of dimension {d}"
                )));
            }
        }
    }

    // Uniform multiplicity over the full lattice.
    let lattice = (q as u64).checked_pow(r as u32).filter(|&l| l <= 1u64 << 24);
    let Some(lattice) = lattice else {
        return Err(unsupported(format!("lattice q^r = {q}^{r} too large")));
    };
    if alloc.n_sub() as u64 % lattice != 0 {
        return Err(unsupported(format!(
            "{} subfiles not a multiple of the {lattice}-point lattice",
            alloc.n_sub()
        )));
    }
    let per = (alloc.n_sub() as u64 / lattice) as usize;
    // BTreeMap (not HashMap): `xtask lint` bans hash-ordered iteration in
    // artifact-affecting modules, and `counts` is iterated below.
    let mut counts: BTreeMap<NodeMask, usize> = BTreeMap::new();
    for &h in &alloc.holders {
        *counts.entry(h).or_insert(0) += 1;
    }
    if counts.len() as u64 != lattice || counts.values().any(|&c| c != per) {
        return Err(unsupported(format!(
            "lattice multiplicity is not uniform ({} of {lattice} points, \
             expected {per} subfiles each)",
            counts.len()
        )));
    }

    Ok(GridStructure { q, r, dims, node_pos, per })
}

/// Build the multi-round combinatorial multicast plan for a grid
/// allocation (call [`detect_grid`] first).
pub fn plan_grid(alloc: &Allocation, grid: &GridStructure) -> ShufflePlan {
    plan_grid_threaded(alloc, grid, 1)
}

/// [`plan_grid`] with construction sharded across up to `threads` scoped
/// workers (`<= 1` = serial): the `q^r` transversal groups and then the
/// `(q−1)·per · q^{r−1}` rounds are both built by index-sharded workers
/// and merged back in index order. Every group and every round is a pure
/// function of its lattice/round index, so the emitted plan is
/// **identical** for every thread count.
pub fn plan_grid_threaded(
    alloc: &Allocation,
    grid: &GridStructure,
    threads: usize,
) -> ShufflePlan {
    let (q, r, per) = (grid.q, grid.r, grid.per);
    let k = alloc.k;
    let nseg = (r - 1) as u32;

    // Subfiles per holder mask, ascending subfile order.
    let mut by_mask: BTreeMap<NodeMask, Vec<usize>> = BTreeMap::new();
    for (sub, &h) in alloc.holders.iter().enumerate() {
        by_mask.entry(h).or_default().push(sub);
    }

    let mask_of = |coords: &[usize]| -> NodeMask {
        coords
            .iter()
            .enumerate()
            .fold(0, |m, (d, &c)| m | (1 << grid.dims[d][c]))
    };

    // Per transversal group: member nodes (ascending) and each member's
    // needed-subfile list — the (q−1)·per lattice neighbors along its own
    // dimension, ordered by coordinate then subfile id. Built ONCE per
    // lattice point (slot-independent; slots index into the lists), so
    // plan construction stays O(K·N_sub).
    struct Group {
        members: NodeMask,
        nodes: Vec<usize>,
        /// `lists[i]` = needed subfiles of `nodes[i]`, slot-indexed.
        lists: Vec<Vec<usize>>,
    }
    let group_of = |coords: &[usize]| -> Group {
        let members = mask_of(coords);
        let mut nodes: Vec<usize> = coords
            .iter()
            .enumerate()
            .map(|(d, &c)| grid.dims[d][c])
            .collect();
        nodes.sort_unstable();
        let lists = nodes
            .iter()
            .map(|&j| {
                let (d, _) = grid.node_pos[j];
                let mut list = Vec::with_capacity((q - 1) * per);
                let mut other = coords.to_vec();
                for m in 0..q {
                    if m == coords[d] {
                        continue;
                    }
                    other[d] = m;
                    list.extend_from_slice(&by_mask[&mask_of(&other)]);
                }
                list
            })
            .collect();
        Group { members, nodes, lists }
    };
    // Mixed-radix lattice coordinates of point `i` (first coordinate most
    // significant, last fastest — the order the serial odometer walked).
    let coords_of = |i: usize| -> Vec<usize> {
        let mut coords = vec![0usize; r];
        let mut x = i;
        for d in (0..r).rev() {
            coords[d] = x % q;
            x /= q;
        }
        coords
    };

    // All q^r groups, indexed by lattice coordinates. Each group is a
    // pure function of its lattice index, so construction shards across
    // workers and merges back in index order — identical at any count.
    let lattice: usize = (0..r).map(|_| q).product();
    let groups: Vec<Group> = crate::util::shard::shard_indexed(lattice, threads, |range| {
        range.map(|i| group_of(&coords_of(i))).collect()
    });
    let index_of = |coords: &[usize]| -> usize { coords.iter().fold(0, |i, &c| i * q + c) };

    // Diagonal-class rounds, one per (slot t, representative): the
    // representative is a lattice point with first coordinate 0
    // (lexicographic, last coordinate fastest), and the round's q groups
    // are its diagonal translates. Like the groups, each round is a pure
    // function of its flat index, so assembly shards the same way.
    let reps: usize = (0..r - 1).map(|_| q).product();
    let slots = (q - 1) * per;
    let total_rounds = slots * reps;
    let groups = &groups;
    let build_round = |round_idx: usize| -> ShuffleRound {
        let t = round_idx / reps;
        let rep_idx = round_idx % reps;
        let mut rep_coords = vec![0usize; r];
        let mut x = rep_idx;
        for d in (1..r).rev() {
            rep_coords[d] = x % q;
            x /= q;
        }
        let mut round = ShuffleRound::default();
        for c in 0..q {
            let coords: Vec<usize> = rep_coords.iter().map(|&v| (v + c) % q).collect();
            let g = &groups[index_of(&coords)];
            let mut group = MulticastGroup {
                members: g.members,
                broadcasts: Vec::with_capacity(r),
            };
            for &ki in &g.nodes {
                let mut parts = Vec::with_capacity(r - 1);
                for (j_pos, &j) in g.nodes.iter().enumerate() {
                    if j == ki {
                        continue;
                    }
                    // Position of ki within A\{j} (ascending order).
                    let seg = g
                        .nodes
                        .iter()
                        .filter(|&&x| x != j)
                        .position(|&x| x == ki)
                        .unwrap() as u32;
                    parts.push(Part {
                        iv: IvId { group: j, sub: g.lists[j_pos][t] },
                        seg,
                        nseg,
                    });
                }
                group.broadcasts.push(Broadcast::Coded { sender: ki, parts });
            }
            round.groups.push(group);
        }
        round
    };
    let rounds = crate::util::shard::shard_indexed(total_rounds, threads, |range| {
        range.map(&build_round).collect()
    });
    let mut plan = ShufflePlan::new(k);
    for round in rounds {
        plan.push_round(round);
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::decoder::verify;
    use crate::coding::plan::plan_greedy;
    use crate::placement::combinatorial::{choose_grid, grid_allocation};
    use crate::placement::homogeneous::symmetric_allocation;
    use crate::placement::k3::optimal_allocation;
    use crate::theory::params::Params3;

    fn grid(k: usize, n: u64, m_min: u64) -> (Allocation, GridStructure) {
        let g = choose_grid(k, n, m_min).unwrap();
        let alloc = grid_allocation(k, n, &g);
        let detected = detect_grid(&alloc).unwrap();
        assert_eq!((detected.q, detected.r), (g.q, g.r));
        assert_eq!(detected.per as u64, g.per);
        (alloc, detected)
    }

    #[test]
    fn k8_grid_plan_decodes_with_gain_3() {
        let (alloc, structure) = grid(8, 8, 4);
        let plan = plan_grid(&alloc, &structure);
        let report = verify(&alloc, &plan);
        assert!(report.is_complete(), "missing {:?}", report.missing);
        // gain r−1 = 3: load = uncoded / 3.
        let uncoded = alloc.uncoded_units() as f64;
        assert!((plan.load_units() - uncoded / 3.0).abs() < 1e-9);
        // Diagonal-class rounds: (q−1)·per slots × q^{r−1} classes.
        assert_eq!(plan.round_count(), 8);
        for round in &plan.rounds {
            assert_eq!(round.groups.len(), structure.q);
            // Groups within a round are node-disjoint and cover [K].
            let mut seen: u32 = 0;
            for g in &round.groups {
                assert_eq!(seen & g.members, 0, "round groups must be disjoint");
                seen |= g.members;
            }
            assert_eq!(seen, alloc.full_mask());
        }
    }

    #[test]
    fn k8_grid_beats_greedy_pairing() {
        let (alloc, structure) = grid(8, 8, 4);
        let comb = plan_grid(&alloc, &structure);
        let greedy = plan_greedy(&alloc);
        assert!(verify(&alloc, &greedy).is_complete());
        assert!(
            comb.load_units() < greedy.load_units(),
            "combinatorial {} !< greedy {}",
            comb.load_units(),
            greedy.load_units()
        );
        // Greedy pairing gains at most 2; the grid exchange gains r−1 = 3.
        assert!(comb.load_units() <= greedy.load_units() * 2.0 / 3.0 + 1e-9);
    }

    #[test]
    fn threaded_plan_is_identical_at_every_thread_count() {
        // Groups and rounds are pure functions of their indices, so the
        // sharded construction must emit the exact same plan structure —
        // every round, group, broadcast, part, and segment index.
        for (k, n, m) in [(8usize, 8u64, 4u64), (12, 12, 4), (16, 16, 8)] {
            let (alloc, structure) = grid(k, n, m);
            let serial = plan_grid(&alloc, &structure);
            for threads in [2usize, 3, 8] {
                let sharded = plan_grid_threaded(&alloc, &structure, threads);
                assert_eq!(serial, sharded, "K={k} threads={threads}");
            }
        }
    }

    #[test]
    fn k12_and_k16_grids_decode() {
        for (k, n, m) in [(12usize, 12u64, 4u64), (16, 16, 8)] {
            let (alloc, structure) = grid(k, n, m);
            let plan = plan_grid(&alloc, &structure);
            let report = verify(&alloc, &plan);
            assert!(report.is_complete(), "K={k}: missing IVs");
            let gain = (structure.r - 1) as f64;
            assert!(
                (plan.load_units() - alloc.uncoded_units() as f64 / gain).abs() < 1e-6,
                "K={k}: load {} != uncoded/{gain}",
                plan.load_units()
            );
        }
    }

    #[test]
    fn r2_grid_degenerates_to_uncoded_load_but_decodes() {
        // K=8 with storage floor 2 only fits q=4, r=2: gain 1.
        let (alloc, structure) = grid(8, 8, 2);
        assert_eq!(structure.r, 2);
        let plan = plan_grid(&alloc, &structure);
        assert!(verify(&alloc, &plan).is_complete());
        assert_eq!(plan.load_units() as u64, alloc.uncoded_units());
    }

    #[test]
    fn detect_grid_rejects_non_grid_allocations() {
        // Theorem-1 K=3 allocation: irregular redundancy.
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let err = detect_grid(&optimal_allocation(&p)).unwrap_err();
        assert!(matches!(err, HetcdcError::Unsupported { .. }));
        // Symmetric C(K,r) allocation: r-regular but every pair of nodes
        // co-holds, so no dimension partition exists.
        let err = detect_grid(&symmetric_allocation(4, 2, 12)).unwrap_err();
        assert!(matches!(err, HetcdcError::Unsupported { .. }), "{err:?}");
        // Empty allocation.
        let err = detect_grid(&Allocation::new(4, 1, vec![])).unwrap_err();
        assert!(matches!(err, HetcdcError::Unsupported { .. }));
    }

    #[test]
    fn every_delivery_covered_exactly_once() {
        let (alloc, structure) = grid(8, 8, 4);
        let plan = plan_grid(&alloc, &structure);
        let mut seen = std::collections::HashSet::new();
        for b in plan.iter_broadcasts() {
            let Broadcast::Coded { parts, .. } = b else {
                panic!("grid plan must be fully coded");
            };
            for p in parts {
                assert_eq!(
                    alloc.holders[p.iv.sub] & (1 << p.iv.group),
                    0,
                    "delivery to a holder"
                );
                // Each (dest, sub) delivery appears once per segment.
                assert!(
                    seen.insert((p.iv, p.seg)),
                    "segment {:?}/{} scheduled twice",
                    p.iv,
                    p.seg
                );
            }
        }
        // Every needed (dest, sub) collected all r−1 segments.
        let nseg = (structure.r - 1) as u32;
        for (sub, &h) in alloc.holders.iter().enumerate() {
            for dest in 0..alloc.k {
                if h & (1 << dest) != 0 {
                    continue;
                }
                for seg in 0..nseg {
                    assert!(
                        seen.contains(&(IvId { group: dest, sub }, seg)),
                        "missing segment {seg} of ({dest}, {sub})"
                    );
                }
            }
        }
    }
}
