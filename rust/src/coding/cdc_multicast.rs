//! Homogeneous CDC multicast of Li–Maddah-Ali–Avestimehr [2].
//!
//! For a symmetric r-redundant placement (every r-subset `T` holds the
//! same number of subfiles), the Shuffle runs per (r+1)-subset `A`: for
//! each `j ∈ A` the IVs `v_{j, S_{A\{j}}}` are split into `r` segments
//! indexed by the members of `A\{j}`; each node `k ∈ A` broadcasts the XOR
//! over `j ∈ A\{k}` of *its* segment of `v_{j, ·}`. Every receiver
//! `j ∈ A\{k}` knows all other summands (it holds their subfiles) and
//! recovers its segment; across the `r` senders of `A\{j}` it collects all
//! `r` segments. Total load: `N(K−r)/r` IV units — the factor-`r` coding
//! gain the paper's §V cost function assumes per subsystem.
//!
//! On the round IR the plan is genuinely multi-round: round `t` carries
//! slot `t` of *every* (r+1)-subset — one [`MulticastGroup`] per subset
//! `A` with its `r+1` coded broadcasts — so the round count equals the
//! per-subset subfile count and a bench artifact can diff it.

use super::plan::{Broadcast, IvId, MulticastGroup, Part, ShufflePlan, ShuffleRound};
use crate::placement::alloc::Allocation;

/// Nodes of `mask` in ascending order.
fn nodes_of(mask: u32) -> Vec<usize> {
    (0..32).filter(|i| mask & (1 << i) != 0).collect()
}

/// Build the [2] multicast plan for a symmetric r-redundant allocation.
///
/// Requires every subfile's holder set to have exactly `r` nodes and every
/// r-subset to hold the same count (use
/// [`crate::placement::homogeneous::symmetric_allocation`]).
pub fn plan_homogeneous(alloc: &Allocation, r: usize) -> ShufflePlan {
    let k = alloc.k;
    assert!(r >= 1 && r <= k);
    assert!(
        alloc.holders.iter().all(|h| h.count_ones() as usize == r),
        "allocation is not r-regular"
    );
    if r == k {
        return ShufflePlan::new(k); // everything everywhere: nothing to shuffle
    }

    // Special case r == 1: no coding possible within groups of size 2;
    // uncoded broadcast from the unique holder — structurally identical
    // to the uncoded baseline (one round, one group per subfile).
    if r == 1 {
        return super::plan::plan_uncoded(alloc);
    }

    // Pre-index subfiles by holder mask.
    let mut by_mask: Vec<Vec<usize>> = vec![Vec::new(); 1 << k];
    for (sub, &h) in alloc.holders.iter().enumerate() {
        by_mask[h as usize].push(sub);
    }

    // Collect the (r+1)-subsets A with their per-member needed-file lists
    // once; rounds then iterate slots across all subsets.
    struct Subsystem<'a> {
        a_mask: u32,
        a_nodes: Vec<usize>,
        per: Vec<&'a Vec<usize>>,
        count: usize,
    }
    let mut subsystems: Vec<Subsystem> = Vec::new();
    let mut max_count = 0usize;
    for a_mask in 1u32..(1 << k) {
        if a_mask.count_ones() as usize != r + 1 {
            continue;
        }
        let a_nodes = nodes_of(a_mask);
        // For j in A: files held by A\{j}, needed by j.
        let per: Vec<&Vec<usize>> = a_nodes
            .iter()
            .map(|&j| &by_mask[(a_mask & !(1 << j)) as usize])
            .collect();
        let count = per.iter().map(|v| v.len()).min().unwrap_or(0);
        // Symmetric placements have equal counts; assert to catch misuse.
        debug_assert!(
            per.iter().all(|v| v.len() == count),
            "asymmetric counts within group {a_mask:b}"
        );
        max_count = max_count.max(count);
        subsystems.push(Subsystem { a_mask, a_nodes, per, count });
    }

    let mut plan = ShufflePlan::new(k);
    for t in 0..max_count {
        let mut round = ShuffleRound::default();
        for sys in &subsystems {
            if t >= sys.count {
                continue;
            }
            let mut group = MulticastGroup {
                members: sys.a_mask,
                broadcasts: Vec::with_capacity(r + 1),
            };
            // Node k_i broadcasts XOR over j != k_i of segment_{k_i} of
            // v_{j, file_j(t)}; segment index = position of k_i in A\{j}.
            for &ki in &sys.a_nodes {
                let mut parts = Vec::with_capacity(r);
                for (j_pos, &j) in sys.a_nodes.iter().enumerate() {
                    if j == ki {
                        continue;
                    }
                    let sub = sys.per[j_pos][t];
                    // Position of ki within A\{j} (ascending order).
                    let seg = sys
                        .a_nodes
                        .iter()
                        .filter(|&&x| x != j)
                        .position(|&x| x == ki)
                        .unwrap() as u32;
                    parts.push(Part {
                        iv: IvId { group: j, sub },
                        seg,
                        nseg: r as u32,
                    });
                }
                group.broadcasts.push(Broadcast::Coded { sender: ki, parts });
            }
            round.groups.push(group);
        }
        plan.push_round(round);
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::decoder::verify;
    use crate::placement::homogeneous::{binom, symmetric_allocation};
    use crate::prop;
    use crate::theory::homogeneous::load_at_r;

    #[test]
    fn k3_r2_load_matches_theory_and_decodes() {
        let alloc = symmetric_allocation(3, 2, 12);
        let plan = plan_homogeneous(&alloc, 2);
        // L = N(K−r)/r = 6 IV units.
        assert!((plan.load_equations(&alloc) - load_at_r(3, 2, 12)).abs() < 1e-9);
        let report = verify(&alloc, &plan);
        assert!(report.is_complete(), "missing {:?}", report.missing);
    }

    #[test]
    fn k4_r2_load_matches_theory_and_decodes() {
        let alloc = symmetric_allocation(4, 2, 12);
        let plan = plan_homogeneous(&alloc, 2);
        assert!((plan.load_equations(&alloc) - load_at_r(4, 2, 12)).abs() < 1e-9);
        assert!(verify(&alloc, &plan).is_complete());
    }

    #[test]
    fn k4_r3_load_matches_theory_and_decodes() {
        let alloc = symmetric_allocation(4, 3, 8);
        let plan = plan_homogeneous(&alloc, 3);
        assert!((plan.load_equations(&alloc) - load_at_r(4, 3, 8)).abs() < 1e-9);
        assert!(verify(&alloc, &plan).is_complete());
    }

    #[test]
    fn round_structure_is_slot_by_subset() {
        // K=4, r=2, N=12: C(4,2)=6 pairs, 2 subfiles each; C(4,3)=4
        // subsets of size r+1, each with per-member count 2 -> 2 rounds,
        // each holding 4 groups of r+1 = 3 broadcasts.
        let alloc = symmetric_allocation(4, 2, 12);
        let plan = plan_homogeneous(&alloc, 2);
        assert_eq!(plan.round_count(), 2);
        for round in &plan.rounds {
            assert_eq!(round.groups.len(), 4);
            for group in &round.groups {
                assert_eq!(group.members.count_ones(), 3);
                assert_eq!(group.broadcasts.len(), 3);
            }
        }
    }

    #[test]
    fn r1_falls_back_to_uncoded() {
        let alloc = symmetric_allocation(3, 1, 6);
        let plan = plan_homogeneous(&alloc, 1);
        assert!((plan.load_equations(&alloc) - load_at_r(3, 1, 6)).abs() < 1e-9);
        assert!(verify(&alloc, &plan).is_complete());
        // Structurally the uncoded baseline: single round, whole-IV
        // broadcasts only, load equal to the uncoded delivery count.
        assert_eq!(plan.round_count(), 1);
        assert_eq!(plan.load_units() as u64, alloc.uncoded_units());
    }

    #[test]
    fn full_replication_needs_no_shuffle() {
        let alloc = symmetric_allocation(3, 3, 6);
        let plan = plan_homogeneous(&alloc, 3);
        assert_eq!(plan.n_broadcasts(), 0);
        assert_eq!(plan.round_count(), 0);
        assert!(verify(&alloc, &plan).is_complete());
    }

    #[test]
    fn edge_cases_r_eq_k_and_r_eq_1_for_k_up_to_6() {
        // r = k: the plan must be literally empty (not just zero-load).
        for k in 2..=6usize {
            for n in [1u64, 4, 6] {
                let alloc = symmetric_allocation(k, k, n);
                let plan = plan_homogeneous(&alloc, k);
                assert_eq!(plan.n_broadcasts(), 0, "k={k} n={n}");
                assert!(verify(&alloc, &plan).is_complete(), "k={k} n={n}");
            }
            // r = 1: uncoded-equivalent — exactly N_sub(K−1) whole-IV
            // units, every broadcast uncoded.
            for n in [1u64, 5] {
                let alloc = symmetric_allocation(k, 1, n);
                let plan = plan_homogeneous(&alloc, 1);
                assert_eq!(
                    plan.load_units() as u64,
                    alloc.n_sub() as u64 * (k as u64 - 1),
                    "k={k} n={n}"
                );
                assert_eq!(plan.load_units() as u64, alloc.uncoded_units());
                assert!(
                    plan.iter_broadcasts()
                        .all(|b| matches!(b, Broadcast::Uncoded { .. })),
                    "k={k} n={n}: r=1 must not emit coded broadcasts"
                );
                assert!(verify(&alloc, &plan).is_complete(), "k={k} n={n}");
            }
        }
    }

    #[test]
    fn load_identity_n_k_minus_r_over_r_k_up_to_6() {
        // The N(K−r)/r identity of [2], checked for every (k, r) with
        // K ≤ 6 against the theory curve — and against the closed form
        // directly, so a theory-side regression cannot mask a plan bug.
        for k in 2..=6usize {
            for r in 1..=k {
                for n in [1u64, 3, 6] {
                    let alloc = symmetric_allocation(k, r, n);
                    let plan = plan_homogeneous(&alloc, r);
                    let got = plan.load_equations(&alloc);
                    let closed = n as f64 * (k - r) as f64 / r as f64;
                    let theory = load_at_r(k as u64, r as u64, n);
                    assert!(
                        (got - closed).abs() < 1e-9,
                        "k={k} r={r} n={n}: plan {got} != N(K-r)/r {closed}"
                    );
                    assert!(
                        (got - theory).abs() < 1e-9,
                        "k={k} r={r} n={n}: plan {got} != theory {theory}"
                    );
                    // Round count = per-subset slot count (0 when r=k,
                    // 1 for the uncoded fallback).
                    let expected_rounds = if r == k {
                        0
                    } else if r == 1 {
                        1
                    } else {
                        (alloc.n_sub() / binom(k as u64, r as u64) as usize).max(1)
                    };
                    assert_eq!(
                        plan.round_count(),
                        expected_rounds,
                        "k={k} r={r} n={n}: unexpected round structure"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_homogeneous_matches_li_curve_and_decodes() {
        prop::run("[2] multicast: load + decode", 60, |g| {
            let k = g.usize_in(2..=5);
            let r = g.usize_in(1..=k);
            let n = g.u64_in(1..=12);
            let alloc = symmetric_allocation(k, r, n);
            let plan = plan_homogeneous(&alloc, r);
            let want = load_at_r(k as u64, r as u64, n);
            let got = plan.load_equations(&alloc);
            if (got - want).abs() > 1e-9 {
                return prop::fail(format!("k={k} r={r} n={n}: load {got} != {want}"));
            }
            let report = verify(&alloc, &plan);
            prop::check(
                report.is_complete(),
                format!("k={k} r={r} n={n}: incomplete"),
            )
        });
    }
}
