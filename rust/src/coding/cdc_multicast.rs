//! Homogeneous CDC multicast of Li–Maddah-Ali–Avestimehr [2].
//!
//! For a symmetric r-redundant placement (every r-subset `T` holds the
//! same number of subfiles), the Shuffle runs per (r+1)-subset `A`: for
//! each `j ∈ A` the IVs `v_{j, S_{A\{j}}}` are split into `r` segments
//! indexed by the members of `A\{j}`; each node `k ∈ A` broadcasts the XOR
//! over `j ∈ A\{k}` of *its* segment of `v_{j, ·}`. Every receiver
//! `j ∈ A\{k}` knows all other summands (it holds their subfiles) and
//! recovers its segment; across the `r` senders of `A\{j}` it collects all
//! `r` segments. Total load: `N(K−r)/r` IV units — the factor-`r` coding
//! gain the paper's §V cost function assumes per subsystem.

use super::plan::{Broadcast, IvId, Part, ShufflePlan};
use crate::placement::alloc::Allocation;

/// Nodes of `mask` in ascending order.
fn nodes_of(mask: u32) -> Vec<usize> {
    (0..32).filter(|i| mask & (1 << i) != 0).collect()
}

/// Build the [2] multicast plan for a symmetric r-redundant allocation.
///
/// Requires every subfile's holder set to have exactly `r` nodes and every
/// r-subset to hold the same count (use
/// [`crate::placement::homogeneous::symmetric_allocation`]).
pub fn plan_homogeneous(alloc: &Allocation, r: usize) -> ShufflePlan {
    let k = alloc.k;
    assert!(r >= 1 && r <= k);
    assert!(
        alloc.holders.iter().all(|h| h.count_ones() as usize == r),
        "allocation is not r-regular"
    );
    let mut plan = ShufflePlan {
        k,
        broadcasts: Vec::new(),
    };

    if r == k {
        return plan; // everything everywhere: nothing to shuffle
    }

    // Special case r == 1: no coding possible within groups of size 2;
    // uncoded broadcast from the unique holder.
    if r == 1 {
        for (sub, &h) in alloc.holders.iter().enumerate() {
            let sender = h.trailing_zeros() as usize;
            for dest in 0..k {
                if dest != sender {
                    plan.broadcasts.push(Broadcast::Uncoded {
                        sender,
                        iv: IvId { group: dest, sub },
                    });
                }
            }
        }
        return plan;
    }

    // Pre-index subfiles by holder mask.
    let mut by_mask: Vec<Vec<usize>> = vec![Vec::new(); 1 << k];
    for (sub, &h) in alloc.holders.iter().enumerate() {
        by_mask[h as usize].push(sub);
    }

    // Iterate over (r+1)-subsets A.
    for a_mask in 1u32..(1 << k) {
        if a_mask.count_ones() as usize != r + 1 {
            continue;
        }
        let a_nodes = nodes_of(a_mask);
        // For j in A: files held by A\{j}, needed by j.
        let per: Vec<&Vec<usize>> = a_nodes
            .iter()
            .map(|&j| &by_mask[(a_mask & !(1 << j)) as usize])
            .collect();
        let count = per.iter().map(|v| v.len()).min().unwrap_or(0);
        // Symmetric placements have equal counts; assert to catch misuse.
        debug_assert!(
            per.iter().all(|v| v.len() == count),
            "asymmetric counts within group {a_mask:b}"
        );
        for t in 0..count {
            // Node k_i broadcasts XOR over j != k_i of segment_{k_i} of
            // v_{j, file_j(t)}; segment index = position of k_i in A\{j}.
            for (ki_pos, &ki) in a_nodes.iter().enumerate() {
                let mut parts = Vec::with_capacity(r);
                for (j_pos, &j) in a_nodes.iter().enumerate() {
                    if j == ki {
                        continue;
                    }
                    let sub = per[j_pos][t];
                    // Position of ki within A\{j} (ascending order).
                    let seg = a_nodes
                        .iter()
                        .filter(|&&x| x != j)
                        .position(|&x| x == ki)
                        .unwrap() as u32;
                    parts.push(Part {
                        iv: IvId { group: j, sub },
                        seg,
                        nseg: r as u32,
                    });
                }
                let _ = ki_pos;
                plan.broadcasts.push(Broadcast::Coded { sender: ki, parts });
            }
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::decoder::verify;
    use crate::placement::homogeneous::symmetric_allocation;
    use crate::prop;
    use crate::theory::homogeneous::load_at_r;

    #[test]
    fn k3_r2_load_matches_theory_and_decodes() {
        let alloc = symmetric_allocation(3, 2, 12);
        let plan = plan_homogeneous(&alloc, 2);
        // L = N(K−r)/r = 6 IV units.
        assert!((plan.load_equations(&alloc) - load_at_r(3, 2, 12)).abs() < 1e-9);
        let report = verify(&alloc, &plan);
        assert!(report.is_complete(), "missing {:?}", report.missing);
    }

    #[test]
    fn k4_r2_load_matches_theory_and_decodes() {
        let alloc = symmetric_allocation(4, 2, 12);
        let plan = plan_homogeneous(&alloc, 2);
        assert!((plan.load_equations(&alloc) - load_at_r(4, 2, 12)).abs() < 1e-9);
        assert!(verify(&alloc, &plan).is_complete());
    }

    #[test]
    fn k4_r3_load_matches_theory_and_decodes() {
        let alloc = symmetric_allocation(4, 3, 8);
        let plan = plan_homogeneous(&alloc, 3);
        assert!((plan.load_equations(&alloc) - load_at_r(4, 3, 8)).abs() < 1e-9);
        assert!(verify(&alloc, &plan).is_complete());
    }

    #[test]
    fn r1_falls_back_to_uncoded() {
        let alloc = symmetric_allocation(3, 1, 6);
        let plan = plan_homogeneous(&alloc, 1);
        assert!((plan.load_equations(&alloc) - load_at_r(3, 1, 6)).abs() < 1e-9);
        assert!(verify(&alloc, &plan).is_complete());
    }

    #[test]
    fn full_replication_needs_no_shuffle() {
        let alloc = symmetric_allocation(3, 3, 6);
        let plan = plan_homogeneous(&alloc, 3);
        assert!(plan.broadcasts.is_empty());
        assert!(verify(&alloc, &plan).is_complete());
    }

    #[test]
    fn prop_homogeneous_matches_li_curve_and_decodes() {
        prop::run("[2] multicast: load + decode", 60, |g| {
            let k = g.usize_in(2..=5);
            let r = g.usize_in(1..=k);
            let n = g.u64_in(1..=12);
            let alloc = symmetric_allocation(k, r, n);
            let plan = plan_homogeneous(&alloc, r);
            let want = load_at_r(k as u64, r as u64, n);
            let got = plan.load_equations(&alloc);
            if (got - want).abs() > 1e-9 {
                return prop::fail(format!("k={k} r={r} n={n}: load {got} != {want}"));
            }
            let report = verify(&alloc, &plan);
            prop::check(
                report.is_complete(),
                format!("k={k} r={r} n={n}: incomplete"),
            )
        });
    }
}
