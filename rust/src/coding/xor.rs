//! Byte-level XOR combine — the shuffle hot path.
//!
//! `xor_into(dst, src)` computes `dst ^= src` over `u64` words with a byte
//! tail, no allocation. This is the Rust counterpart of the Layer-1
//! `xor_blocks` Pallas kernel; integration tests cross-check the two
//! bit-for-bit through the PJRT runtime.

/// `dst ^= src` (lengths must match).
pub fn xor_into(dst: &mut [u8], src: &[u8]) {
    assert_eq!(dst.len(), src.len(), "xor length mismatch");
    // u64 body.
    let n = dst.len();
    let words = n / 8;
    // Safety-free word loop: chunks_exact keeps this in safe Rust; the
    // compiler vectorizes it (verified in bench_kernels).
    let (d_body, d_tail) = dst.split_at_mut(words * 8);
    let (s_body, s_tail) = src.split_at(words * 8);
    for (dc, sc) in d_body.chunks_exact_mut(8).zip(s_body.chunks_exact(8)) {
        let d = u64::from_ne_bytes(dc.try_into().unwrap());
        let s = u64::from_ne_bytes(sc.try_into().unwrap());
        dc.copy_from_slice(&(d ^ s).to_ne_bytes());
    }
    for (d, s) in d_tail.iter_mut().zip(s_tail) {
        *d ^= s;
    }
}

/// Fresh XOR of two buffers.
pub fn xor_of(a: &[u8], b: &[u8]) -> Vec<u8> {
    let mut out = a.to_vec();
    xor_into(&mut out, b);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::util::rng::Xoshiro256;

    fn rand_bytes(rng: &mut Xoshiro256, n: usize) -> Vec<u8> {
        (0..n).map(|_| rng.next_u64() as u8).collect()
    }

    #[test]
    fn xor_matches_scalar_reference() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let a = rand_bytes(&mut rng, n);
            let b = rand_bytes(&mut rng, n);
            let got = xor_of(&a, &b);
            let want: Vec<u8> = a.iter().zip(&b).map(|(x, y)| x ^ y).collect();
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn involution_recovers_original() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let a = rand_bytes(&mut rng, 129);
        let b = rand_bytes(&mut rng, 129);
        let mut x = a.clone();
        xor_into(&mut x, &b);
        xor_into(&mut x, &b);
        assert_eq!(x, a);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn length_mismatch_panics() {
        xor_into(&mut [0u8; 4], &[0u8; 5]);
    }

    #[test]
    fn prop_commutative_associative() {
        prop::run("xor algebra", 100, |g| {
            let n = g.usize_in(0..=64);
            let a: Vec<u8> = (0..n).map(|_| g.u64_in(0..=255) as u8).collect();
            let b: Vec<u8> = (0..n).map(|_| g.u64_in(0..=255) as u8).collect();
            let c: Vec<u8> = (0..n).map(|_| g.u64_in(0..=255) as u8).collect();
            let ab = xor_of(&a, &b);
            let ba = xor_of(&b, &a);
            let abc1 = xor_of(&ab, &c);
            let abc2 = xor_of(&a, &xor_of(&b, &c));
            prop::check(ab == ba && abc1 == abc2, format!("n={n}"))
        });
    }
}
