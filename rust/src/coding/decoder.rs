//! Symbolic shuffle decoder: proves a [`ShufflePlan`] is decodable.
//!
//! Simulates the Reduce-phase knowledge of every node: a node knows every
//! IV of every subfile it holds (Map phase), plus whatever it can decode
//! from the broadcast sequence. A coded broadcast is decodable by a node
//! when at most one of its parts is unknown to that node; decoding learns
//! that part. Iterates to fixpoint (plans may be order-dependent), then
//! checks the §II Reduce requirement: node `n` knows `(n, f)` for every
//! subfile `f`.
//!
//! The decoder works over the plan's **flattened** broadcast order
//! (round-major, group-major — see [`ShufflePlan::iter_broadcasts`]);
//! every index in a [`DecodeSchedule`] refers to that order, which is
//! also the executor's transmission order, so round structure never
//! changes what a schedule index means.

use super::plan::{Broadcast, IvId, ShufflePlan};
use crate::error::{HetcdcError, Result};
use crate::placement::alloc::Allocation;
use std::collections::HashMap;

/// Per-node knowledge of IV segments: `(iv) -> (nseg, bitmask of known
/// segments)`. A fully-known IV is `(1, 0b1)` or all-`nseg` bits.
#[derive(Clone, Debug, Default)]
pub struct Knowledge {
    segs: HashMap<IvId, (u32, u64)>,
    /// Subfiles held (full IVs for every group).
    holds: Vec<bool>,
}

impl Knowledge {
    fn new(n_sub: usize) -> Self {
        Self {
            segs: HashMap::new(),
            holds: vec![false; n_sub],
        }
    }

    fn knows_part(&self, iv: IvId, seg: u32, nseg: u32) -> bool {
        if self.holds[iv.sub] {
            return true;
        }
        match self.segs.get(&iv) {
            Some((n, mask)) => {
                if *n == nseg {
                    mask & (1 << seg) != 0
                } else {
                    // Whole-IV knowledge recorded with nseg=1 covers all.
                    *n == 1 && mask & 1 != 0
                }
            }
            None => false,
        }
    }

    fn learn_part(&mut self, iv: IvId, seg: u32, nseg: u32) {
        let entry = self.segs.entry(iv).or_insert((nseg, 0));
        if entry.0 != nseg {
            // Mixed granularities: only upgrade to whole-IV knowledge.
            if nseg == 1 {
                *entry = (1, 1);
            }
            return;
        }
        entry.1 |= 1 << seg;
    }

    /// Knows the complete IV payload?
    pub fn knows_iv(&self, iv: IvId) -> bool {
        if self.holds[iv.sub] {
            return true;
        }
        match self.segs.get(&iv) {
            Some((nseg, mask)) => {
                let full = if *nseg >= 64 { u64::MAX } else { (1u64 << nseg) - 1 };
                *mask & full == full
            }
            None => false,
        }
    }
}

/// Outcome of symbolic decoding.
#[derive(Clone, Debug)]
pub struct DecodeReport {
    /// Per-node: list of missing IVs (empty everywhere iff plan is valid).
    pub missing: Vec<Vec<IvId>>,
    /// Fixpoint decode passes used.
    pub passes: usize,
}

impl DecodeReport {
    pub fn is_complete(&self) -> bool {
        self.missing.iter().all(|m| m.is_empty())
    }
}

/// Deterministic per-node decode order for a verified plan: entry
/// `order[node]` lists broadcast indices in an order such that each one is
/// decodable given Map-phase knowledge plus all earlier entries. Baked
/// into [`crate::engine::Plan`] artifacts so execution replays decoding
/// without any fixpoint iteration or re-verification.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DecodeSchedule {
    pub order: Vec<Vec<usize>>,
    /// Fixpoint passes the symbolic decoder needed to converge.
    pub passes: usize,
}

/// Shared symbolic simulation: final knowledge, per-node learn order, and
/// pass count. Senders never "learn" from their own broadcasts (they hold
/// every part they transmit).
fn simulate(alloc: &Allocation, plan: &ShufflePlan) -> (Vec<Knowledge>, Vec<Vec<usize>>, usize) {
    let k = alloc.k;
    let n_sub = alloc.n_sub();
    let mut know: Vec<Knowledge> = (0..k).map(|_| Knowledge::new(n_sub)).collect();
    for (sub, &h) in alloc.holders.iter().enumerate() {
        for (node, knowledge) in know.iter_mut().enumerate() {
            if h & (1 << node) != 0 {
                knowledge.holds[sub] = true;
            }
        }
    }

    // Fixpoint over the flattened broadcasts (senders know their own
    // payloads already).
    let flat: Vec<&Broadcast> = plan.iter_broadcasts().collect();
    let mut order: Vec<Vec<usize>> = vec![Vec::new(); k];
    let mut passes = 0;
    loop {
        passes += 1;
        let mut progress = false;
        for (bi, b) in flat.iter().enumerate() {
            match b {
                Broadcast::Uncoded { iv, .. } => {
                    for (node, knowledge) in know.iter_mut().enumerate() {
                        if !knowledge.knows_part(*iv, 0, 1) {
                            knowledge.learn_part(*iv, 0, 1);
                            order[node].push(bi);
                            progress = true;
                        }
                    }
                }
                Broadcast::Coded { parts, .. } => {
                    for (node, knowledge) in know.iter_mut().enumerate() {
                        let unknown: Vec<_> = parts
                            .iter()
                            .filter(|p| !knowledge.knows_part(p.iv, p.seg, p.nseg))
                            .collect();
                        if unknown.len() == 1 {
                            let p = unknown[0];
                            knowledge.learn_part(p.iv, p.seg, p.nseg);
                            order[node].push(bi);
                            progress = true;
                        }
                    }
                }
            }
        }
        if !progress || passes > flat.len() + 2 {
            break;
        }
    }
    (know, order, passes)
}

/// Simulate decoding of `plan` under `alloc`; check Reduce completeness.
pub fn verify(alloc: &Allocation, plan: &ShufflePlan) -> DecodeReport {
    let (know, _, passes) = simulate(alloc, plan);
    // Reduce requirement: node n needs (n, f) for every subfile f.
    let missing = (0..alloc.k)
        .map(|node| {
            (0..alloc.n_sub())
                .map(|sub| IvId { group: node, sub })
                .filter(|iv| !know[node].knows_iv(*iv))
                .collect()
        })
        .collect();
    DecodeReport { missing, passes }
}

/// Verify `plan` and return its [`DecodeSchedule`]; typed error when some
/// node would end the Shuffle phase missing IVs.
pub fn schedule(alloc: &Allocation, plan: &ShufflePlan) -> Result<DecodeSchedule> {
    let (know, order, passes) = simulate(alloc, plan);
    for (node, knowledge) in know.iter().enumerate() {
        let missing = (0..alloc.n_sub())
            .filter(|&sub| !knowledge.knows_iv(IvId { group: node, sub }))
            .count();
        if missing > 0 {
            return Err(HetcdcError::Undecodable { node, missing });
        }
    }
    Ok(DecodeSchedule { order, passes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::plan::{plan_greedy, plan_k3, plan_uncoded, Part};
    use crate::placement::k3::optimal_allocation;
    use crate::prop;
    use crate::theory::params::Params3;

    #[test]
    fn k3_optimal_plans_decode_on_paper_example() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        for plan in [plan_k3(&alloc), plan_greedy(&alloc), plan_uncoded(&alloc)] {
            let report = verify(&alloc, &plan);
            assert!(report.is_complete(), "missing: {:?}", report.missing);
        }
    }

    #[test]
    fn detects_incomplete_plan() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let mut plan = plan_k3(&alloc);
        plan.pop_broadcast(); // drop one message
        let report = verify(&alloc, &plan);
        assert!(!report.is_complete());
    }

    #[test]
    fn detects_undecodable_xor() {
        // XOR of two IVs that no receiver can cancel.
        let alloc = Allocation::new(3, 1, vec![0b001, 0b001, 0b010]);
        let plan = ShufflePlan::from_broadcasts(
            3,
            vec![Broadcast::Coded {
                sender: 0,
                parts: vec![
                    Part::whole(IvId { group: 1, sub: 0 }),
                    Part::whole(IvId { group: 2, sub: 1 }),
                ],
            }],
        );
        let report = verify(&alloc, &plan);
        // Nodes 1 and 2 know neither part; nothing decodes.
        assert!(!report.is_complete());
    }

    #[test]
    fn schedule_orders_every_learned_broadcast() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let plan = plan_k3(&alloc);
        let sched = schedule(&alloc, &plan).unwrap();
        assert_eq!(sched.order.len(), 3);
        // Each node's order lists distinct broadcast indices.
        for order in &sched.order {
            let mut seen = std::collections::HashSet::new();
            for &bi in order {
                assert!(bi < plan.n_broadcasts());
                assert!(seen.insert(bi), "broadcast {bi} scheduled twice");
            }
        }
        // Every broadcast is learned from by at least one node.
        let all: std::collections::HashSet<usize> =
            sched.order.iter().flatten().copied().collect();
        assert_eq!(all.len(), plan.n_broadcasts());
    }

    #[test]
    fn schedule_rejects_incomplete_plan() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let mut plan = plan_k3(&alloc);
        plan.pop_broadcast();
        let err = schedule(&alloc, &plan).unwrap_err();
        assert!(matches!(err, HetcdcError::Undecodable { .. }));
    }

    #[test]
    fn prop_all_k3_plans_decode_on_all_params() {
        prop::run("k3 plans decode everywhere", 250, |g| {
            let n = g.u64_in(1..=20);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(p) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let alloc = optimal_allocation(&p);
            let plan = plan_k3(&alloc);
            let report = verify(&alloc, &plan);
            prop::check(
                report.is_complete(),
                format!("{p}: missing {:?}", report.missing),
            )
        });
    }

    #[test]
    fn prop_greedy_decodes_on_random_allocations_any_k() {
        prop::run("greedy decodes", 200, |g| {
            let k = g.usize_in(2..=5);
            let n_sub = g.usize_in(1..=25);
            let full = (1u64 << k) - 1;
            let holders: Vec<u32> =
                (0..n_sub).map(|_| g.u64_in(1..=full) as u32).collect();
            let alloc = Allocation::new(k, 1, holders);
            let plan = plan_greedy(&alloc);
            let report = verify(&alloc, &plan);
            prop::check(
                report.is_complete(),
                format!("k={k} n_sub={n_sub}: missing {:?}", report.missing),
            )
        });
    }
}
