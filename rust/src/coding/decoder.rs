//! Symbolic shuffle decoder: proves a [`ShufflePlan`] is decodable.
//!
//! Simulates the Reduce-phase knowledge of every node: a node knows every
//! IV of every subfile it holds (Map phase), plus whatever it can decode
//! from the broadcast sequence. A coded broadcast is decodable by a node
//! when at most one of its parts is unknown to that node; decoding learns
//! that part. The §II Reduce requirement then demands node `n` know
//! `(n, f)` for every subfile `f`.
//!
//! The decoder works over the plan's **flattened** broadcast order
//! (round-major, group-major — see [`ShufflePlan::iter_broadcasts`]);
//! every index in a [`DecodeSchedule`] refers to that order, which is
//! also the executor's transmission order, so round structure never
//! changes what a schedule index means.
//!
//! ## Worklist propagation (not a rescan fixpoint)
//!
//! Decoding is simulated by **indexed worklist propagation**, not by
//! rescanning the broadcast list to a fixpoint. One inverted index maps
//! every `(iv, seg, nseg)` part to the broadcasts containing it; each
//! node keeps a per-broadcast unknown-part counter, and a queue of
//! broadcasts whose counter has dropped to one. Learning a part walks
//! only the broadcasts that contain that IV, so the whole simulation is
//! one `O(K · Σ|parts|)` sweep plus `O(learns · log B)` queue traffic —
//! the legacy algorithm rescanned all `B` broadcasts per pass for up to
//! `B` passes (`O(K · B²)` on deep XOR dependency chains) and bailed out
//! on a pass cap rather than true quiescence.
//!
//! A node's knowledge evolves independently of every other node's (a
//! broadcast's decodability for node `n` reads only node `n`'s
//! knowledge), so the decode [`DecodeSchedule`] order of the legacy
//! pass-scan is reproduced *exactly*: within a pass, ready broadcasts
//! are processed in ascending index; a broadcast unlocked at an index
//! **ahead** of the cursor joins the current pass, one **behind** it
//! waits for the next pass — precisely when the rescan would have
//! reached it. The legacy fixpoint survives only as a `#[cfg(test)]`
//! oracle; a sweep over every placer × coder pair asserts bit-equal
//! schedules. Node independence also makes the simulation shardable
//! across worker threads ([`schedule_threaded`]) with identical output.

use super::plan::{Broadcast, IvId, ShufflePlan};
use crate::error::{HetcdcError, Result};
use crate::placement::alloc::Allocation;
use std::collections::{BTreeSet, HashMap};

/// Per-node knowledge of IV segments: `(iv) -> (nseg, bitmask of known
/// segments)`. A fully-known IV is `(1, 0b1)` or all-`nseg` bits.
#[derive(Clone, Debug, Default)]
pub struct Knowledge {
    segs: HashMap<IvId, (u32, u64)>,
    /// Subfiles held (full IVs for every group).
    holds: Vec<bool>,
}

impl Knowledge {
    fn new(n_sub: usize) -> Self {
        Self {
            segs: HashMap::new(),
            holds: vec![false; n_sub],
        }
    }

    fn knows_part(&self, iv: IvId, seg: u32, nseg: u32) -> bool {
        if self.holds[iv.sub] {
            return true;
        }
        match self.segs.get(&iv) {
            Some((n, mask)) => {
                if *n == nseg {
                    mask & (1 << seg) != 0
                } else {
                    // Whole-IV knowledge recorded with nseg=1 covers all.
                    *n == 1 && mask & 1 != 0
                }
            }
            None => false,
        }
    }

    fn learn_part(&mut self, iv: IvId, seg: u32, nseg: u32) {
        let entry = self.segs.entry(iv).or_insert((nseg, 0));
        if entry.0 != nseg {
            // Mixed granularities: only upgrade to whole-IV knowledge.
            if nseg == 1 {
                *entry = (1, 1);
            }
            return;
        }
        entry.1 |= 1 << seg;
    }

    /// Knows the complete IV payload?
    pub fn knows_iv(&self, iv: IvId) -> bool {
        if self.holds[iv.sub] {
            return true;
        }
        match self.segs.get(&iv) {
            Some((nseg, mask)) => {
                let full = if *nseg >= 64 { u64::MAX } else { (1u64 << nseg) - 1 };
                *mask & full == full
            }
            None => false,
        }
    }
}

/// Outcome of symbolic decoding.
#[derive(Clone, Debug)]
pub struct DecodeReport {
    /// Per-node: list of missing IVs (empty everywhere iff plan is valid).
    pub missing: Vec<Vec<IvId>>,
    /// Propagation waves needed (the legacy decoder's pass count: last
    /// wave in which any node learned, plus the final quiescent check).
    pub passes: usize,
}

impl DecodeReport {
    pub fn is_complete(&self) -> bool {
        self.missing.iter().all(|m| m.is_empty())
    }
}

/// Deterministic per-node decode order for a verified plan: entry
/// `order[node]` lists broadcast indices in an order such that each one is
/// decodable given Map-phase knowledge plus all earlier entries. Baked
/// into [`crate::engine::Plan`] artifacts so execution replays decoding
/// without any fixpoint iteration or re-verification.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DecodeSchedule {
    pub order: Vec<Vec<usize>>,
    /// Propagation waves the symbolic decoder needed (see
    /// [`DecodeReport::passes`]).
    pub passes: usize,
}

/// One part occurrence inside the flattened broadcast list.
struct Occ {
    /// Flattened broadcast index containing this part.
    bi: u32,
    iv: IvId,
    seg: u32,
    nseg: u32,
}

/// The shared (node-independent) decode index: every part occurrence in
/// flat order, the per-broadcast occurrence ranges, and the inverted
/// IV → occurrences map. Built once per simulation, read by every node.
/// `pub(crate)` so the runtime erasure path ([`runtime_recovery`]) can
/// reuse the same index instead of rebuilding its own inverted map.
pub(crate) struct DecodeIndex {
    occs: Vec<Occ>,
    /// `part_start[bi]..part_start[bi + 1]` = occurrence ids of broadcast
    /// `bi` (length `n_broadcasts + 1`).
    part_start: Vec<usize>,
    /// IV -> occurrence ids (all granularities — learning whole-IV
    /// knowledge can satisfy segment parts of the same IV).
    by_iv: HashMap<IvId, Vec<u32>>,
}

impl DecodeIndex {
    pub(crate) fn build(plan: &ShufflePlan) -> Self {
        let mut occs: Vec<Occ> = Vec::new();
        let mut part_start = Vec::with_capacity(plan.n_broadcasts() + 1);
        for (bi, b) in plan.iter_broadcasts().enumerate() {
            part_start.push(occs.len());
            match b {
                Broadcast::Uncoded { iv, .. } => {
                    occs.push(Occ { bi: bi as u32, iv: *iv, seg: 0, nseg: 1 });
                }
                Broadcast::Coded { parts, .. } => {
                    for p in parts {
                        occs.push(Occ { bi: bi as u32, iv: p.iv, seg: p.seg, nseg: p.nseg });
                    }
                }
            }
        }
        part_start.push(occs.len());
        let mut by_iv: HashMap<IvId, Vec<u32>> = HashMap::new();
        for (oi, o) in occs.iter().enumerate() {
            by_iv.entry(o.iv).or_default().push(oi as u32);
        }
        DecodeIndex { occs, part_start, by_iv }
    }

    fn n_broadcasts(&self) -> usize {
        self.part_start.len() - 1
    }
}

/// Worklist simulation of one node: returns its decode order and the
/// number of propagation waves it used (0 if it learns nothing).
///
/// The wave structure reproduces the legacy pass-scan order exactly: the
/// ready set is processed in ascending broadcast index; a broadcast whose
/// unknown count drops to one at an index **after** the current cursor is
/// decoded within the same wave, one **at or before** the cursor waits
/// for the next wave — when a rescan of the list would first revisit it.
/// Every (node, broadcast) pair decodes at most once (`done`), so the
/// simulation reaches true quiescence even on adversarial plans where a
/// mixed-granularity learn cannot advance knowledge (the legacy rescan
/// re-queued such broadcasts every pass until its pass cap tripped).
///
/// `erased[bi] == true` marks a broadcast the node never received (the
/// runtime erasure model): it is pre-marked `done`, so it neither decodes
/// nor teaches anything, but survivors still propagate through every
/// dependency edge. An empty slice means nothing was erased.
fn run_node(know: &mut Knowledge, index: &DecodeIndex, erased: &[bool]) -> (Vec<usize>, usize) {
    let nb = index.n_broadcasts();
    let mut known = vec![false; index.occs.len()];
    let mut unknown = vec![0u32; nb];
    for (oi, o) in index.occs.iter().enumerate() {
        if know.knows_part(o.iv, o.seg, o.nseg) {
            known[oi] = true;
        } else {
            unknown[o.bi as usize] += 1;
        }
    }
    let mut done = vec![false; nb];
    for (bi, d) in done.iter_mut().enumerate() {
        if erased.get(bi).copied().unwrap_or(false) {
            *d = true;
        }
    }
    let mut ready_now: BTreeSet<usize> = unknown
        .iter()
        .enumerate()
        .filter(|&(bi, &u)| u == 1 && !done[bi])
        .map(|(bi, _)| bi)
        .collect();
    let mut ready_next: BTreeSet<usize> = BTreeSet::new();
    let mut order = Vec::new();
    let mut waves = 0usize;
    while !ready_now.is_empty() {
        let mut learned_this_wave = false;
        while let Some(bi) = ready_now.pop_first() {
            if unknown[bi] != 1 || done[bi] {
                // Stale entry: an earlier decode made this broadcast's
                // last unknown part known while it sat in the queue (the
                // rescan saw zero unknowns at this index and decoded
                // nothing). A wave draining only stale entries learns
                // nothing, queues nothing, and is therefore terminal.
                continue;
            }
            done[bi] = true;
            learned_this_wave = true;
            let oi = (index.part_start[bi]..index.part_start[bi + 1])
                .find(|&oi| !known[oi])
                .expect("ready broadcast has exactly one unknown part");
            let learned_iv = index.occs[oi].iv;
            know.learn_part(learned_iv, index.occs[oi].seg, index.occs[oi].nseg);
            order.push(bi);
            // Propagate: every occurrence of this IV that just became
            // known decrements its broadcast's unknown counter.
            for &oj in &index.by_iv[&learned_iv] {
                let oj = oj as usize;
                if known[oj] {
                    continue;
                }
                let o = &index.occs[oj];
                if !know.knows_part(o.iv, o.seg, o.nseg) {
                    continue;
                }
                known[oj] = true;
                let target = o.bi as usize;
                unknown[target] -= 1;
                if unknown[target] == 1 && !done[target] {
                    if target > bi {
                        ready_now.insert(target);
                    } else {
                        ready_next.insert(target);
                    }
                }
            }
        }
        if learned_this_wave {
            waves += 1;
        }
        std::mem::swap(&mut ready_now, &mut ready_next);
    }
    (order, waves)
}

/// Map-phase knowledge of one node.
pub(crate) fn node_knowledge(alloc: &Allocation, node: usize) -> Knowledge {
    let mut know = Knowledge::new(alloc.n_sub());
    for (sub, &h) in alloc.holders.iter().enumerate() {
        if h & (1 << node) != 0 {
            know.holds[sub] = true;
        }
    }
    know
}

/// Map-phase knowledge of every node (legacy-oracle setup).
#[cfg(test)]
fn initial_knowledge(alloc: &Allocation) -> Vec<Knowledge> {
    (0..alloc.k).map(|node| node_knowledge(alloc, node)).collect()
}

/// Shared symbolic simulation: final knowledge, per-node learn order, and
/// wave count. Senders never "learn" from their own broadcasts (they hold
/// every part they transmit, so their unknown counters start at zero).
/// `threads > 1` shards nodes across scoped worker threads
/// ([`crate::util::shard::shard_indexed`]) — output is identical for
/// every thread count because nodes are independent.
fn simulate(
    alloc: &Allocation,
    plan: &ShufflePlan,
    threads: usize,
) -> (Vec<Knowledge>, Vec<Vec<usize>>, usize) {
    let k = alloc.k;
    let index = DecodeIndex::build(plan);
    let index = &index;
    let per_node: Vec<(Knowledge, Vec<usize>, usize)> =
        crate::util::shard::shard_indexed(k, threads, |range| {
            range
                .map(|node| {
                    let mut know = node_knowledge(alloc, node);
                    let (order, waves) = run_node(&mut know, index, &[]);
                    (know, order, waves)
                })
                .collect()
        });
    let mut know = Vec::with_capacity(k);
    let mut order = Vec::with_capacity(k);
    // Legacy-compatible pass count: the last wave in which any node
    // learned, plus the final pass that observed quiescence.
    let mut passes = 1usize;
    for (kn, ord, waves) in per_node {
        passes = passes.max(1 + waves);
        know.push(kn);
        order.push(ord);
    }
    (know, order, passes)
}

/// Simulate decoding of `plan` under `alloc`; check Reduce completeness.
pub fn verify(alloc: &Allocation, plan: &ShufflePlan) -> DecodeReport {
    let (know, _, passes) = simulate(alloc, plan, 1);
    // Reduce requirement: node n needs (n, f) for every subfile f.
    let missing = (0..alloc.k)
        .map(|node| {
            (0..alloc.n_sub())
                .map(|sub| IvId { group: node, sub })
                .filter(|iv| !know[node].knows_iv(*iv))
                .collect()
        })
        .collect();
    DecodeReport { missing, passes }
}

/// Runtime-recovery worklist result for one erasure pattern: per-node
/// decode orders over the surviving broadcasts, plus the IVs the
/// erasures strand.
#[derive(Clone, Debug)]
pub(crate) struct RuntimeRecovery {
    /// Per-node decode order over the survivors — same flat index space
    /// as [`DecodeSchedule::order`]; erased indices never appear. With no
    /// erasures this is bit-equal to the baked schedule.
    pub orders: Vec<Vec<usize>>,
    /// `(node, iv)` pairs stranded by the erasures: complete in the
    /// fault-free propagation, incomplete over the survivors (losses
    /// exceeded the plan's repair tolerance for that node). Ordered by
    /// node ascending, then `(group, sub)` — the deterministic
    /// retransmission order the executor replays.
    pub stranded: Vec<(usize, IvId)>,
}

/// Rerun the worklist decoder over the broadcasts that survived an
/// erasure pattern (`erased[bi]` = flat index `bi` was lost in transit).
/// Diffing each node's final knowledge against its fault-free propagation
/// names exactly the IVs retransmission must restore: resending those —
/// and nothing else — makes the full-IV state of every node bit-equal to
/// the fault-free run, which is the runtime half of the recovery
/// contract ([`verify_loss_patterns`] is the build-time half).
pub(crate) fn runtime_recovery(
    alloc: &Allocation,
    plan: &ShufflePlan,
    erased: &[bool],
) -> RuntimeRecovery {
    let index = DecodeIndex::build(plan);
    let k = alloc.k;
    let mut orders = Vec::with_capacity(k);
    let mut stranded = Vec::new();
    for node in 0..k {
        let mut full = node_knowledge(alloc, node);
        run_node(&mut full, &index, &[]);
        let mut know = node_knowledge(alloc, node);
        let (order, _) = run_node(&mut know, &index, erased);
        for group in 0..k {
            for sub in 0..alloc.n_sub() {
                let iv = IvId { group, sub };
                if full.knows_iv(iv) && !know.knows_iv(iv) {
                    stranded.push((node, iv));
                }
            }
        }
        orders.push(order);
    }
    RuntimeRecovery { orders, stranded }
}

/// Degraded-decode gate: prove `plan` recovers every IV under **every**
/// loss pattern of up to `f` broadcasts. Enumerates all single losses
/// (`f >= 1`) and all unordered pairs (`f >= 2`) over the flattened
/// order, re-running [`verify`] on each pruned plan
/// ([`ShufflePlan::without_broadcast`]); the typed error names the first
/// failing pattern. `f` is capped at
/// [`crate::net::faults::MAX_REPAIR_F`] — the enumeration is
/// combinatorial in `f`.
pub fn verify_loss_patterns(alloc: &Allocation, plan: &ShufflePlan, f: usize) -> Result<()> {
    if f > crate::net::faults::MAX_REPAIR_F {
        return Err(HetcdcError::InvalidParams(format!(
            "loss-pattern verification supports f <= {}, got {f}",
            crate::net::faults::MAX_REPAIR_F
        )));
    }
    let check = |pruned: &ShufflePlan, lost: &[usize]| -> Result<()> {
        let report = verify(alloc, pruned);
        if report.is_complete() {
            return Ok(());
        }
        let node = report
            .missing
            .iter()
            .position(|m| !m.is_empty())
            .expect("incomplete report has a missing node");
        Err(HetcdcError::PlanMismatch(format!(
            "degraded decode: losing broadcast(s) {lost:?} leaves node {node} missing \
             {} IVs — the plan does not tolerate f={f} losses",
            report.missing[node].len()
        )))
    };
    let nb = plan.n_broadcasts();
    if f >= 1 {
        for i in 0..nb {
            check(&plan.without_broadcast(i), &[i])?;
        }
    }
    if f >= 2 {
        for j in 1..nb {
            // Remove the higher index first so `i < j` stays valid in
            // the already-pruned plan.
            let minus_j = plan.without_broadcast(j);
            for i in 0..j {
                check(&minus_j.without_broadcast(i), &[i, j])?;
            }
        }
    }
    Ok(())
}

/// Verify `plan` and return its [`DecodeSchedule`]; typed error when some
/// node would end the Shuffle phase missing IVs.
pub fn schedule(alloc: &Allocation, plan: &ShufflePlan) -> Result<DecodeSchedule> {
    schedule_threaded(alloc, plan, 1)
}

/// [`schedule`] with the per-node simulation sharded across `threads`
/// scoped workers (`<= 1` = serial). The schedule is **identical** for
/// every thread count: nodes decode independently, so sharding changes
/// wall-clock only — this is the plan-build half of the determinism
/// contract `hetcdc plan --threads N` relies on.
pub fn schedule_threaded(
    alloc: &Allocation,
    plan: &ShufflePlan,
    threads: usize,
) -> Result<DecodeSchedule> {
    let (know, order, passes) = simulate(alloc, plan, threads);
    for (node, knowledge) in know.iter().enumerate() {
        let missing = (0..alloc.n_sub())
            .filter(|&sub| !knowledge.knows_iv(IvId { group: node, sub }))
            .count();
        if missing > 0 {
            return Err(HetcdcError::Undecodable { node, missing });
        }
    }
    Ok(DecodeSchedule { order, passes })
}

/// The legacy rescan-to-fixpoint simulation, kept verbatim as the test
/// oracle for the worklist rewrite. Rescans every broadcast each pass and
/// stops on no-progress **or** on the `passes > B + 2` cap — the cap that
/// could truncate adversarial plans mid-propagation (and emit duplicate
/// order entries for broadcasts whose mixed-granularity learn is a
/// no-op). Production code never calls this.
#[cfg(test)]
fn simulate_fixpoint(
    alloc: &Allocation,
    plan: &ShufflePlan,
) -> (Vec<Knowledge>, Vec<Vec<usize>>, usize) {
    let k = alloc.k;
    let mut know = initial_knowledge(alloc);
    let flat: Vec<&Broadcast> = plan.iter_broadcasts().collect();
    let mut order: Vec<Vec<usize>> = vec![Vec::new(); k];
    let mut passes = 0;
    loop {
        passes += 1;
        let mut progress = false;
        for (bi, b) in flat.iter().enumerate() {
            match b {
                Broadcast::Uncoded { iv, .. } => {
                    for (node, knowledge) in know.iter_mut().enumerate() {
                        if !knowledge.knows_part(*iv, 0, 1) {
                            knowledge.learn_part(*iv, 0, 1);
                            order[node].push(bi);
                            progress = true;
                        }
                    }
                }
                Broadcast::Coded { parts, .. } => {
                    for (node, knowledge) in know.iter_mut().enumerate() {
                        let unknown: Vec<_> = parts
                            .iter()
                            .filter(|p| !knowledge.knows_part(p.iv, p.seg, p.nseg))
                            .collect();
                        if unknown.len() == 1 {
                            let p = unknown[0];
                            knowledge.learn_part(p.iv, p.seg, p.nseg);
                            order[node].push(bi);
                            progress = true;
                        }
                    }
                }
            }
        }
        if !progress || passes > flat.len() + 2 {
            break;
        }
    }
    (know, order, passes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::coder::builtin_coders;
    use crate::coding::plan::{plan_greedy, plan_k3, plan_uncoded, Part};
    use crate::model::cluster::ClusterSpec;
    use crate::model::job::JobSpec;
    use crate::placement::combinatorial::{choose_grid, grid_allocation};
    use crate::placement::k3::optimal_allocation;
    use crate::placement::placer::builtin_placers;
    use crate::prop;
    use crate::theory::params::Params3;

    /// Oracle comparison: worklist simulate == legacy fixpoint simulate,
    /// field by field (order, passes, and final Reduce completeness).
    fn assert_matches_oracle(alloc: &Allocation, plan: &ShufflePlan, ctx: &str) {
        let (know_new, order_new, passes_new) = simulate(alloc, plan, 1);
        let (know_old, order_old, passes_old) = simulate_fixpoint(alloc, plan);
        assert_eq!(order_new, order_old, "{ctx}: decode order diverged");
        assert_eq!(passes_new, passes_old, "{ctx}: pass count diverged");
        for node in 0..alloc.k {
            for sub in 0..alloc.n_sub() {
                for group in 0..alloc.k {
                    let iv = IvId { group, sub };
                    assert_eq!(
                        know_new[node].knows_iv(iv),
                        know_old[node].knows_iv(iv),
                        "{ctx}: node {node} {iv:?} knowledge diverged"
                    );
                }
            }
        }
        // Threaded sharding must not change a single schedule entry.
        for threads in [2usize, 8] {
            let (_, order_t, passes_t) = simulate(alloc, plan, threads);
            assert_eq!(order_t, order_new, "{ctx}: threads={threads} order");
            assert_eq!(passes_t, passes_new, "{ctx}: threads={threads} passes");
        }
    }

    fn cluster(storage: &[u64]) -> ClusterSpec {
        let mut c = ClusterSpec::homogeneous(storage.len(), 1, 1000.0);
        for (node, &m) in c.nodes.iter_mut().zip(storage) {
            node.storage = m;
        }
        c
    }

    #[test]
    fn worklist_matches_fixpoint_oracle_for_every_placer_coder_k3_to_6() {
        // The acceptance gate of the worklist rewrite: bit-equal decode
        // schedules on every placer × coder pair that serves K = 3..6.
        let shapes: Vec<(Vec<u64>, u64)> = vec![
            (vec![6, 7, 7], 12),
            (vec![3, 4, 5, 6], 8),
            (vec![3, 4, 5, 6, 7], 10),
            (vec![2, 3, 3, 4, 4, 5], 8),
        ];
        let mut checked = 0usize;
        for (storage, n) in shapes {
            let cl = cluster(&storage);
            let job = JobSpec::terasort(n);
            for placer in builtin_placers() {
                let Ok(alloc) = placer.place(&cl, &job) else {
                    continue; // shape not served (e.g. K=3-only)
                };
                for coder in builtin_coders() {
                    let Ok(plan) = coder.plan(&cl, &job, &alloc) else {
                        continue; // coder rejects this allocation
                    };
                    let ctx = format!(
                        "K={} {} x {}",
                        cl.k(),
                        placer.name(),
                        coder.name()
                    );
                    assert_matches_oracle(&alloc, &plan, &ctx);
                    checked += 1;
                }
                let plan = plan_uncoded(&alloc);
                assert_matches_oracle(
                    &alloc,
                    &plan,
                    &format!("K={} {} x uncoded", cl.k(), placer.name()),
                );
                checked += 1;
            }
        }
        assert!(checked >= 20, "sweep too small: only {checked} combos ran");
    }

    #[test]
    fn worklist_matches_fixpoint_oracle_on_combinatorial_grids() {
        // The large-K sweep: grid allocations at K ∈ {4, 6, 8, 12, 16}
        // under the combinatorial coder and the generic pair coders.
        let grids: Vec<(usize, u64, u64)> = vec![
            (4, 8, 4),
            (6, 8, 4),
            (8, 8, 4),
            (12, 12, 4),
            (16, 16, 8),
        ];
        for (k, n, m_min) in grids {
            let g = choose_grid(k, n, m_min).unwrap();
            let alloc = grid_allocation(k, n, &g);
            let cl = cluster(&vec![m_min; k]);
            let job = JobSpec::terasort(n);
            for coder in builtin_coders() {
                let Ok(plan) = coder.plan(&cl, &job, &alloc) else {
                    continue;
                };
                assert_matches_oracle(
                    &alloc,
                    &plan,
                    &format!("grid K={k} x {}", coder.name()),
                );
            }
            assert_matches_oracle(
                &alloc,
                &plan_uncoded(&alloc),
                &format!("grid K={k} x uncoded"),
            );
        }
    }

    #[test]
    fn long_xor_chain_unlocks_sequentially_and_matches_oracle() {
        // B broadcasts whose decode order is forced to B sequential
        // unlocks: the chain is laid out in *reverse* flat order, so each
        // wave can decode exactly one broadcast (the legacy rescan burned
        // a full O(B) pass per unlock — O(B²) total; the worklist walks
        // each dependency edge once). v_0 arrives uncoded at the END of
        // the list; broadcast B−2−i is v_{i+1} ⊕ v_i.
        const B: usize = 40;
        let alloc = Allocation::new(2, 1, vec![0b01; B]);
        let iv = |sub: usize| IvId { group: 1, sub };
        let mut broadcasts = Vec::with_capacity(B);
        for i in 0..B - 1 {
            broadcasts.push(Broadcast::Coded {
                sender: 0,
                parts: vec![Part::whole(iv(B - 1 - i)), Part::whole(iv(B - 2 - i))],
            });
        }
        broadcasts.push(Broadcast::Uncoded { sender: 0, iv: iv(0) });
        let plan = ShufflePlan::from_broadcasts(2, broadcasts);

        assert_matches_oracle(&alloc, &plan, "reverse XOR chain");
        let sched = schedule(&alloc, &plan).unwrap();
        // Node 1 decodes strictly back-to-front: B−1 (uncoded v_0), then
        // B−2 (unlocks v_1), …, then 0 — one unlock per wave.
        let expected: Vec<usize> = (0..B).rev().collect();
        assert_eq!(sched.order[1], expected);
        assert!(sched.order[0].is_empty(), "the sender holds everything");
        // One wave per unlock plus the final quiescent pass.
        assert_eq!(sched.passes, B + 1);
    }

    #[test]
    fn worklist_quiesces_where_the_fixpoint_cap_emitted_duplicates() {
        // Adversarial mixed-granularity plan: node 1 first learns segment
        // (0, nseg=2) of an IV; a later broadcast carries segment
        // (1, nseg=4) of the SAME IV. `Knowledge::learn_part` cannot
        // record the mismatched granularity, so the legacy rescan saw an
        // eternally-decodable broadcast: it re-queued it every pass,
        // emitting duplicate schedule entries until the `passes > B + 2`
        // cap truncated the loop — the silent hazard this PR removes. The
        // worklist decodes each (node, broadcast) pair at most once and
        // reaches true quiescence.
        let alloc = Allocation::new(2, 1, vec![0b01, 0b01]);
        let iv = IvId { group: 1, sub: 0 };
        let plan = ShufflePlan::from_broadcasts(
            2,
            vec![
                Broadcast::Coded {
                    sender: 0,
                    parts: vec![Part { iv, seg: 0, nseg: 2 }],
                },
                Broadcast::Coded {
                    sender: 0,
                    parts: vec![Part { iv, seg: 1, nseg: 4 }],
                },
            ],
        );

        // Legacy behavior (oracle): duplicate entries, cap-bounded exit.
        let (_, order_old, passes_old) = simulate_fixpoint(&alloc, &plan);
        assert!(
            order_old[1].len() > 2,
            "oracle was expected to loop on the no-op learn (got {:?})",
            order_old[1]
        );
        assert_eq!(passes_old, plan.n_broadcasts() + 3, "oracle exits on the cap");

        // Worklist: every broadcast decoded at most once, true quiescence.
        let (_, order_new, passes_new) = simulate(&alloc, &plan, 1);
        assert_eq!(order_new[1], vec![0, 1]);
        let distinct: std::collections::HashSet<_> = order_new[1].iter().collect();
        assert_eq!(distinct.len(), order_new[1].len(), "no duplicate entries");
        assert!(passes_new <= 2, "quiescence, not a cap ({passes_new} passes)");
        // Either way the plan is genuinely incomplete for node 1.
        assert!(!verify(&alloc, &plan).is_complete());
    }

    #[test]
    fn loss_patterns_verify_on_repaired_plans_and_fail_on_bare_ones() {
        use crate::coding::plan::with_repair_rounds;
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        for base in [plan_k3(&alloc), plan_greedy(&alloc), plan_uncoded(&alloc)] {
            // f=0 is vacuous everywhere.
            assert!(verify_loss_patterns(&alloc, &base, 0).is_ok());
            // Bare plans have critical broadcasts: some single loss fails.
            assert!(matches!(
                verify_loss_patterns(&alloc, &base, 1),
                Err(HetcdcError::PlanMismatch(_))
            ));
            // Repaired at f=1: every single loss recovers.
            let r1 = with_repair_rounds(&base, &alloc, 1).unwrap();
            assert!(verify_loss_patterns(&alloc, &r1, 1).is_ok());
            // ...but a single repair round need not survive pair losses.
            // Repaired at f=2: every pair loss recovers.
            let r2 = with_repair_rounds(&base, &alloc, 2).unwrap();
            assert!(verify_loss_patterns(&alloc, &r2, 2).is_ok());
        }
        // f beyond the supported maximum is a typed error, not a hang.
        let plan = plan_uncoded(&alloc);
        assert!(matches!(
            verify_loss_patterns(&alloc, &plan, crate::net::faults::MAX_REPAIR_F + 1),
            Err(HetcdcError::InvalidParams(_))
        ));
    }

    #[test]
    fn runtime_recovery_mirrors_schedule_and_strands_only_above_tolerance() {
        use crate::coding::plan::with_repair_rounds;
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let base = plan_k3(&alloc);

        // No erasures: orders bit-equal the baked schedule, nothing
        // stranded.
        let clean = runtime_recovery(&alloc, &base, &[]);
        assert_eq!(clean.orders, schedule(&alloc, &base).unwrap().order);
        assert!(clean.stranded.is_empty());

        // The bare plan has critical broadcasts: some single erasure
        // strands an IV, and the erased index never appears in an order.
        let nb = base.n_broadcasts();
        let mut any_stranded = false;
        for bi in 0..nb {
            let mut erased = vec![false; nb];
            erased[bi] = true;
            let rec = runtime_recovery(&alloc, &base, &erased);
            assert!(rec.orders.iter().all(|o| !o.contains(&bi)));
            // Stranded pairs are sorted: node asc, then (group, sub).
            let keys: Vec<_> = rec
                .stranded
                .iter()
                .map(|(n, iv)| (*n, iv.group, iv.sub))
                .collect();
            assert!(keys.windows(2).all(|w| w[0] < w[1]));
            any_stranded |= !rec.stranded.is_empty();
        }
        assert!(any_stranded, "bare plan tolerated every single loss");

        // Repaired at f=1 every single erasure decodes without stranding
        // — the runtime mirror of verify_loss_patterns.
        let r1 = with_repair_rounds(&base, &alloc, 1).unwrap();
        for bi in 0..r1.n_broadcasts() {
            let mut erased = vec![false; r1.n_broadcasts()];
            erased[bi] = true;
            let rec = runtime_recovery(&alloc, &r1, &erased);
            assert!(rec.stranded.is_empty(), "erasing {bi} stranded IVs at f=1");
        }
    }

    #[test]
    fn k3_optimal_plans_decode_on_paper_example() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        for plan in [plan_k3(&alloc), plan_greedy(&alloc), plan_uncoded(&alloc)] {
            let report = verify(&alloc, &plan);
            assert!(report.is_complete(), "missing: {:?}", report.missing);
        }
    }

    #[test]
    fn detects_incomplete_plan() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let mut plan = plan_k3(&alloc);
        plan.pop_broadcast(); // drop one message
        let report = verify(&alloc, &plan);
        assert!(!report.is_complete());
    }

    #[test]
    fn detects_undecodable_xor() {
        // XOR of two IVs that no receiver can cancel.
        let alloc = Allocation::new(3, 1, vec![0b001, 0b001, 0b010]);
        let plan = ShufflePlan::from_broadcasts(
            3,
            vec![Broadcast::Coded {
                sender: 0,
                parts: vec![
                    Part::whole(IvId { group: 1, sub: 0 }),
                    Part::whole(IvId { group: 2, sub: 1 }),
                ],
            }],
        );
        let report = verify(&alloc, &plan);
        // Nodes 1 and 2 know neither part; nothing decodes.
        assert!(!report.is_complete());
    }

    #[test]
    fn schedule_orders_every_learned_broadcast() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let plan = plan_k3(&alloc);
        let sched = schedule(&alloc, &plan).unwrap();
        assert_eq!(sched.order.len(), 3);
        // Each node's order lists distinct broadcast indices.
        for order in &sched.order {
            let mut seen = std::collections::HashSet::new();
            for &bi in order {
                assert!(bi < plan.n_broadcasts());
                assert!(seen.insert(bi), "broadcast {bi} scheduled twice");
            }
        }
        // Every broadcast is learned from by at least one node.
        let all: std::collections::HashSet<usize> =
            sched.order.iter().flatten().copied().collect();
        assert_eq!(all.len(), plan.n_broadcasts());
    }

    #[test]
    fn schedule_rejects_incomplete_plan() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        let alloc = optimal_allocation(&p);
        let mut plan = plan_k3(&alloc);
        plan.pop_broadcast();
        let err = schedule(&alloc, &plan).unwrap_err();
        assert!(matches!(err, HetcdcError::Undecodable { .. }));
    }

    #[test]
    fn prop_all_k3_plans_decode_on_all_params() {
        prop::run("k3 plans decode everywhere", 250, |g| {
            let n = g.u64_in(1..=20);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(p) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let alloc = optimal_allocation(&p);
            let plan = plan_k3(&alloc);
            let report = verify(&alloc, &plan);
            prop::check(
                report.is_complete(),
                format!("{p}: missing {:?}", report.missing),
            )
        });
    }

    #[test]
    fn prop_greedy_decodes_on_random_allocations_any_k() {
        prop::run("greedy decodes", 200, |g| {
            let k = g.usize_in(2..=5);
            let n_sub = g.usize_in(1..=25);
            let full = (1u64 << k) - 1;
            let holders: Vec<u32> =
                (0..n_sub).map(|_| g.u64_in(1..=full) as u32).collect();
            let alloc = Allocation::new(k, 1, holders);
            let plan = plan_greedy(&alloc);
            let report = verify(&alloc, &plan);
            prop::check(
                report.is_complete(),
                format!("k={k} n_sub={n_sub}: missing {:?}", report.missing),
            )
        });
    }

    #[test]
    fn prop_worklist_matches_oracle_on_random_allocations() {
        // Randomized cross-check on arbitrary (non-designed) allocations:
        // the greedy coder serves anything, so this explores schedule
        // shapes none of the curated designs produce.
        prop::run("worklist == fixpoint oracle", 120, |g| {
            let k = g.usize_in(2..=5);
            let n_sub = g.usize_in(1..=20);
            let full = (1u64 << k) - 1;
            let holders: Vec<u32> =
                (0..n_sub).map(|_| g.u64_in(1..=full) as u32).collect();
            let alloc = Allocation::new(k, 1, holders);
            let plan = plan_greedy(&alloc);
            let (_, order_new, passes_new) = simulate(&alloc, &plan, 1);
            let (_, order_old, passes_old) = simulate_fixpoint(&alloc, &plan);
            prop::check(
                order_new == order_old && passes_new == passes_old,
                format!("k={k} n_sub={n_sub}: schedule diverged"),
            )
        });
    }
}
