//! Closed-form theory of the paper: Theorem 1 (K=3 minimum communication
//! load), the §IV converse bounds, the uncoded baseline, and the
//! homogeneous-system results of Li–Maddah-Ali–Avestimehr [2] that Remark 2
//! reduces to.
//!
//! ## Units
//!
//! All loads are measured as in the paper: number of intermediate-value
//! *equations* broadcast during the Shuffle phase, normalized by `T` (one
//! unit = one IV worth of bits), with `Q = K` reduce-function groups.
//! Because Theorem 1's expressions contain halves (e.g. `7N/2 − 3M/2`),
//! the exact integer API works in **half-units** (`*_half` functions return
//! `2·L`); `f64` accessors divide by two for display.

pub mod converse;
pub mod homogeneous;
pub mod load;
pub mod params;

pub use load::{classify, lstar, lstar_half, uncoded, uncoded_half, Regime};
pub use params::Params3;
