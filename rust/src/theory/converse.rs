//! The four §IV lower bounds on the communication load.
//!
//! Each is valid for *every* file allocation and coding scheme; Theorem 1's
//! converse is their union (the paper notes "each inequality is a valid
//! lower bound in every regime, but they are not simultaneously active").
//! A key structural fact our tests exploit: `L* = max(all four bounds)`
//! everywhere in the parameter space.

use super::params::Params3;

/// Parameter-level lower bounds in half-units (`2·L`). Negative
/// intermediate values are clamped at 0 (a vacuous bound).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bounds {
    /// §IV-A: `L >= 7N/2 − 3M/2`, from Corollary 1 + `ΣS_k >= 2N − M`
    /// (only non-vacuous when `M <= 2N`).
    pub corollary_tight: i64,
    /// §IV-B: `L >= 3N/2 − M/2` (Corollary 1 with `ΣS_k >= 0`).
    pub corollary_loose: i64,
    /// §IV-C cut-set at the smallest node: `L >= N − M1`.
    pub cutset: i64,
    /// §IV-D genie-aided: `L >= 3N − (M + M1)`.
    pub genie: i64,
}

impl Bounds {
    pub fn max_half(&self) -> u64 {
        self.corollary_tight
            .max(self.corollary_loose)
            .max(self.cutset)
            .max(self.genie)
            .max(0) as u64
    }

    pub fn as_array(&self) -> [i64; 4] {
        [
            self.corollary_tight,
            self.corollary_loose,
            self.cutset,
            self.genie,
        ]
    }
}

/// Compute all four bounds (half-units, possibly negative when vacuous).
pub fn bounds_half(p: &Params3) -> Bounds {
    let ([m1, _, _], _) = p.sorted();
    let n = p.n as i64;
    let m = p.total() as i64;
    let m1 = m1 as i64;
    Bounds {
        // 2L >= 7N − 3M, derivable only while ΣS_k >= 2N − M is forced,
        // i.e. M <= 2N; otherwise fall back to the loose corollary.
        corollary_tight: if m <= 2 * n { 7 * n - 3 * m } else { 3 * n - m },
        corollary_loose: 3 * n - m,
        cutset: 2 * (n - m1),
        genie: 2 * (3 * n - m - m1),
    }
}

/// Best (largest) converse bound in IV units.
pub fn best_bound(p: &Params3) -> f64 {
    bounds_half(p).max_half() as f64 / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::theory::load::{lstar_half, uncoded_half};

    fn p(m1: u64, m2: u64, m3: u64, n: u64) -> Params3 {
        Params3::new(m1, m2, m3, n).unwrap()
    }

    #[test]
    fn paper_example_converse_is_tight() {
        let params = p(6, 7, 7, 12);
        let b = bounds_half(&params);
        // 2L bounds: 7*12-3*20 = 24; 36-20 = 16; 2*(12-6)=12; 2*(36-20-6)=20.
        assert_eq!(b.corollary_tight, 24);
        assert_eq!(b.corollary_loose, 16);
        assert_eq!(b.cutset, 12);
        assert_eq!(b.genie, 20);
        assert_eq!(b.max_half(), 24);
        assert_eq!(lstar_half(&params), 24);
    }

    #[test]
    fn r7_cutset_active() {
        let params = p(5, 11, 11, 12); // R7: L* = N - M1 = 7
        let b = bounds_half(&params);
        assert_eq!(b.cutset, 14);
        assert_eq!(b.max_half(), 14);
        assert_eq!(lstar_half(&params), 14);
    }

    #[test]
    fn r4_genie_active() {
        let params = p(2, 3, 12, 12); // R4: L* = 3N - (M+M1) = 17
        let b = bounds_half(&params);
        assert_eq!(b.genie, 34);
        assert_eq!(b.max_half(), 34);
        assert_eq!(lstar_half(&params), 34);
    }

    #[test]
    fn prop_lstar_equals_max_of_bounds() {
        // The structural heart of Theorem 1: achievability meets the best
        // of the four converse bounds at EVERY valid parameter point.
        prop::run("L* == max(converse bounds)", 2000, |g| {
            let n = g.u64_in(1..=50);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(params) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let ls = lstar_half(&params);
            let cv = bounds_half(&params).max_half();
            prop::check(ls == cv, format!("{params}: L*half={ls} converse={cv}"))
        });
    }

    #[test]
    fn prop_bounds_never_exceed_uncoded() {
        prop::run("bounds <= uncoded", 500, |g| {
            let n = g.u64_in(1..=40);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(params) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let cv = bounds_half(&params).max_half();
            prop::check(
                cv <= uncoded_half(&params),
                format!("{params}: converse {cv} > uncoded {}", uncoded_half(&params)),
            )
        });
    }
}
