//! Problem parameters `P = (M_1, .., M_K, N)` of the CDC system model (§II).

use crate::error::{HetcdcError, Result};
use std::fmt;

fn invalid(msg: impl Into<String>) -> HetcdcError {
    HetcdcError::InvalidParams(msg.into())
}

/// K=3 problem instance. Storage sizes are in files; `m` is kept in the
/// caller's node order (the theory sorts internally, per the paper's WLOG
/// `M1 <= M2 <= M3`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Params3 {
    pub m: [u64; 3],
    pub n: u64,
}

impl Params3 {
    pub fn new(m1: u64, m2: u64, m3: u64, n: u64) -> Result<Self> {
        let p = Self { m: [m1, m2, m3], n };
        p.validate()?;
        Ok(p)
    }

    /// System-model constraints: every node stores something, no node
    /// stores more than everything, and all files fit somewhere
    /// (`∪_k M_k = N` requires `ΣM_k >= N`).
    pub fn validate(&self) -> Result<()> {
        if self.n == 0 {
            return Err(invalid("N must be positive"));
        }
        for (k, &mk) in self.m.iter().enumerate() {
            if mk == 0 {
                return Err(invalid(format!("M{} must be positive", k + 1)));
            }
            if mk > self.n {
                return Err(invalid(format!(
                    "M{} = {} exceeds N = {}",
                    k + 1,
                    mk,
                    self.n
                )));
            }
        }
        if self.total() < self.n {
            return Err(invalid(format!(
                "sum of storage {} cannot cover N = {}",
                self.total(),
                self.n
            )));
        }
        Ok(())
    }

    pub fn total(&self) -> u64 {
        self.m.iter().sum()
    }

    /// Sorted storage `(m1 <= m2 <= m3)` plus the permutation `perm` such
    /// that `sorted[i] = self.m[perm[i]]` (used to un-permute placements).
    pub fn sorted(&self) -> ([u64; 3], [usize; 3]) {
        let mut idx = [0usize, 1, 2];
        idx.sort_by_key(|&i| self.m[i]);
        let sorted = [self.m[idx[0]], self.m[idx[1]], self.m[idx[2]]];
        (sorted, idx)
    }

    pub fn is_homogeneous(&self) -> bool {
        self.m[0] == self.m[1] && self.m[1] == self.m[2]
    }
}

impl fmt::Display for Params3 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "(M1,M2,M3,N)=({},{},{},{})",
            self.m[0], self.m[1], self.m[2], self.n
        )
    }
}

/// General-K problem instance for the §V algorithm.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParamsK {
    pub m: Vec<u64>,
    pub n: u64,
}

impl ParamsK {
    pub fn new(m: Vec<u64>, n: u64) -> Result<Self> {
        if m.len() < 2 {
            return Err(invalid("need at least 2 nodes"));
        }
        if n == 0 {
            return Err(invalid("N must be positive"));
        }
        for (k, &mk) in m.iter().enumerate() {
            if mk == 0 || mk > n {
                return Err(invalid(format!(
                    "M{} = {} out of range (0, N={}]",
                    k + 1,
                    mk,
                    n
                )));
            }
        }
        if m.iter().sum::<u64>() < n {
            return Err(invalid("sum of storage cannot cover N"));
        }
        Ok(Self { m, n })
    }

    pub fn k(&self) -> usize {
        self.m.len()
    }

    pub fn total(&self) -> u64 {
        self.m.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_paper_example() {
        let p = Params3::new(6, 7, 7, 12).unwrap();
        assert_eq!(p.total(), 20);
        assert!(!p.is_homogeneous());
    }

    #[test]
    fn rejects_invalid() {
        assert!(Params3::new(0, 1, 1, 3).is_err()); // zero storage
        assert!(Params3::new(5, 1, 1, 4).is_err()); // M1 > N
        assert!(Params3::new(1, 1, 1, 9).is_err()); // cannot cover N
        assert!(Params3::new(1, 1, 1, 0).is_err()); // N = 0
    }

    #[test]
    fn sorted_returns_permutation() {
        let p = Params3::new(7, 6, 9, 12).unwrap();
        let (s, perm) = p.sorted();
        assert_eq!(s, [6, 7, 9]);
        assert_eq!(perm, [1, 0, 2]);
        for i in 0..3 {
            assert_eq!(s[i], p.m[perm[i]]);
        }
    }

    #[test]
    fn sorted_is_stable_for_ties() {
        let p = Params3::new(7, 7, 6, 12).unwrap();
        let (s, perm) = p.sorted();
        assert_eq!(s, [6, 7, 7]);
        assert_eq!(perm, [2, 0, 1]);
    }

    #[test]
    fn params_k_validation() {
        assert!(ParamsK::new(vec![2, 3, 4, 5], 10).is_ok());
        assert!(ParamsK::new(vec![2], 2).is_err());
        assert!(ParamsK::new(vec![2, 0, 4], 10).is_err());
        assert!(ParamsK::new(vec![1, 1, 1, 1], 10).is_err());
    }
}
