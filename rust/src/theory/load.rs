//! Theorem 1: regime classification and the closed-form minimum load `L*`.
//!
//! With storage sorted `M1 <= M2 <= M3` and `M = M1+M2+M3`:
//!
//! ```text
//! L* = (7N − 3M)/2          P ∈ R1 ∪ R2 ∪ R3
//! L* = 3N − (M1 + M)        P ∈ R4 ∪ R5
//! L* = (3N − M)/2           P ∈ R6
//! L* = N − M1               P ∈ R7
//! ```
//!
//! The regime conditions follow the paper's Theorem 1 with R2/R3 split at
//! `M3 = 3N − M1 − 3M2` (as used in §III-B; the theorem statement's R2 line
//! contains a typo that would make R2 ⊇ R3).

use super::params::Params3;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Regime {
    R1,
    R2,
    R3,
    R4,
    R5,
    R6,
    R7,
}

impl std::fmt::Display for Regime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

/// Classify sorted parameters into R1..R7 (Theorem 1). The regimes
/// partition the valid parameter space: exactly one matches.
pub fn classify(p: &Params3) -> Regime {
    let ([m1, m2, m3], _) = p.sorted();
    let n = p.n;
    let m = m1 + m2 + m3;
    if m > 2 * n {
        // C. M > 2N
        if m3 + m2 <= n + m1 {
            Regime::R6
        } else {
            Regime::R7
        }
    } else if m1 + m2 <= n {
        // A. M1 + M2 <= N
        if m3 + m2 <= n + m1 {
            Regime::R1
        } else {
            Regime::R4
        }
    } else {
        // B. M <= 2N, M1 + M2 > N
        if m3 + m2 > n + m1 {
            Regime::R5
        } else if m3 + m1 + 3 * m2 <= 3 * n {
            Regime::R2
        } else {
            Regime::R3
        }
    }
}

/// `2·L*` (exact integer half-units).
pub fn lstar_half(p: &Params3) -> u64 {
    let ([m1, _m2, _m3], _) = p.sorted();
    let n = p.n;
    let m = p.total();
    match classify(p) {
        Regime::R1 | Regime::R2 | Regime::R3 => 7 * n - 3 * m,
        Regime::R4 | Regime::R5 => 2 * (3 * n - m1 - m),
        Regime::R6 => 3 * n - m,
        Regime::R7 => 2 * (n - m1),
    }
}

/// `L*` in IV-equation units.
pub fn lstar(p: &Params3) -> f64 {
    lstar_half(p) as f64 / 2.0
}

/// Uncoded shuffle load `2·L_uncoded = 2(3N − M)` (half-units): with `Q=K`
/// every file stored at `r` nodes costs `3 − r` deliveries; the best
/// uncoded allocation stores every file as redundantly as storage allows.
pub fn uncoded_half(p: &Params3) -> u64 {
    2 * (3 * p.n - p.total().min(3 * p.n))
}

/// Uncoded load in IV units (Remark 1's comparison point).
pub fn uncoded(p: &Params3) -> f64 {
    uncoded_half(p) as f64 / 2.0
}

/// Remark 1: achievable saving `3N − M − L*` (IV units).
pub fn saving(p: &Params3) -> f64 {
    uncoded(p) - lstar(p)
}

/// Load of the storage-OBLIVIOUS baseline: provision every node to
/// `min_k M_k` and run the homogeneous scheme (the [13] failure mode the
/// paper's §I cites). `None` when even that cannot cover `N`.
pub fn oblivious(p: &Params3) -> Option<f64> {
    let m_min = *p.m.iter().min().unwrap();
    if 3 * m_min < p.n {
        return None;
    }
    let r = 3.0 * m_min as f64 / p.n as f64;
    Some(crate::theory::homogeneous::load_envelope(3, r.min(3.0), p.n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    fn p(m1: u64, m2: u64, m3: u64, n: u64) -> Params3 {
        Params3::new(m1, m2, m3, n).unwrap()
    }

    #[test]
    fn paper_example_677_12() {
        // Fig 3: (6,7,7,12) -> L* = 12, uncoded = 16 (25% lower).
        // (M3 = 7 <= 3N−M1−3M2 = 9, so this point sits in R2.)
        let params = p(6, 7, 7, 12);
        assert_eq!(classify(&params), Regime::R2);
        assert_eq!(lstar(&params), 12.0);
        assert_eq!(uncoded(&params), 16.0);
        assert_eq!(saving(&params), 4.0);
    }

    #[test]
    fn regime_examples_cover_all_seven() {
        // Hand-constructed representative of each regime.
        assert_eq!(classify(&p(4, 5, 6, 12)), Regime::R1); // M1+M2<=N, M3<=N+M1-M2
        assert_eq!(classify(&p(2, 3, 12, 12)), Regime::R4); // M3>N+M1-M2
        assert_eq!(classify(&p(6, 7, 7, 12)), Regime::R2);
        assert_eq!(classify(&p(8, 8, 8, 12)), Regime::R3); // homogeneous r=2
        assert_eq!(classify(&p(7, 7, 7, 12)), Regime::R2);
        assert_eq!(classify(&p(5, 8, 11, 12)), Regime::R5); // M<=2N, M3>N+M1-M2
        assert_eq!(classify(&p(10, 10, 10, 12)), Regime::R6); // M>2N
        assert_eq!(classify(&p(5, 11, 11, 12)), Regime::R7); // M>2N, M3>N+M1-M2
        // R2 needs M3 <= 3N-M1-3M2: e.g. N=12, (5,8,9)? 3N-M1-3M2 = 36-5-24 = 7 < 9 no.
        // (7,6,5)? sorted (5,6,7): M1+M2=11<=12 -> R1. Try N=10, (4,7,5):
        // sorted (4,5,7): M1+M2=9<=10 -> A. Use (6,5,4), N=9: sorted (4,5,6),
        // M1+M2=9>9? no. N=8, (4,5,4): sorted (4,4,5) M1+M2=8<=8 -> A.
        // (5,5,4), N=8: sorted (4,5,5): M1+M2=9>8, M=14<=16, 3N-M1-3M2=24-4-15=5>=5 -> R2.
        assert_eq!(classify(&p(5, 5, 4, 8)), Regime::R2);
    }

    #[test]
    fn lstar_values_per_regime() {
        assert_eq!(lstar(&p(4, 5, 6, 12)), (7.0 * 12.0 - 3.0 * 15.0) / 2.0); // R1: 19.5
        assert_eq!(lstar(&p(2, 3, 12, 12)), 3.0 * 12.0 - (2.0 + 17.0)); // R4: 17
        assert_eq!(lstar(&p(5, 5, 4, 8)), (7.0 * 8.0 - 3.0 * 14.0) / 2.0); // R2: 7
        assert_eq!(lstar(&p(5, 8, 11, 12)), 36.0 - (5.0 + 24.0)); // R5: 7
        assert_eq!(lstar(&p(10, 10, 10, 12)), (36.0 - 30.0) / 2.0); // R6: 3
        assert_eq!(lstar(&p(5, 11, 11, 12)), 12.0 - 5.0); // R7: 7
    }

    #[test]
    fn classification_is_order_invariant() {
        let a = p(6, 7, 7, 12);
        let b = p(7, 6, 7, 12);
        let c = p(7, 7, 6, 12);
        assert_eq!(lstar_half(&a), lstar_half(&b));
        assert_eq!(lstar_half(&b), lstar_half(&c));
        assert_eq!(classify(&a), classify(&b));
    }

    #[test]
    fn homogeneous_full_replication_is_free() {
        // M_k = N for all k: every node has everything -> L* = 0 (R6).
        let params = p(12, 12, 12, 12);
        assert_eq!(classify(&params), Regime::R6);
        assert_eq!(lstar(&params), 0.0);
    }

    #[test]
    fn prop_exactly_one_regime_and_lstar_sane() {
        prop::run("regimes partition + L* in [0, uncoded]", 500, |g| {
            let n = g.u64_in(1..=40);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(params) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let ls = lstar(&params);
            let un = uncoded(&params);
            prop::check(
                ls >= 0.0 && ls <= un + 1e-9,
                format!("{params}: L*={ls} uncoded={un}"),
            )
        });
    }

    #[test]
    fn prop_lstar_monotone_in_storage() {
        // Adding storage to any node can only reduce L*.
        prop::run("L* monotone", 300, |g| {
            let n = g.u64_in(2..=30);
            let m1 = g.u64_in(1..=n);
            let m2 = g.u64_in(1..=n);
            let m3 = g.u64_in(1..=n);
            let Ok(pa) = Params3::new(m1, m2, m3, n) else {
                return Ok(());
            };
            let which = g.usize_in(0..=2);
            let mut m = pa.m;
            if m[which] >= n {
                return Ok(());
            }
            m[which] += 1;
            let pb = Params3::new(m[0], m[1], m[2], n).unwrap();
            prop::check(
                lstar_half(&pb) <= lstar_half(&pa),
                format!("{pa} -> {pb}: {} > {}", lstar_half(&pb), lstar_half(&pa)),
            )
        });
    }
}
