//! Homogeneous-system baseline: Li–Maddah-Ali–Avestimehr [2].
//!
//! For `K` nodes each storing `rN/K` files (computation load `r`), the
//! optimal total shuffle load with `Q = K` function groups is
//! `L_hom(r) = N (K − r) / r` IV equations (the paper's normalized
//! `(1/r)(1 − r/K)` times `NK`). Remark 2: Theorem 1 with `M1=M2=M3`
//! reduces to this curve at integer `r`, with the lower convex envelope
//! (memory sharing) in between.

use super::params::Params3;

/// Total shuffle load (IV units) of the homogeneous CDC scheme at integer
/// computation load `r` on `K` nodes and `N` files.
pub fn load_at_r(k: u64, r: u64, n: u64) -> f64 {
    assert!(r >= 1 && r <= k, "computation load r in [1, K]");
    n as f64 * (k - r) as f64 / r as f64
}

/// Memory-sharing lower convex envelope of `load_at_r` at real-valued
/// `r = KM/(KN)·K = M/N` — the homogeneous optimum for arbitrary storage.
pub fn load_envelope(k: u64, r: f64, n: u64) -> f64 {
    assert!(r >= 1.0 - 1e-12 && r <= k as f64 + 1e-12);
    let lo = r.floor().clamp(1.0, k as f64) as u64;
    let hi = r.ceil().clamp(1.0, k as f64) as u64;
    if lo == hi {
        return load_at_r(k, lo, n);
    }
    let w = r - lo as f64;
    (1.0 - w) * load_at_r(k, lo, n) + w * load_at_r(k, hi, n)
}

/// Remark 2 check helper: the heterogeneous `L*` at `M1=M2=M3=M` equals
/// the homogeneous envelope at `r = 3M/N`.
pub fn matches_remark2(m: u64, n: u64) -> bool {
    let Ok(p) = Params3::new(m, m, m, n) else {
        return true;
    };
    let r = 3.0 * m as f64 / n as f64;
    if !(1.0..=3.0).contains(&r) {
        return true;
    }
    (crate::theory::load::lstar(&p) - load_envelope(3, r, n)).abs() < 1e-9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::theory::load::lstar;

    #[test]
    fn integer_r_values() {
        // K=3, N=12: r=1 -> 24, r=2 -> 6, r=3 -> 0.
        assert_eq!(load_at_r(3, 1, 12), 24.0);
        assert_eq!(load_at_r(3, 2, 12), 6.0);
        assert_eq!(load_at_r(3, 3, 12), 0.0);
    }

    #[test]
    fn envelope_interpolates() {
        let mid = load_envelope(3, 1.5, 12);
        assert_eq!(mid, 0.5 * 24.0 + 0.5 * 6.0);
        assert_eq!(load_envelope(3, 2.0, 12), 6.0);
    }

    #[test]
    fn remark2_at_integer_r() {
        // M=4 (r=1), M=8 (r=2), M=12 (r=3) on N=12.
        for m in [4u64, 8, 12] {
            let p = Params3::new(m, m, m, 12).unwrap();
            let r = 3 * m / 12;
            assert_eq!(lstar(&p), load_at_r(3, r, 12), "m={m}");
        }
    }

    #[test]
    fn prop_remark2_reduction() {
        // Heterogeneous Theorem 1 at equal storage == homogeneous envelope.
        prop::run("Remark 2", 300, |g| {
            let n = g.u64_in(3..=60);
            let m = g.u64_in(1..=n);
            if 3 * m < n {
                return Ok(()); // cannot cover N
            }
            prop::check(matches_remark2(m, n), format!("m={m} n={n}"))
        });
    }

    #[test]
    fn coding_gain_is_r() {
        // CDC reduces the uncoded load N(K-r) by exactly factor r.
        for k in 2..=6u64 {
            for r in 1..=k {
                let n = 120;
                let uncoded = (n * (k - r)) as f64;
                assert!((load_at_r(k, r, n) * r as f64 - uncoded).abs() < 1e-9);
            }
        }
    }
}
