//! Workload generators and native (oracle) Map/Reduce implementations.
//!
//! Data is generated **per subfile, deterministically from (seed, subfile
//! id)** so every node that stores a subfile materializes identical bytes
//! without any coordination — exactly how a distributed FS replica would
//! behave, with no network cost attributed to input loading.

pub mod terasort;
pub mod wordcount;

use crate::model::job::{JobSpec, WorkloadKind};

/// Native Map: compute all Q groups' IVs for one subfile.
/// Returns `q` payloads of `t` 4-byte elements each (little-endian bytes).
pub fn native_map(job: &JobSpec, q: usize, sub: usize) -> Vec<Vec<u8>> {
    match job.workload {
        WorkloadKind::WordCount => wordcount::map_subfile(job, q, sub),
        WorkloadKind::TeraSort => terasort::map_subfile(job, q, sub),
    }
}

/// Native Reduce oracle: group `g`'s final output over all `n_sub`
/// subfiles (f32 accumulation for WordCount, i64 exact for TeraSort,
/// both surfaced as f64 for comparison).
pub fn native_reduce_oracle(job: &JobSpec, q: usize, g: usize, n_sub: usize) -> Vec<f64> {
    match job.workload {
        WorkloadKind::WordCount => wordcount::reduce_oracle(job, q, g, n_sub),
        WorkloadKind::TeraSort => terasort::reduce_oracle(job, q, g, n_sub),
    }
}

/// All groups' oracle outputs in one Map pass (what the engine's per-run
/// verification uses — one pass instead of `q`).
pub fn native_reduce_oracle_all(job: &JobSpec, q: usize, n_sub: usize) -> Vec<Vec<f64>> {
    match job.workload {
        WorkloadKind::WordCount => wordcount::reduce_oracle_all(job, q, n_sub),
        WorkloadKind::TeraSort => terasort::reduce_oracle_all(job, q, n_sub),
    }
}

/// Decode an IV payload into f64s for verification.
pub fn decode_payload(job: &JobSpec, bytes: &[u8]) -> Vec<f64> {
    match job.workload {
        WorkloadKind::WordCount => bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()) as f64)
            .collect(),
        WorkloadKind::TeraSort => bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()) as f64)
            .collect(),
    }
}
