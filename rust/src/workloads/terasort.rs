//! TeraSort range-partition workload (the CodedTeraSort experiment [10]).
//!
//! Each subfile holds `keys_per_file` uniform u32 keys. The key space is
//! range-partitioned into `Q` reducer ranges of `T` sub-buckets each
//! (`QT` splitters total); Map counts the subfile's keys per sub-bucket —
//! those per-reducer count vectors are the shuffled IVs, and Reduce merges
//! them into reducer `q`'s slice of the global key histogram (the
//! splitter-refinement stage of a production sort).

use crate::model::job::JobSpec;
use crate::util::rng::Xoshiro256;

/// Key space: 30-bit keys so keys AND bucket bounds are exactly
/// representable as the i32 the `map_histogram` XLA artifact consumes.
pub const KEY_BITS: u32 = 30;
pub const KEY_SPACE: u64 = 1 << KEY_BITS;

/// Deterministic keys of a subfile.
pub fn keys(job: &JobSpec, sub: usize) -> Vec<u32> {
    let mut rng = Xoshiro256::seed_from_u64(job.seed ^ (0xFEED + sub as u64 * 0x9E37_79B9));
    (0..job.keys_per_file)
        .map(|_| rng.next_u32() >> (32 - KEY_BITS))
        .collect()
}

/// Bucket boundaries: `q*t + 1` uniform splitters over the key space.
pub fn bounds(job: &JobSpec, q: usize) -> Vec<u32> {
    let buckets = (q * job.t) as u64;
    (0..=buckets)
        .map(|i| ((i * KEY_SPACE) / buckets) as u32)
        .collect()
}

/// Bucket index of one key (uniform splitters allow direct computation).
fn bucket_of(key: u32, buckets: u64) -> usize {
    ((key as u64 * buckets) >> KEY_BITS) as usize
}

/// Native Map: per-group count vectors (i32 LE payloads of length `t`).
pub fn map_subfile(job: &JobSpec, q: usize, sub: usize) -> Vec<Vec<u8>> {
    let t = job.t;
    let buckets = (q * t) as u64;
    let mut counts = vec![0i32; q * t];
    for key in keys(job, sub) {
        counts[bucket_of(key, buckets)] += 1;
    }
    (0..q)
        .map(|g| {
            let mut payload = Vec::with_capacity(t * 4);
            for &c in &counts[g * t..(g + 1) * t] {
                payload.extend_from_slice(&c.to_le_bytes());
            }
            payload
        })
        .collect()
}

/// Oracle Reduce for group `g`: exact global counts of its `t` buckets.
pub fn reduce_oracle(job: &JobSpec, q: usize, g: usize, n_sub: usize) -> Vec<f64> {
    std::mem::take(&mut reduce_oracle_all(job, q, n_sub)[g])
}

/// Oracle Reduce for ALL groups in one Map pass (see wordcount's
/// counterpart; avoids q× recomputation during verification).
pub fn reduce_oracle_all(job: &JobSpec, q: usize, n_sub: usize) -> Vec<Vec<f64>> {
    let mut acc = vec![vec![0i64; job.t]; q];
    for sub in 0..n_sub {
        let ivs = map_subfile(job, q, sub);
        for (g, payload) in ivs.iter().enumerate() {
            for (a, chunk) in acc[g].iter_mut().zip(payload.chunks_exact(4)) {
                *a += i32::from_le_bytes(chunk.try_into().unwrap()) as i64;
            }
        }
    }
    acc.into_iter()
        .map(|v| v.into_iter().map(|x| x as f64).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> JobSpec {
        let mut j = JobSpec::terasort(4);
        j.t = 8;
        j.keys_per_file = 64;
        j
    }

    #[test]
    fn keys_deterministic_per_subfile() {
        let j = job();
        assert_eq!(keys(&j, 0), keys(&j, 0));
        assert_ne!(keys(&j, 0), keys(&j, 1));
        assert_eq!(keys(&j, 0).len(), 64);
    }

    #[test]
    fn bounds_cover_key_space_and_fit_i32() {
        let j = job();
        let b = bounds(&j, 3);
        assert_eq!(b.len(), 25);
        assert_eq!(b[0], 0);
        assert_eq!(*b.last().unwrap(), KEY_SPACE as u32);
        assert!(b.windows(2).all(|w| w[0] < w[1]));
        assert!(b.iter().all(|&x| x <= i32::MAX as u32));
    }

    #[test]
    fn map_counts_every_key_once() {
        let j = job();
        let ivs = map_subfile(&j, 3, 2);
        let total: i64 = ivs
            .iter()
            .flat_map(|p| p.chunks_exact(4))
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()) as i64)
            .sum();
        assert_eq!(total, j.keys_per_file as i64);
    }

    #[test]
    fn map_matches_bucket_of() {
        let j = job();
        let ks = keys(&j, 0);
        let buckets = (3 * j.t) as u64;
        let mut want = vec![0i32; 3 * j.t];
        for k in ks {
            want[bucket_of(k, buckets)] += 1;
        }
        let ivs = map_subfile(&j, 3, 0);
        for g in 0..3 {
            let got: Vec<i32> = ivs[g]
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            assert_eq!(got, want[g * j.t..(g + 1) * j.t]);
        }
    }

    #[test]
    fn reduce_oracle_totals_all_keys() {
        let j = job();
        let n_sub = 6;
        let total: f64 = (0..3)
            .flat_map(|g| reduce_oracle(&j, 3, g, n_sub))
            .sum();
        assert_eq!(total, (n_sub * j.keys_per_file) as f64);
    }

    #[test]
    fn bucket_distribution_roughly_uniform() {
        let mut j = job();
        j.keys_per_file = 4096;
        let ivs = map_subfile(&j, 2, 0);
        let counts: Vec<i32> = ivs
            .iter()
            .flat_map(|p| p.chunks_exact(4))
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let expect = 4096.0 / counts.len() as f64;
        for &c in &counts {
            assert!((c as f64) < 3.0 * expect, "bucket count {c} vs mean {expect}");
        }
    }
}
