//! WordCount / feature-projection workload.
//!
//! Each subfile is a bag of `D_TOKENS` zipf-distributed tokens over a
//! vocabulary of `V`; its raw representation is the token-count vector
//! `counts ∈ R^V`. The Map functions (eq. (1)'s `g_{q,n}`) are rows of a
//! fixed random projection `W ∈ R^{QT×V}`: `IV_{q,n} = W_q · counts_n`,
//! computed natively here (oracle) or via the `map_project` XLA artifact
//! (runtime path). Reduce (`h_q`) sums IVs across files — the linearity
//! the pipeline-invariant tests rely on.

use crate::model::job::JobSpec;
use crate::util::rng::{Xoshiro256, Zipf};

/// Tokens drawn per subfile.
pub const D_TOKENS: usize = 512;
/// Zipf skew of the synthetic corpus.
pub const ZIPF_S: f64 = 1.1;

/// Deterministic token-count vector of a subfile (length `vocab`).
pub fn counts(job: &JobSpec, sub: usize) -> Vec<f32> {
    let mut rng = Xoshiro256::seed_from_u64(job.seed ^ (0x9E37 + sub as u64 * 0x1234_5677));
    let zipf = zipf_table(job.vocab);
    let mut c = vec![0f32; job.vocab];
    for _ in 0..D_TOKENS {
        c[zipf.sample(&mut rng)] += 1.0;
    }
    c
}

/// Shared Zipf CDF per vocabulary size (rebuilding the table per subfile
/// showed up in the Map-phase profile).
fn zipf_table(vocab: usize) -> std::sync::Arc<Zipf> {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex, OnceLock};
    static CACHE: OnceLock<Mutex<HashMap<usize, Arc<Zipf>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(z) = cache.lock().unwrap().get(&vocab) {
        return z.clone();
    }
    let z = Arc::new(Zipf::new(vocab, ZIPF_S));
    cache.lock().unwrap().insert(vocab, z.clone());
    z
}

/// Deterministic projection matrix `W` of shape `(q*t, vocab)`, row-major.
/// Entries are small signed integers over 8 (exactly representable in f32)
/// so Rust-native and XLA matmuls agree to float round-off only.
///
/// Cached per `(seed, q, t, vocab)`: the Map hot loop calls this once per
/// subfile and regenerating 24k+ PRNG draws per call dominated the
/// WordCount profile (see EXPERIMENTS.md §Perf).
pub fn projection(job: &JobSpec, q: usize) -> std::sync::Arc<Vec<f32>> {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex, OnceLock};
    type Key = (u64, usize, usize, usize);
    static CACHE: OnceLock<Mutex<HashMap<Key, Arc<Vec<f32>>>>> = OnceLock::new();
    let key = (job.seed, q, job.t, job.vocab);
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(w) = cache.lock().unwrap().get(&key) {
        return w.clone();
    }
    let rows = q * job.t;
    let mut rng = Xoshiro256::seed_from_u64(job.seed ^ 0xBEEF);
    let w: Arc<Vec<f32>> = Arc::new(
        (0..rows * job.vocab)
            .map(|_| (rng.gen_range(17) as f32 - 8.0) / 8.0)
            .collect(),
    );
    cache.lock().unwrap().insert(key, w.clone());
    w
}

/// Native Map for one subfile: all `q` groups' IVs (f32 LE payloads).
pub fn map_subfile(job: &JobSpec, q: usize, sub: usize) -> Vec<Vec<u8>> {
    let c = counts(job, sub);
    let w = projection(job, q);
    let t = job.t;
    let mut out = Vec::with_capacity(q);
    for g in 0..q {
        let mut payload = Vec::with_capacity(t * 4);
        for row in 0..t {
            let wrow = &w[((g * t + row) * job.vocab)..((g * t + row + 1) * job.vocab)];
            let dot: f32 = wrow.iter().zip(&c).map(|(a, b)| a * b).sum();
            payload.extend_from_slice(&dot.to_le_bytes());
        }
        out.push(payload);
    }
    out
}

/// Oracle Reduce for group `g`: sum of its IVs over all subfiles.
pub fn reduce_oracle(job: &JobSpec, q: usize, g: usize, n_sub: usize) -> Vec<f64> {
    std::mem::take(&mut reduce_oracle_all(job, q, n_sub)[g])
}

/// Oracle Reduce for ALL groups in one Map pass (the engine verifies every
/// node per run; per-group recomputation tripled verification cost —
/// EXPERIMENTS.md §Perf).
pub fn reduce_oracle_all(job: &JobSpec, q: usize, n_sub: usize) -> Vec<Vec<f64>> {
    let mut acc = vec![vec![0f64; job.t]; q];
    for sub in 0..n_sub {
        let ivs = map_subfile(job, q, sub);
        for (g, payload) in ivs.iter().enumerate() {
            for (a, chunk) in acc[g].iter_mut().zip(payload.chunks_exact(4)) {
                *a += f32::from_le_bytes(chunk.try_into().unwrap()) as f64;
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> JobSpec {
        let mut j = JobSpec::wordcount(4);
        j.t = 8;
        j.vocab = 32;
        j
    }

    #[test]
    fn counts_are_deterministic_and_total_d() {
        let j = job();
        let a = counts(&j, 3);
        let b = counts(&j, 3);
        assert_eq!(a, b);
        assert_eq!(a.iter().sum::<f32>(), D_TOKENS as f32);
        let c = counts(&j, 4);
        assert_ne!(a, c);
    }

    #[test]
    fn map_produces_q_payloads_of_t_words() {
        let j = job();
        let ivs = map_subfile(&j, 3, 0);
        assert_eq!(ivs.len(), 3);
        assert!(ivs.iter().all(|p| p.len() == j.t * 4));
    }

    #[test]
    fn map_matches_direct_projection() {
        let j = job();
        let c = counts(&j, 1);
        let w = projection(&j, 3);
        let ivs = map_subfile(&j, 3, 1);
        // Check group 2, row 5 by hand.
        let (g, row) = (2usize, 5usize);
        let wrow = &w[((g * j.t + row) * j.vocab)..((g * j.t + row + 1) * j.vocab)];
        let want: f32 = wrow.iter().zip(&c).map(|(a, b)| a * b).sum();
        let got = f32::from_le_bytes(ivs[g][row * 4..row * 4 + 4].try_into().unwrap());
        assert_eq!(got, want);
    }

    #[test]
    fn reduce_oracle_is_sum_of_maps() {
        let j = job();
        let oracle = reduce_oracle(&j, 3, 1, 4);
        let mut acc = vec![0f64; j.t];
        for sub in 0..4 {
            let ivs = map_subfile(&j, 3, sub);
            for (a, chunk) in acc.iter_mut().zip(ivs[1].chunks_exact(4)) {
                *a += f32::from_le_bytes(chunk.try_into().unwrap()) as f64;
            }
        }
        assert_eq!(oracle, acc);
    }
}
