//! Cluster specification: per-node storage, bandwidth, and compute rate.
//!
//! JSON round-trip via the built-in [`crate::util::json`] substrate, so
//! deployments describe heterogeneous clusters in config files:
//!
//! ```json
//! {"nodes": [
//!   {"name": "m4.large",  "storage": 6, "uplink_mbps": 450, "map_files_per_s": 120},
//!   {"name": "m4.xlarge", "storage": 7, "uplink_mbps": 750, "map_files_per_s": 240}
//! ], "latency_ms": 0.5}
//! ```

use crate::error::{HetcdcError, Result};
use crate::net::{BroadcastNet, FaultSpec, Topology};
use crate::theory::params::{Params3, ParamsK};
use crate::util::json::Json;
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub struct NodeSpec {
    pub name: String,
    /// Storage capacity in files (the paper's `M_k`).
    pub storage: u64,
    /// Uplink bandwidth, Mbit/s.
    pub uplink_mbps: f64,
    /// Map throughput, files/second (heterogeneous compute).
    pub map_files_per_s: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    pub nodes: Vec<NodeSpec>,
    /// Per-message broadcast latency, milliseconds.
    pub latency_ms: f64,
    /// Network topology between the nodes ([`Topology::Shared`] = the
    /// paper's single broadcast medium, the default; switched variants
    /// change the simulated schedule, never the byte/round counts).
    pub topology: Topology,
    /// Fault model the cluster is planned and metered under
    /// ([`FaultSpec::default`] = no faults, the implicit state of every
    /// pre-fault artifact; the JSON key is omitted in that case).
    pub faults: FaultSpec,
}

impl ClusterSpec {
    pub fn k(&self) -> usize {
        self.nodes.len()
    }

    pub fn storage(&self) -> Vec<u64> {
        self.nodes.iter().map(|n| n.storage).collect()
    }

    pub fn params3(&self, n_files: u64) -> Result<Params3> {
        if self.k() != 3 {
            return Err(HetcdcError::InvalidParams(format!(
                "params3 needs K=3, cluster has {}",
                self.k()
            )));
        }
        Params3::new(
            self.nodes[0].storage,
            self.nodes[1].storage,
            self.nodes[2].storage,
            n_files,
        )
    }

    pub fn params_k(&self, n_files: u64) -> Result<ParamsK> {
        ParamsK::new(self.storage(), n_files)
    }

    pub fn network(&self) -> Result<BroadcastNet> {
        BroadcastNet::with_topology(
            self.nodes.iter().map(|n| n.uplink_mbps * 1e6).collect(),
            self.latency_ms / 1e3,
            self.topology,
        )
    }

    /// Builder-style topology override.
    pub fn with_topology(mut self, topology: Topology) -> Self {
        self.topology = topology;
        self
    }

    /// Builder-style fault-model override.
    pub fn with_faults(mut self, faults: FaultSpec) -> Self {
        self.faults = faults;
        self
    }

    /// A 3-node heterogeneous cluster shaped like mixed EC2 instances,
    /// sized for the paper's Fig 3 example (storage 6, 7, 7).
    pub fn ec2_like_3node(n_files_hint: u64) -> Self {
        // Scale storage to the workload: ratios from the (6,7,7,12) example.
        let scale = (n_files_hint as f64 / 12.0).max(1.0);
        let st = |x: f64| (x * scale).round() as u64;
        ClusterSpec {
            nodes: vec![
                NodeSpec {
                    name: "m4.large".into(),
                    storage: st(6.0),
                    uplink_mbps: 450.0,
                    map_files_per_s: 120.0,
                },
                NodeSpec {
                    name: "m4.xlarge".into(),
                    storage: st(7.0),
                    uplink_mbps: 750.0,
                    map_files_per_s: 240.0,
                },
                NodeSpec {
                    name: "m4.2xlarge".into(),
                    storage: st(7.0),
                    uplink_mbps: 1000.0,
                    map_files_per_s: 480.0,
                },
            ],
            latency_ms: 0.5,
            topology: Topology::Shared,
            faults: FaultSpec::default(),
        }
    }

    pub fn homogeneous(k: usize, storage: u64, uplink_mbps: f64) -> Self {
        ClusterSpec {
            nodes: (0..k)
                .map(|i| NodeSpec {
                    name: format!("node{i}"),
                    storage,
                    uplink_mbps,
                    map_files_per_s: 200.0,
                })
                .collect(),
            latency_ms: 0.5,
            topology: Topology::Shared,
            faults: FaultSpec::default(),
        }
    }

    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|n| {
                let mut m = BTreeMap::new();
                m.insert("name".into(), Json::Str(n.name.clone()));
                m.insert("storage".into(), Json::Num(n.storage as f64));
                m.insert("uplink_mbps".into(), Json::Num(n.uplink_mbps));
                m.insert("map_files_per_s".into(), Json::Num(n.map_files_per_s));
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("nodes".into(), Json::Arr(nodes));
        m.insert("latency_ms".into(), Json::Num(self.latency_ms));
        // Omitted when Shared: every pre-topology artifact stays
        // byte-identical, and older readers never see the key.
        if !self.topology.is_shared() {
            m.insert("topology".into(), self.topology.to_json());
        }
        // Same contract for faults: omitted when none are configured.
        if !self.faults.is_none() {
            m.insert("faults".into(), self.faults.to_json());
        }
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let nodes = j
            .get("nodes")
            .and_then(|n| n.as_arr())
            .ok_or_else(|| HetcdcError::Json("cluster: missing 'nodes' array".into()))?;
        let parsed: Result<Vec<NodeSpec>> = nodes
            .iter()
            .enumerate()
            .map(|(i, n)| {
                Ok(NodeSpec {
                    name: n
                        .get("name")
                        .and_then(|v| v.as_str())
                        .map(String::from)
                        .unwrap_or_else(|| format!("node{i}")),
                    storage: n
                        .get("storage")
                        .and_then(|v| v.as_usize())
                        .ok_or_else(|| {
                            HetcdcError::Json(format!("cluster node {i}: missing 'storage'"))
                        })? as u64,
                    uplink_mbps: n
                        .get("uplink_mbps")
                        .and_then(|v| v.as_f64())
                        .unwrap_or(1000.0),
                    map_files_per_s: n
                        .get("map_files_per_s")
                        .and_then(|v| v.as_f64())
                        .unwrap_or(200.0),
                })
            })
            .collect();
        let topology = match j.get("topology") {
            Some(t) => Topology::from_json(t)?,
            None => Topology::Shared,
        };
        let faults = match j.get("faults") {
            Some(f) => FaultSpec::from_json(f)?,
            None => FaultSpec::default(),
        };
        let spec = ClusterSpec {
            nodes: parsed?,
            latency_ms: j.get("latency_ms").and_then(|v| v.as_f64()).unwrap_or(0.5),
            topology,
            faults,
        };
        spec.topology.validate(spec.k())?;
        spec.faults.validate(spec.k())?;
        Ok(spec)
    }

    pub fn from_json_str(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let c = ClusterSpec::ec2_like_3node(12);
        let text = c.to_json().to_string_pretty();
        let back = ClusterSpec::from_json_str(&text).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn parses_minimal_config() {
        let c = ClusterSpec::from_json_str(
            r#"{"nodes": [{"storage": 6}, {"storage": 7}, {"storage": 7}]}"#,
        )
        .unwrap();
        assert_eq!(c.storage(), vec![6, 7, 7]);
        assert_eq!(c.latency_ms, 0.5);
        assert_eq!(c.nodes[1].name, "node1");
    }

    #[test]
    fn rejects_bad_config() {
        assert!(ClusterSpec::from_json_str("{}").is_err());
        assert!(ClusterSpec::from_json_str(r#"{"nodes": [{"name": "x"}]}"#).is_err());
        assert!(ClusterSpec::from_json_str("not json").is_err());
    }

    #[test]
    fn params_and_network_construction() {
        let c = ClusterSpec::ec2_like_3node(12);
        let p = c.params3(12).unwrap();
        assert_eq!(p.m, [6, 7, 7]);
        assert!(c.params3(100).is_err()); // storage cannot cover N
        let net = c.network().unwrap();
        assert_eq!(net.uplink_bps.len(), 3);
        assert!(c.params_k(12).is_ok());
        // A config with a dead uplink is a typed error, not a panic.
        let mut broken = c.clone();
        broken.nodes[1].uplink_mbps = 0.0;
        assert!(matches!(
            broken.network(),
            Err(HetcdcError::InvalidParams(_))
        ));
    }

    #[test]
    fn ec2_preset_scales_storage() {
        let c = ClusterSpec::ec2_like_3node(120);
        assert_eq!(c.storage(), vec![60, 70, 70]);
    }

    #[test]
    fn topology_roundtrips_and_shared_is_omitted() {
        let c = ClusterSpec::ec2_like_3node(12);
        assert!(!c.to_json().to_string_pretty().contains("topology"));
        let rack = c.clone().with_topology(Topology::Rack { racks: 3, oversub: 2.0 });
        let text = rack.to_json().to_string_pretty();
        assert!(text.contains("rack:q=3,oversub=2"));
        let back = ClusterSpec::from_json_str(&text).unwrap();
        assert_eq!(rack, back);
        // A topology that does not fit the node count is a typed error.
        let mut j = rack.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("topology".into(), Json::Str("rack:q=9".into()));
        }
        assert!(matches!(
            ClusterSpec::from_json(&j),
            Err(HetcdcError::InvalidParams(_))
        ));
    }

    #[test]
    fn faults_roundtrip_and_none_is_omitted() {
        let c = ClusterSpec::ec2_like_3node(12);
        assert!(!c.to_json().to_string_pretty().contains("faults"));
        let faulty = c
            .clone()
            .with_faults(FaultSpec::parse("straggle:seed=0xbe7c,amp=0.5;repair:f=1").unwrap());
        let text = faulty.to_json().to_string_pretty();
        assert!(text.contains("straggle:seed=0xbe7c,amp=0.5"));
        let back = ClusterSpec::from_json_str(&text).unwrap();
        assert_eq!(faulty, back);
        // An invalid fault spec is a typed error.
        let mut j = faulty.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("faults".into(), Json::Str("repair:f=99".into()));
        }
        assert!(matches!(
            ClusterSpec::from_json(&j),
            Err(HetcdcError::InvalidParams(_))
        ));
    }

    #[test]
    fn network_inherits_the_cluster_topology() {
        let c = ClusterSpec::ec2_like_3node(12)
            .with_topology(Topology::Rack { racks: 3, oversub: 1.0 });
        let net = c.network().unwrap();
        assert_eq!(*net.topology(), c.topology);
    }
}
