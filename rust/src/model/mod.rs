//! Job and cluster specifications (the framework's config surface).

pub mod cluster;
pub mod job;

pub use cluster::{ClusterSpec, NodeSpec};
pub use job::{JobSpec, ShuffleMode, WorkloadKind};
