//! Job specification: the MapReduce computation to run (§II model).

use crate::error::{HetcdcError, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Built-in workloads (DESIGN.md §4 explains the substitutions for the
/// paper's TeraSort / production traces).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    /// Zipf token corpus; Map = feature projection (`W @ counts`, f32),
    /// Reduce = sum. Exercises the `map_project` Pallas/XLA artifact.
    WordCount,
    /// Uniform u32 keys; Map = per-reducer range histogram (i32),
    /// Reduce = merge counts. Exercises `map_histogram`.
    TeraSort,
}

impl WorkloadKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            WorkloadKind::WordCount => "wordcount",
            WorkloadKind::TeraSort => "terasort",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "wordcount" => Ok(WorkloadKind::WordCount),
            "terasort" => Ok(WorkloadKind::TeraSort),
            other => Err(HetcdcError::InvalidJob(format!(
                "unknown workload '{other}'"
            ))),
        }
    }
}

/// How the Shuffle phase is coded.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShuffleMode {
    /// Paper's scheme: optimal K=3 plan (Lemma 1) or greedy pairing K>3.
    Coded,
    /// Baseline: every needed IV broadcast plainly.
    Uncoded,
}

impl ShuffleMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShuffleMode::Coded => "coded",
            ShuffleMode::Uncoded => "uncoded",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "coded" => Ok(ShuffleMode::Coded),
            "uncoded" => Ok(ShuffleMode::Uncoded),
            other => Err(HetcdcError::InvalidJob(format!(
                "unknown shuffle mode '{other}'"
            ))),
        }
    }
}

#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Number of input files N.
    pub n_files: u64,
    /// IV length T (f32/i32 words per intermediate value).
    pub t: usize,
    /// Workload.
    pub workload: WorkloadKind,
    /// Deterministic data seed.
    pub seed: u64,
    /// WordCount vocabulary size V (ignored by TeraSort).
    pub vocab: usize,
    /// TeraSort keys per file D (ignored by WordCount).
    pub keys_per_file: usize,
}

impl JobSpec {
    pub fn wordcount(n_files: u64) -> Self {
        JobSpec {
            n_files,
            t: 32,
            workload: WorkloadKind::WordCount,
            seed: 0xC0DE,
            vocab: 256,
            keys_per_file: 0,
        }
    }

    pub fn terasort(n_files: u64) -> Self {
        JobSpec {
            n_files,
            t: 32,
            workload: WorkloadKind::TeraSort,
            seed: 0x5027, // "SORT"
            vocab: 0,
            keys_per_file: 512,
        }
    }

    /// IV payload size in bytes (both workloads use 4-byte elements).
    pub fn iv_bytes(&self) -> usize {
        self.t * 4
    }

    pub fn validate(&self, k: usize) -> Result<()> {
        let invalid = |m: &str| Err(HetcdcError::InvalidJob(m.into()));
        if self.n_files == 0 {
            return invalid("n_files must be positive");
        }
        if self.t == 0 {
            return invalid("t must be positive");
        }
        if k < 2 {
            return invalid("need at least 2 nodes");
        }
        match self.workload {
            WorkloadKind::WordCount if self.vocab == 0 => invalid("WordCount needs vocab > 0"),
            WorkloadKind::TeraSort if self.keys_per_file == 0 => {
                invalid("TeraSort needs keys_per_file > 0")
            }
            _ => Ok(()),
        }
    }

    /// JSON form used inside serialized [`crate::engine::Plan`] artifacts.
    /// The seed travels as a hex *string*: JSON numbers are f64 in this
    /// substrate and would silently round u64 seeds above 2^53.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("workload".into(), Json::Str(self.workload.as_str().into()));
        m.insert("n_files".into(), Json::Num(self.n_files as f64));
        m.insert("t".into(), Json::Num(self.t as f64));
        m.insert("seed".into(), Json::Str(format!("{:#x}", self.seed)));
        m.insert("vocab".into(), Json::Num(self.vocab as f64));
        m.insert(
            "keys_per_file".into(),
            Json::Num(self.keys_per_file as f64),
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let bad = |f: &str| HetcdcError::Json(format!("job: missing or invalid '{f}'"));
        let workload = WorkloadKind::parse(
            j.get("workload").and_then(|v| v.as_str()).ok_or_else(|| bad("workload"))?,
        )?;
        // Seed: hex/decimal string (exact), or a plain number for
        // hand-written specs (exact only up to 2^53).
        let seed = match j.get("seed") {
            None => 0,
            Some(Json::Str(s)) => parse_u64_exact(s).ok_or_else(|| bad("seed"))?,
            Some(v) => v.as_usize().ok_or_else(|| bad("seed"))? as u64,
        };
        Ok(JobSpec {
            n_files: j.get("n_files").and_then(|v| v.as_usize()).ok_or_else(|| bad("n_files"))?
                as u64,
            t: j.get("t").and_then(|v| v.as_usize()).ok_or_else(|| bad("t"))?,
            workload,
            seed,
            vocab: j.get("vocab").and_then(|v| v.as_usize()).unwrap_or(0),
            keys_per_file: j.get("keys_per_file").and_then(|v| v.as_usize()).unwrap_or(0),
        })
    }
}

/// Parse a u64 from `"0x"`-prefixed hex or plain decimal, bit-exact.
fn parse_u64_exact(s: &str) -> Option<u64> {
    match s.strip_prefix("0x") {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => s.parse().ok(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_are_valid() {
        assert!(JobSpec::wordcount(12).validate(3).is_ok());
        assert!(JobSpec::terasort(12).validate(3).is_ok());
    }

    #[test]
    fn validation_catches_errors() {
        let mut j = JobSpec::wordcount(12);
        j.vocab = 0;
        assert!(j.validate(3).is_err());
        assert!(JobSpec::wordcount(0).validate(3).is_err());
        assert!(JobSpec::wordcount(12).validate(1).is_err());
        let mut ts = JobSpec::terasort(4);
        ts.keys_per_file = 0;
        assert!(ts.validate(3).is_err());
    }

    #[test]
    fn iv_bytes() {
        assert_eq!(JobSpec::wordcount(1).iv_bytes(), 128);
    }

    #[test]
    fn json_roundtrip() {
        let mut big_seed = JobSpec::terasort(5);
        big_seed.seed = 0x9E37_79B9_7F4A_7C15; // above 2^53: must stay exact
        for job in [JobSpec::wordcount(7), JobSpec::terasort(9), big_seed] {
            let back = JobSpec::from_json(&job.to_json()).unwrap();
            assert_eq!(back.n_files, job.n_files);
            assert_eq!(back.t, job.t);
            assert_eq!(back.workload, job.workload);
            assert_eq!(back.seed, job.seed);
            assert_eq!(back.vocab, job.vocab);
            assert_eq!(back.keys_per_file, job.keys_per_file);
        }
    }

    #[test]
    fn mode_and_workload_parse() {
        assert_eq!(ShuffleMode::parse("coded").unwrap(), ShuffleMode::Coded);
        assert!(ShuffleMode::parse("xor").is_err());
        assert_eq!(
            WorkloadKind::parse("terasort").unwrap(),
            WorkloadKind::TeraSort
        );
        assert!(WorkloadKind::parse("sort").is_err());
    }
}
