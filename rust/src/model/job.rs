//! Job specification: the MapReduce computation to run (§II model).

/// Built-in workloads (DESIGN.md §4 explains the substitutions for the
/// paper's TeraSort / production traces).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Zipf token corpus; Map = feature projection (`W @ counts`, f32),
    /// Reduce = sum. Exercises the `map_project` Pallas/XLA artifact.
    WordCount,
    /// Uniform u32 keys; Map = per-reducer range histogram (i32),
    /// Reduce = merge counts. Exercises `map_histogram`.
    TeraSort,
}

/// How the Shuffle phase is coded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShuffleMode {
    /// Paper's scheme: optimal K=3 plan (Lemma 1) or greedy pairing K>3.
    Coded,
    /// Baseline: every needed IV broadcast plainly.
    Uncoded,
}

#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Number of input files N.
    pub n_files: u64,
    /// IV length T (f32/i32 words per intermediate value).
    pub t: usize,
    /// Workload.
    pub workload: WorkloadKind,
    /// Deterministic data seed.
    pub seed: u64,
    /// WordCount vocabulary size V (ignored by TeraSort).
    pub vocab: usize,
    /// TeraSort keys per file D (ignored by WordCount).
    pub keys_per_file: usize,
}

impl JobSpec {
    pub fn wordcount(n_files: u64) -> Self {
        JobSpec {
            n_files,
            t: 32,
            workload: WorkloadKind::WordCount,
            seed: 0xC0DE,
            vocab: 256,
            keys_per_file: 0,
        }
    }

    pub fn terasort(n_files: u64) -> Self {
        JobSpec {
            n_files,
            t: 32,
            workload: WorkloadKind::TeraSort,
            seed: 0x5027, // "SORT"
            vocab: 0,
            keys_per_file: 512,
        }
    }

    /// IV payload size in bytes (both workloads use 4-byte elements).
    pub fn iv_bytes(&self) -> usize {
        self.t * 4
    }

    pub fn validate(&self, k: usize) -> Result<(), String> {
        if self.n_files == 0 {
            return Err("n_files must be positive".into());
        }
        if self.t == 0 {
            return Err("t must be positive".into());
        }
        if k < 2 {
            return Err("need at least 2 nodes".into());
        }
        match self.workload {
            WorkloadKind::WordCount if self.vocab == 0 => {
                Err("WordCount needs vocab > 0".into())
            }
            WorkloadKind::TeraSort if self.keys_per_file == 0 => {
                Err("TeraSort needs keys_per_file > 0".into())
            }
            _ => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_are_valid() {
        assert!(JobSpec::wordcount(12).validate(3).is_ok());
        assert!(JobSpec::terasort(12).validate(3).is_ok());
    }

    #[test]
    fn validation_catches_errors() {
        let mut j = JobSpec::wordcount(12);
        j.vocab = 0;
        assert!(j.validate(3).is_err());
        assert!(JobSpec::wordcount(0).validate(3).is_err());
        assert!(JobSpec::wordcount(12).validate(1).is_err());
        let mut ts = JobSpec::terasort(4);
        ts.keys_per_file = 0;
        assert!(ts.validate(3).is_err());
    }

    #[test]
    fn iv_bytes() {
        assert_eq!(JobSpec::wordcount(1).iv_bytes(), 128);
    }
}
