//! Two-phase **revised** primal simplex: hybrid Dantzig/Bland pricing, a
//! Harris-style two-pass ratio test, and periodic basis refactorization.
//!
//! Generic over [`Scalar`], so the same code runs in `f64` (production) and
//! exact rationals (test oracle). Solves
//!
//! ```text
//! min c'x  s.t.  A x {<=,=,>=} b,  x >= 0
//! ```
//!
//! The constraint matrix is stored as **sparse columns** and the basis
//! inverse as a **product-form eta file**: every pivot appends one eta
//! vector instead of rewriting a dense `rows × cols` tableau. Each
//! iteration prices by the factorization —
//!
//! * BTRAN: `y = c_B B⁻¹` (apply etas newest-first to the basis costs),
//! * reduced cost `d_j = c_j − y·A_j` per sparse column,
//! * FTRAN: `w = B⁻¹ A_e` for the entering column's ratio test,
//!
//! so per-pivot work is `O(nnz(etas) + nnz(A))` instead of the dense
//! rewrite's `O(rows · cols)`. [`Solution::eta_applications`] counts the
//! scalar work actually spent in eta applications and
//! [`Solution::dense_cells`] the counterfactual cells a dense per-pivot
//! rewrite would have touched, so callers assert the speedup in
//! deterministic counters rather than wall clock.
//!
//! **Pricing** is Dantzig's rule — the most negative reduced cost enters,
//! ties broken toward the lowest index with the exact comparison
//! [`Scalar::lt`] — under an anti-stall governor: after [`STALL_WINDOW`]
//! consecutive degenerate pivots the solve falls back to Bland's rule
//! (lowest-index entering *and* leaving) until a non-degenerate pivot
//! lands. Bland's theorem rules out cycling while the governor is
//! engaged and every non-degenerate pivot strictly improves the
//! objective, so the solve is finite; outside stalls, Dantzig keeps the
//! pivot count far below pure Bland's on degenerate §V masters.
//!
//! **Leaving** uses a Harris-style two-pass ratio test: pass 1 finds the
//! minimum ratio `θ`, pass 2 picks, among rows within tolerance of `θ`,
//! the row with the largest pivot magnitude (tie → smallest basis
//! index). Large pivots keep the eta file well-conditioned; in exact
//! arithmetic the tolerance band degenerates to exact ties and the test
//! stays deterministic.
//!
//! A **ray guard** protects the unboundedness check: when the entering
//! column's FTRAN direction has no positive entry but its reduced cost is
//! within [`super::problem::F64_RAY_TOL`] of zero
//! ([`Scalar::is_ray_noise`]), the column is rounding noise — e.g. the
//! negated twin of a basic free-variable pair — not a certified ray; it
//! is skipped for the current pricing round instead of aborting the
//! solve. Exact scalars never take this path.
//!
//! The eta file is **refactorized** whenever it outgrows
//! `max(64, 2·rows)` etas: the basis columns are re-eliminated in basis
//! order (pivot row = largest magnitude among unplaced rows, lowest index
//! on ties) and the basic solution recomputed from the stored rhs, so
//! FTRAN/BTRAN cost stays proportional to the basis size instead of the
//! pivot history. Reinversion is triggered by eta *count* and pivots by
//! magnitude, so it is deterministic at every thread count.
//!
//! Phase 1 minimizes the sum of artificial variables to find a basic
//! feasible solution; phase 2 optimizes the real objective.
//!
//! [`solve_with_threads`] shards the pricing scan over contiguous column
//! chunks on scoped worker threads. The dual vector `y` is computed
//! **once per iteration** before any fan-out, each chunk reports its own
//! best `(reduced cost, column)` pair, and the lexicographic minimum wins
//! — an associative merge, so the entering column (and therefore the
//! entire pivot sequence, eta file, and solution) is **bit-identical** to
//! the serial scan for every thread count. Per-column arithmetic is
//! shared between the serial and sharded paths (same fold order over the
//! same sparse entries), so chunking cannot perturb a single float.

use super::problem::{Cmp, Lp, Scalar};

/// Entering-variable pricing floor: below this many candidate columns a
/// sharded scan costs more in thread spawns than it saves.
const PAR_MIN_COLS: usize = 128;

/// Anti-stall governor: after this many consecutive degenerate pivots
/// (ratio-test minimum of zero), pricing falls back to Bland's rule until
/// a non-degenerate pivot resets the counter.
const STALL_WINDOW: usize = 16;

#[derive(Clone, Debug, PartialEq)]
pub enum LpError {
    Infeasible,
    Unbounded,
    /// Reinversion could not re-eliminate the basis columns (every
    /// remaining pivot candidate was below tolerance). Exact arithmetic
    /// never produces this; in `f64` it flags an eta file degraded past
    /// recovery.
    Singular,
}

impl std::fmt::Display for LpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LpError::Infeasible => write!(f, "LP is infeasible"),
            LpError::Unbounded => write!(f, "LP is unbounded"),
            LpError::Singular => write!(f, "numerically singular basis at reinversion"),
        }
    }
}

impl std::error::Error for LpError {}

#[derive(Clone, Debug)]
pub struct Solution<S> {
    pub objective: S,
    /// Values of the original variables.
    pub values: Vec<S>,
    /// Simplex pivots performed (both phases) — used by bench_simplex.
    pub pivots: usize,
    /// Scalar multiply-add slots touched applying eta vectors across all
    /// FTRAN/BTRAN passes and basic-solution updates — the revised
    /// simplex's actual factorization work, deterministic at every
    /// thread count.
    pub eta_applications: u64,
    /// Counterfactual: the cells a dense-tableau solver's per-pivot
    /// `O(rows · cols)` rewrite would have touched over the same pivot
    /// sequence (`pivots × rows × cols`). Compare against
    /// [`Solution::eta_applications`] to assert the factorization did
    /// strictly less work.
    pub dense_cells: u64,
    /// Eta-file refactorizations performed (deterministic: triggered by
    /// eta count alone).
    pub reinversions: usize,
    /// Dual value per input constraint at phase-2 optimality
    /// (`y = c_B B⁻¹`, sign-corrected for rows the rhs normalization
    /// flipped, so signs refer to the constraints as given). Under
    /// minimization a binding `<=` row has `y <= 0`, a `>=` row
    /// `y >= 0`; reduced costs `c_j − y·A_j` are `>= 0` for every
    /// column within scalar tolerance.
    pub duals: Vec<S>,
}

/// One product-form eta vector: `B⁻¹_new = E · B⁻¹_old` where `E` is the
/// identity except for column `r`, holding `1/w_r` on the diagonal and
/// `−w_i/w_r` off it (`w` = the FTRAN'd entering column).
struct Eta<S> {
    r: u32,
    diag: S,
    /// Off-diagonal entries `(row, −w_row/w_r)`, ascending row order.
    rest: Vec<(u32, S)>,
}

/// Revised-simplex state: sparse columns + eta-file basis factorization.
struct Revised<S> {
    /// Sparse columns (ascending row order), structural then
    /// slack/surplus then artificial. No rhs column — see `b_vals`.
    cols: Vec<Vec<(u32, S)>>,
    /// Product-form representation of `B⁻¹`, oldest first.
    etas: Vec<Eta<S>>,
    /// Basis variable per row.
    basis: Vec<usize>,
    /// Whether each column is currently basic (pricing skips these:
    /// their reduced cost is exactly zero in exact arithmetic, and
    /// skipping keeps float drift from ever re-entering one).
    in_basis: Vec<bool>,
    /// Current basic solution, by row (`x_B = B⁻¹ b`).
    b_vals: Vec<S>,
    /// Normalized right-hand side as of basis construction, so
    /// reinversion can recompute `x_B = B⁻¹ b` from scratch.
    rhs0: Vec<S>,
    rows: usize,
    /// Scalar slots touched by eta applications (see
    /// [`Solution::eta_applications`]).
    eta_ops: u64,
    /// Refactorize once the eta file exceeds this many etas.
    reinvert_every: usize,
    reinversions: usize,
}

impl<S: Scalar> Revised<S> {
    /// Scatter column `j` into a dense vector.
    fn dense_col(&self, j: usize) -> Vec<S> {
        let mut x = vec![S::zero(); self.rows];
        for (r, a) in &self.cols[j] {
            x[*r as usize] = a.clone();
        }
        x
    }

    /// FTRAN: overwrite `x` with `B⁻¹ x` by applying the eta file oldest
    /// first. Skips etas whose pivot-row entry is zero (the usual
    /// sparse-column fast path; deterministic — the skip depends only on
    /// the vector, never on thread count).
    fn ftran(&mut self, x: &mut [S]) {
        for eta in &self.etas {
            let t = x[eta.r as usize].clone();
            if t.is_zero() {
                continue;
            }
            x[eta.r as usize] = t.mul(&eta.diag);
            for (i, v) in &eta.rest {
                x[*i as usize] = x[*i as usize].add(&t.mul(v));
            }
            self.eta_ops += 1 + eta.rest.len() as u64;
        }
    }

    /// BTRAN: overwrite `y` with `y B⁻¹` by applying the eta file newest
    /// first; each eta only rewrites `y[r] = y·E_col(r)`, folded diagonal
    /// term first then off-diagonals in ascending row order.
    fn btran(&mut self, y: &mut [S]) {
        for eta in self.etas.iter().rev() {
            let mut acc = y[eta.r as usize].mul(&eta.diag);
            for (i, v) in &eta.rest {
                let yi = &y[*i as usize];
                if !yi.is_zero() {
                    acc = acc.add(&yi.mul(v));
                }
            }
            y[eta.r as usize] = acc;
            self.eta_ops += 1 + eta.rest.len() as u64;
        }
    }

    /// Simplex multipliers for `cost`: `y = c_B B⁻¹`.
    fn multipliers(&mut self, cost: &[S]) -> Vec<S> {
        let mut y: Vec<S> = self.basis.iter().map(|&b| cost[b].clone()).collect();
        self.btran(&mut y);
        y
    }

    /// Row `i` of `B⁻¹` (BTRAN of the unit vector), for the phase-1
    /// artificial drive-out.
    fn inverse_row(&mut self, i: usize) -> Vec<S> {
        let mut rho = vec![S::zero(); self.rows];
        rho[i] = S::one();
        self.btran(&mut rho);
        rho
    }

    /// Reduced cost `c_j − y·A_j`, folded over the sparse column in
    /// ascending row order, skipping zero multipliers. The serial and
    /// sharded pricing scans both call this, so chunking cannot change a
    /// bit of any column's value.
    fn reduced_cost(&self, y: &[S], cost: &[S], j: usize) -> S {
        let mut zj = S::zero();
        for (r, a) in &self.cols[j] {
            let yr = &y[*r as usize];
            if !yr.is_zero() {
                zj = zj.add(&yr.mul(a));
            }
        }
        cost[j].sub(&zj)
    }

    /// Bland pricing: the first non-basic, non-skipped column in
    /// `0..limit` with negative reduced cost under the (per-iteration,
    /// thread-independent) multipliers `y`, or `None` at optimality.
    /// `threads > 1` shards the scan over contiguous column chunks on
    /// scoped workers; each chunk reports its own first hit and the
    /// lowest index wins regardless of chunking, so the entering column
    /// equals the serial scan's.
    ///
    /// Bland's rule usually enters at a low index, so the first chunk is
    /// scanned serially before paying for any thread spawn — most pivots
    /// resolve without fanning out, and the fan-out (which cannot early-
    /// exit across chunks) only runs when the low columns are all priced
    /// out. Either path computes each column identically, so the result
    /// is the same column (or None) in every configuration.
    fn price_bland(
        &self,
        y: &[S],
        cost: &[S],
        limit: usize,
        threads: usize,
        skipped: &[usize],
    ) -> Option<(usize, S)> {
        let candidate = |j: &usize| {
            !self.in_basis[*j]
                && !skipped.contains(j)
                && self.reduced_cost(y, cost, *j).is_neg()
        };
        let j = if threads <= 1 || limit < PAR_MIN_COLS {
            (0..limit).find(candidate)
        } else {
            let workers = threads.min(limit);
            let chunk = limit.div_ceil(workers);
            if let Some(j) = (0..chunk).find(candidate) {
                Some(j)
            } else {
                let mut firsts: Vec<Option<usize>> = vec![None; workers - 1];
                // lint: allow(unordered-merge): each worker writes its own chunk slot; min() over slots is finish-order independent
                std::thread::scope(|s| {
                    for (w, slot) in firsts.iter_mut().enumerate() {
                        let lo = (w + 1) * chunk;
                        let hi = ((w + 2) * chunk).min(limit);
                        let this = &*self;
                        s.spawn(move || {
                            *slot = (lo..hi).find(|j| {
                                !this.in_basis[*j]
                                    && !skipped.contains(j)
                                    && this.reduced_cost(y, cost, *j).is_neg()
                            });
                        });
                    }
                });
                firsts.into_iter().flatten().min()
            }
        }?;
        Some((j, self.reduced_cost(y, cost, j)))
    }

    /// One contiguous chunk of the Dantzig pricing scan: the most
    /// negative reduced cost in `lo..hi` as a `(rc, column)` pair, ties
    /// broken toward the lower column by the ascending scan order. Both
    /// the serial path and every worker chunk run exactly this code.
    fn scan_dantzig(
        &self,
        y: &[S],
        cost: &[S],
        lo: usize,
        hi: usize,
        skipped: &[usize],
    ) -> Option<(S, usize)> {
        let mut best: Option<(S, usize)> = None;
        for j in lo..hi {
            if self.in_basis[j] || skipped.contains(&j) {
                continue;
            }
            let rc = self.reduced_cost(y, cost, j);
            if rc.is_neg() {
                let better = match &best {
                    None => true,
                    Some((brc, _)) => rc.lt(brc),
                };
                if better {
                    best = Some((rc, j));
                }
            }
        }
        best
    }

    /// Dantzig pricing: the most negative reduced cost enters (tie →
    /// lowest column index), or `None` at optimality. The tie-break uses
    /// the exact comparison [`Scalar::lt`] — a tolerance-based one is not
    /// associative, so chunk merges could disagree with a serial scan.
    /// Unlike Bland, Dantzig needs the full scan every iteration, so
    /// `threads > 1` shards all of `0..limit` (first chunk on the calling
    /// thread) and folds the chunk winners with the lexicographic
    /// `(rc, j)` minimum, which is associative and therefore
    /// chunking-independent.
    fn price_dantzig(
        &self,
        y: &[S],
        cost: &[S],
        limit: usize,
        threads: usize,
        skipped: &[usize],
    ) -> Option<(usize, S)> {
        let merged = if threads <= 1 || limit < PAR_MIN_COLS {
            self.scan_dantzig(y, cost, 0, limit, skipped)
        } else {
            let workers = threads.min(limit);
            let chunk = limit.div_ceil(workers);
            let mut bests: Vec<Option<(S, usize)>> = vec![None; workers - 1];
            // lint: allow(unordered-merge): each worker writes its own chunk slot; the lexicographic (rc, j) fold below is associative and finish-order independent
            let first = std::thread::scope(|s| {
                for (w, slot) in bests.iter_mut().enumerate() {
                    let lo = (w + 1) * chunk;
                    let hi = ((w + 2) * chunk).min(limit);
                    let this = &*self;
                    s.spawn(move || {
                        *slot = this.scan_dantzig(y, cost, lo, hi, skipped);
                    });
                }
                self.scan_dantzig(y, cost, 0, chunk, skipped)
            });
            let mut best = first;
            for b in bests.into_iter().flatten() {
                let better = match &best {
                    None => true,
                    Some((brc, bj)) => b.0.lt(brc) || (!brc.lt(&b.0) && b.1 < *bj),
                };
                if better {
                    best = Some(b);
                }
            }
            best
        };
        merged.map(|(rc, j)| (j, rc))
    }

    /// Build the eta vector that pivots row `r` on the FTRAN'd entering
    /// column `w` (shared by [`Revised::pivot`] and
    /// [`Revised::reinvert`]).
    fn make_eta(&self, r: usize, w: &[S]) -> Eta<S> {
        let piv = w[r].clone();
        debug_assert!(!piv.is_zero());
        let diag = S::one().div(&piv);
        let mut rest = Vec::new();
        for (i, wi) in w.iter().enumerate() {
            if i != r && !wi.is_zero() {
                rest.push((i as u32, wi.div(&piv).neg()));
            }
        }
        Eta {
            r: r as u32,
            diag,
            rest,
        }
    }

    /// Pivot column `c` into the basis at row `r`: append the eta built
    /// from the FTRAN'd entering column `w` and update the basic
    /// solution through it (the same arithmetic every later FTRAN sees).
    /// Refactorizes when the eta file outgrows `reinvert_every`.
    fn pivot(&mut self, r: usize, c: usize, w: &[S]) -> Result<(), LpError> {
        let eta = self.make_eta(r, w);
        // Update x_B by applying the new eta (skip-free: the pivot row's
        // value may be zero on degenerate pivots, and the update must
        // still install it).
        let t = self.b_vals[eta.r as usize].clone();
        self.b_vals[eta.r as usize] = t.mul(&eta.diag);
        for (i, v) in &eta.rest {
            self.b_vals[*i as usize] = self.b_vals[*i as usize].add(&t.mul(v));
        }
        self.eta_ops += 1 + eta.rest.len() as u64;
        self.in_basis[self.basis[r]] = false;
        self.in_basis[c] = true;
        self.basis[r] = c;
        self.etas.push(eta);
        if self.etas.len() > self.reinvert_every {
            self.reinvert()?;
        }
        Ok(())
    }

    /// Refactorize: rebuild the eta file from the current basis columns
    /// (Gaussian elimination in basis order, pivot row = the largest
    /// magnitude among unplaced rows, lowest index on ties), then
    /// recompute `x_B` from the stored rhs. The rebuilt file represents
    /// the same `B⁻¹` in `O(rows)` etas regardless of how many pivots
    /// produced the old one. Deterministic: triggered by eta count,
    /// pivots chosen by (magnitude, index).
    fn reinvert(&mut self) -> Result<(), LpError> {
        self.reinversions += 1;
        let cols_in = self.basis.clone();
        self.etas.clear();
        let mut placed = vec![false; self.rows];
        let mut new_basis = vec![0usize; self.rows];
        for c in cols_in {
            let mut w = self.dense_col(c);
            self.ftran(&mut w);
            let mut best: Option<(S, usize)> = None;
            for (i, wi) in w.iter().enumerate() {
                if placed[i] {
                    continue;
                }
                let a = if wi.is_neg() { wi.neg() } else { wi.clone() };
                if a.is_pos() {
                    let better = match &best {
                        None => true,
                        Some((ba, _)) => ba.lt(&a),
                    };
                    if better {
                        best = Some((a, i));
                    }
                }
            }
            let Some((_, r)) = best else {
                return Err(LpError::Singular);
            };
            let eta = self.make_eta(r, &w);
            self.etas.push(eta);
            placed[r] = true;
            new_basis[r] = c;
        }
        self.basis = new_basis;
        let mut b = self.rhs0.clone();
        self.ftran(&mut b);
        self.b_vals = b;
        Ok(())
    }

    /// Minimize `cost` over the columns `0..limit` starting from the
    /// current basis, pricing with up to `threads` workers. Returns
    /// (objective value, pivots) or Unbounded/Singular.
    fn optimize(
        &mut self,
        cost: &[S],
        limit: usize,
        threads: usize,
    ) -> Result<(S, usize), LpError> {
        let mut pivots = 0usize;
        let mut stall = 0usize;
        // Ray-guard skip list: columns whose noise-level reduced cost
        // produced a nonpositive FTRAN direction this pricing round.
        // Cleared on every pivot, so it stays tiny; membership tests
        // only, so a Vec suffices.
        let mut skipped: Vec<usize> = Vec::new();
        loop {
            let y = self.multipliers(cost);
            let governed = stall >= STALL_WINDOW;
            let priced = if governed {
                self.price_bland(&y, cost, limit, threads, &skipped)
            } else {
                self.price_dantzig(&y, cost, limit, threads, &skipped)
            };
            let Some((c, rc)) = priced else {
                // Optimal: objective = sum_i cost[basis[i]] * x_B[i].
                let mut obj = S::zero();
                for i in 0..self.rows {
                    obj = obj.add(&cost[self.basis[i]].mul(&self.b_vals[i]));
                }
                return Ok((obj, pivots));
            };
            let mut w = self.dense_col(c);
            self.ftran(&mut w);
            // Harris two-pass ratio test. Pass 1: minimum ratio θ.
            let mut theta: Option<S> = None;
            for (wi, bi) in w.iter().zip(&self.b_vals) {
                if wi.is_pos() {
                    let ratio = bi.div(wi);
                    let better = match &theta {
                        None => true,
                        Some(t) => ratio.lt(t),
                    };
                    if better {
                        theta = Some(ratio);
                    }
                }
            }
            let Some(theta) = theta else {
                // Ray guard: a noise-level reduced cost (e.g. the negated
                // twin of a basic free-variable pair) whose direction has
                // no positive entry is not a certified ray; exclude the
                // column for this round and re-price.
                if rc.is_ray_noise() {
                    skipped.push(c);
                    continue;
                }
                return Err(LpError::Unbounded);
            };
            // Pass 2: among rows within tolerance of θ, the largest
            // pivot magnitude leaves (tie → smallest basis index). Under
            // the governor, Bland's leaving rule instead: the smallest
            // basis index among qualifying rows, completing Bland's
            // anti-cycling pair.
            let mut leave: Option<usize> = None;
            let mut best_piv = S::zero();
            for (i, wi) in w.iter().enumerate() {
                if !wi.is_pos() {
                    continue;
                }
                let ratio = self.b_vals[i].div(wi);
                if ratio.sub(&theta).is_pos() {
                    continue;
                }
                let better = match leave {
                    None => true,
                    Some(l) => {
                        if governed {
                            self.basis[i] < self.basis[l]
                        } else {
                            best_piv.lt(wi) || (!wi.lt(&best_piv) && self.basis[i] < self.basis[l])
                        }
                    }
                };
                if better {
                    leave = Some(i);
                    best_piv = wi.clone();
                }
            }
            let Some(r) = leave else {
                // Unreachable: the row attaining θ always qualifies.
                return Err(LpError::Singular);
            };
            if theta.is_pos() {
                stall = 0;
            } else {
                stall += 1;
            }
            self.pivot(r, c, &w)?;
            skipped.clear();
            pivots += 1;
        }
    }
}

/// Solve the LP serially. See module docs.
pub fn solve<S: Scalar>(lp: &Lp<S>) -> Result<Solution<S>, LpError> {
    solve_inner(lp, 1, 0)
}

/// Solve the LP with the pricing scan sharded across up to `threads`
/// scoped workers (`<= 1` = serial). The returned basis, objective,
/// values, duals, and every work counter are **bit-identical** to
/// [`solve`] for every thread count — sharding changes wall-clock only.
pub fn solve_with_threads<S: Scalar>(lp: &Lp<S>, threads: usize) -> Result<Solution<S>, LpError> {
    solve_inner(lp, threads, 0)
}

/// Shared implementation; `reinvert_every == 0` selects the default
/// refactorization period `max(64, 2·rows)` (tests pass a small value to
/// exercise reinversion on small LPs).
fn solve_inner<S: Scalar>(
    lp: &Lp<S>,
    threads: usize,
    reinvert_every: usize,
) -> Result<Solution<S>, LpError> {
    let n = lp.n_vars;
    let m = lp.constraints.len();
    let reinvert_every = if reinvert_every == 0 {
        64.max(2 * m)
    } else {
        reinvert_every
    };

    // Column layout: [original n] [slack/surplus per row as needed]
    // [artificials]. Rows are normalized so rhs >= 0 (flipping the
    // comparison when the input rhs was negative); `flipped` remembers
    // which, so the reported duals keep the caller's row orientation.
    let mut n_slack = 0usize;
    for c in &lp.constraints {
        if matches!(c.cmp, Cmp::Le | Cmp::Ge) {
            n_slack += 1;
        }
    }
    let mut rows: Vec<(Vec<(usize, S)>, Cmp, S)> = Vec::with_capacity(m);
    let mut flipped = vec![false; m];
    for (i, c) in lp.constraints.iter().enumerate() {
        // Merge duplicate variable mentions (ascending variable order so
        // column entries come out in a canonical order).
        let mut merged: std::collections::BTreeMap<usize, S> = std::collections::BTreeMap::new();
        for (v, a) in &c.coeffs {
            let slot = merged.entry(*v).or_insert_with(S::zero);
            *slot = slot.add(a);
        }
        let (coeffs, cmp, rhs) = if c.rhs.is_neg() {
            flipped[i] = true;
            let f = match c.cmp {
                Cmp::Le => Cmp::Ge,
                Cmp::Ge => Cmp::Le,
                Cmp::Eq => Cmp::Eq,
            };
            (
                merged.into_iter().map(|(v, a)| (v, a.neg())).collect(),
                f,
                c.rhs.neg(),
            )
        } else {
            (merged.into_iter().collect(), c.cmp, c.rhs.clone())
        };
        rows.push((coeffs, cmp, rhs));
    }

    let mut n_artif = 0usize;
    for (_, cmp, _) in &rows {
        if matches!(cmp, Cmp::Ge | Cmp::Eq) {
            n_artif += 1;
        }
    }
    let total = n + n_slack + n_artif;
    let artif_start = n + n_slack;

    let mut cols: Vec<Vec<(u32, S)>> = vec![Vec::new(); total];
    let mut basis = vec![0usize; m];
    let mut in_basis = vec![false; total];
    let mut b_vals = Vec::with_capacity(m);
    let mut slack_idx = n;
    let mut artif_idx = artif_start;
    for (i, (coeffs, cmp, rhs)) in rows.iter().enumerate() {
        for (v, a) in coeffs {
            if !a.is_zero() {
                cols[*v].push((i as u32, a.clone()));
            }
        }
        b_vals.push(rhs.clone());
        match cmp {
            Cmp::Le => {
                cols[slack_idx].push((i as u32, S::one()));
                basis[i] = slack_idx;
                slack_idx += 1;
            }
            Cmp::Ge => {
                cols[slack_idx].push((i as u32, S::one().neg()));
                slack_idx += 1;
                cols[artif_idx].push((i as u32, S::one()));
                basis[i] = artif_idx;
                artif_idx += 1;
            }
            Cmp::Eq => {
                cols[artif_idx].push((i as u32, S::one()));
                basis[i] = artif_idx;
                artif_idx += 1;
            }
        }
        in_basis[basis[i]] = true;
    }

    let rhs0 = b_vals.clone();
    let mut rev = Revised {
        cols,
        etas: Vec::new(),
        basis,
        in_basis,
        b_vals,
        rhs0,
        rows: m,
        eta_ops: 0,
        reinvert_every,
        reinversions: 0,
    };

    let mut total_pivots = 0usize;

    // Phase 1.
    if n_artif > 0 {
        let mut cost1 = vec![S::zero(); total];
        for item in cost1.iter_mut().take(total).skip(artif_start) {
            *item = S::one();
        }
        let (obj1, p1) = rev.optimize(&cost1, total, threads)?;
        total_pivots += p1;
        if obj1.is_pos() {
            return Err(LpError::Infeasible);
        }
        // Drive any artificial still in the basis out (degenerate rows):
        // row i of B⁻¹A is priced per column via one BTRAN of e_i, and
        // the first real column with a nonzero entry pivots in.
        for i in 0..m {
            if rev.basis[i] >= artif_start {
                let rho = rev.inverse_row(i);
                let mut found = None;
                for j in 0..artif_start {
                    if !rev.in_basis[j] {
                        let mut entry = S::zero();
                        for (r, a) in &rev.cols[j] {
                            let rr = &rho[*r as usize];
                            if !rr.is_zero() {
                                entry = entry.add(&rr.mul(a));
                            }
                        }
                        if !entry.is_zero() {
                            found = Some(j);
                            break;
                        }
                    }
                }
                if let Some(j) = found {
                    let mut w = rev.dense_col(j);
                    rev.ftran(&mut w);
                    rev.pivot(i, j, &w)?;
                    total_pivots += 1;
                }
                // else: the row is all-zero over real columns — redundant
                // constraint; leave the artificial basic at value 0.
            }
        }
    }

    // Phase 2: minimize real objective; artificial columns are barred.
    let mut cost2 = vec![S::zero(); total];
    for j in 0..n {
        cost2[j] = lp.objective[j].clone();
    }
    let (obj, p2) = rev.optimize(&cost2, artif_start, threads)?;
    total_pivots += p2;

    let mut values = vec![S::zero(); n];
    for i in 0..m {
        if rev.basis[i] < n {
            values[rev.basis[i]] = rev.b_vals[i].clone();
        }
    }
    // Duals at optimality, restored to the caller's row orientation.
    let mut duals = rev.multipliers(&cost2);
    for (i, f) in flipped.iter().enumerate() {
        if *f {
            duals[i] = duals[i].neg();
        }
    }
    let dense_cells = total_pivots as u64 * m as u64 * (total as u64 + 1);
    Ok(Solution {
        objective: obj,
        values,
        pivots: total_pivots,
        eta_applications: rev.eta_ops,
        dense_cells,
        reinversions: rev.reinversions,
        duals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::rational::Rat;
    use crate::prop;

    fn lp_f64() -> Lp<f64> {
        Lp::new()
    }

    #[test]
    fn simple_minimization() {
        // min x + y s.t. x + y >= 4, x <= 3 -> obj 4.
        let mut lp = lp_f64();
        let x = lp.add_var("x", 1.0);
        let y = lp.add_var("y", 1.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 4.0);
        lp.constrain(vec![(x, 1.0)], Cmp::Le, 3.0);
        let sol = solve(&lp).unwrap();
        assert!((sol.objective - 4.0).abs() < 1e-9);
        assert!(lp.is_feasible(&sol.values));
    }

    #[test]
    fn maximization_via_negated_cost() {
        // max 3x + 2y s.t. x + y <= 4, x + 3y <= 6 -> x=4, y=0, obj 12.
        let mut lp = lp_f64();
        let x = lp.add_var("x", -3.0);
        let y = lp.add_var("y", -2.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Le, 4.0);
        lp.constrain(vec![(x, 1.0), (y, 3.0)], Cmp::Le, 6.0);
        let sol = solve(&lp).unwrap();
        assert!((sol.objective + 12.0).abs() < 1e-9);
        assert!((sol.values[0] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn equality_constraints() {
        // min 2x + 3y s.t. x + y = 10, x - y = 2 -> x=6, y=4, obj 24.
        let mut lp = lp_f64();
        let x = lp.add_var("x", 2.0);
        let y = lp.add_var("y", 3.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 10.0);
        lp.constrain(vec![(x, 1.0), (y, -1.0)], Cmp::Eq, 2.0);
        let sol = solve(&lp).unwrap();
        assert!((sol.objective - 24.0).abs() < 1e-9);
        assert!((sol.values[0] - 6.0).abs() < 1e-9);
        assert!((sol.values[1] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn detects_infeasible() {
        let mut lp = lp_f64();
        let x = lp.add_var("x", 1.0);
        lp.constrain(vec![(x, 1.0)], Cmp::Le, 1.0);
        lp.constrain(vec![(x, 1.0)], Cmp::Ge, 2.0);
        assert_eq!(solve(&lp).unwrap_err(), LpError::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        // max x with no upper bound: the entering column's reduced cost
        // is -1, far below the ray-noise tolerance, so the ray guard
        // must not swallow the genuine ray.
        let mut lp = lp_f64();
        let x = lp.add_var("x", -1.0);
        lp.constrain(vec![(x, 1.0)], Cmp::Ge, 0.0);
        assert_eq!(solve(&lp).unwrap_err(), LpError::Unbounded);
    }

    #[test]
    fn negative_rhs_normalization() {
        // x - y <= -2 with min x -> x=0, y>=2 feasible; obj 0.
        let mut lp = lp_f64();
        let x = lp.add_var("x", 1.0);
        let y = lp.add_var("y", 0.0);
        lp.constrain(vec![(x, 1.0), (y, -1.0)], Cmp::Le, -2.0);
        let sol = solve(&lp).unwrap();
        assert!(sol.objective.abs() < 1e-9);
        assert!(lp.is_feasible(&sol.values));
    }

    #[test]
    fn redundant_equalities_ok() {
        // x + y = 4 twice (redundant) — phase 1 leaves a zero artificial.
        let mut lp = lp_f64();
        let x = lp.add_var("x", 1.0);
        let y = lp.add_var("y", 1.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 4.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 4.0);
        let sol = solve(&lp).unwrap();
        assert!((sol.objective - 4.0).abs() < 1e-9);
    }

    #[test]
    fn exact_rational_solver_agrees() {
        // Same LP in both fields; rational is the oracle.
        let mut lpf = lp_f64();
        let mut lpr: Lp<Rat> = Lp::new();
        let xf = lpf.add_var("x", 1.0);
        let yf = lpf.add_var("y", 3.0);
        let xr = lpr.add_var("x", Rat::int(1));
        let yr = lpr.add_var("y", Rat::int(3));
        lpf.constrain(vec![(xf, 2.0), (yf, 1.0)], Cmp::Ge, 5.0);
        lpr.constrain(vec![(xr, Rat::int(2)), (yr, Rat::int(1))], Cmp::Ge, Rat::int(5));
        lpf.constrain(vec![(xf, 1.0)], Cmp::Le, 2.0);
        lpr.constrain(vec![(xr, Rat::int(1))], Cmp::Le, Rat::int(2));
        let sf = solve(&lpf).unwrap();
        let sr = solve(&lpr).unwrap();
        assert!((sf.objective - sr.objective.to_f64()).abs() < 1e-9);
        // optimum: x=2, y=1 -> obj 5.
        assert_eq!(sr.objective, Rat::int(5));
    }

    #[test]
    fn duals_price_the_binding_constraints() {
        // min x + y s.t. x + y >= 4, x <= 3: only the >= row binds the
        // optimum, so its shadow price is 1 and the slack row's is 0.
        // Dual feasibility must hold for every column.
        let mut lp = lp_f64();
        let x = lp.add_var("x", 1.0);
        let y = lp.add_var("y", 1.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 4.0);
        lp.constrain(vec![(x, 1.0)], Cmp::Le, 3.0);
        let sol = solve(&lp).unwrap();
        assert!((sol.duals[0] - 1.0).abs() < 1e-9, "duals {:?}", sol.duals);
        assert!(sol.duals[1].abs() < 1e-9, "duals {:?}", sol.duals);
        // Reduced costs c_j − y·A_j >= 0 for both structural columns.
        let rc_x = 1.0 - (sol.duals[0] + sol.duals[1]);
        let rc_y = 1.0 - sol.duals[0];
        assert!(rc_x > -1e-9 && rc_y > -1e-9);
    }

    #[test]
    fn duals_keep_caller_row_orientation_after_rhs_flip() {
        // x − y <= −2 is normalized to −x + y >= 2 internally; the
        // reported dual must still carry the <=-row sign (y <= 0 under
        // minimization). min y s.t. x − y <= −2 -> y = 2, dual = −1.
        let mut lp = lp_f64();
        let _x = lp.add_var("x", 0.0);
        let y = lp.add_var("y", 1.0);
        lp.constrain(vec![(0, 1.0), (y, -1.0)], Cmp::Le, -2.0);
        let sol = solve(&lp).unwrap();
        assert!((sol.objective - 2.0).abs() < 1e-9);
        assert!((sol.duals[0] + 1.0).abs() < 1e-9, "duals {:?}", sol.duals);
    }

    #[test]
    fn eta_work_undercuts_the_dense_counterfactual() {
        // On a wide LP the factorization's actual scalar work must come
        // in strictly under the dense rewrite's pivots × rows × cols —
        // the counter pair the bench suite asserts on.
        let mut lp = lp_f64();
        let n = 2 * PAR_MIN_COLS;
        for v in 0..n {
            let c = ((v * 7) % 5) as f64 - 2.0;
            lp.add_var(format!("v{v}"), c);
        }
        for v in 0..n {
            lp.constrain(vec![(v, 1.0)], Cmp::Le, 3.0);
        }
        let coupling: Vec<(usize, f64)> = (0..n).map(|v| (v, 1.0)).collect();
        lp.constrain(coupling, Cmp::Ge, 5.0);
        let sol = solve(&lp).unwrap();
        assert!(sol.pivots > 0);
        // rows = n + 1; columns = n structural + (n+1) slack + 1
        // artificial + rhs = 2n + 3.
        assert_eq!(
            sol.dense_cells,
            sol.pivots as u64 * (n as u64 + 1) * (2 * n as u64 + 3)
        );
        assert!(
            sol.eta_applications < sol.dense_cells,
            "eta work {} >= dense counterfactual {}",
            sol.eta_applications,
            sol.dense_cells
        );
    }

    #[test]
    fn sharded_pricing_is_bit_identical_to_serial() {
        // Wide LP (past the PAR_MIN_COLS floor) so the sharded scan
        // actually engages: the basis walk, objective, values, duals,
        // and work counters must match the serial solve bit for bit at
        // every thread count — the lexicographic (rc, column) chunk
        // merge is associative, so chunking cannot change the entering
        // column.
        let mut lp = lp_f64();
        let n = 2 * PAR_MIN_COLS;
        for v in 0..n {
            let c = ((v * 7) % 5) as f64 - 2.0;
            lp.add_var(format!("v{v}"), c);
        }
        for v in 0..n {
            lp.constrain(vec![(v, 1.0)], Cmp::Le, 3.0);
        }
        let coupling: Vec<(usize, f64)> = (0..n).map(|v| (v, 1.0)).collect();
        lp.constrain(coupling, Cmp::Ge, 5.0);
        let serial = solve(&lp).unwrap();
        assert!(lp.is_feasible(&serial.values));
        for threads in [2usize, 3, 8] {
            let sharded = solve_with_threads(&lp, threads).unwrap();
            assert_eq!(
                serial.objective.to_bits(),
                sharded.objective.to_bits(),
                "threads={threads}: objective"
            );
            assert_eq!(serial.pivots, sharded.pivots, "threads={threads}: pivots");
            assert_eq!(
                serial.eta_applications, sharded.eta_applications,
                "threads={threads}: eta work"
            );
            assert_eq!(
                serial.reinversions, sharded.reinversions,
                "threads={threads}: reinversions"
            );
            for (v, (a, b)) in serial.values.iter().zip(&sharded.values).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}: value {v}");
            }
            for (r, (a, b)) in serial.duals.iter().zip(&sharded.duals).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}: dual {r}");
            }
        }
    }

    #[test]
    fn reinversion_reproduces_the_default_solution() {
        // Force a refactorization every 2 pivots: the rebuilt eta file
        // represents the same B⁻¹, so in exact arithmetic the pivot walk
        // and solution are unchanged bit for bit; in f64 the result
        // stays feasible and optimal within tolerance.
        let mut lpr: Lp<Rat> = Lp::new();
        let mut lpf = lp_f64();
        let costs = [2i128, 3, 4, 5];
        for (v, c) in costs.iter().enumerate() {
            lpr.add_var(format!("v{v}"), Rat::int(*c));
            lpf.add_var(format!("v{v}"), *c as f64);
        }
        let rows: [(&[usize], i128); 4] = [
            (&[0, 1, 2, 3], 10),
            (&[0, 1], 4),
            (&[2, 3], 3),
            (&[1, 2], 5),
        ];
        for (vs, rhs) in rows {
            lpr.constrain(
                vs.iter().map(|v| (*v, Rat::int(1))).collect(),
                Cmp::Ge,
                Rat::int(rhs),
            );
            lpf.constrain(vs.iter().map(|v| (*v, 1.0)).collect(), Cmp::Ge, rhs as f64);
        }
        let base = solve(&lpr).unwrap();
        assert_eq!(base.reinversions, 0, "default period fired on a tiny LP");
        let reinv = solve_inner(&lpr, 1, 2).unwrap();
        assert!(reinv.reinversions > 0, "reinversion never triggered");
        assert_eq!(base.objective, reinv.objective);
        assert_eq!(base.values, reinv.values);
        assert_eq!(base.pivots, reinv.pivots);
        let f = solve_inner(&lpf, 1, 2).unwrap();
        assert!(f.reinversions > 0);
        assert!((f.objective - base.objective.to_f64()).abs() < 1e-6);
        assert!(lpf.is_feasible(&f.values));
    }

    #[test]
    fn degenerate_lp_terminates_and_matches_oracle() {
        // Pile redundant binding rows on one vertex so most ratio tests
        // return zero: the Dantzig walk must still terminate (the stall
        // governor caps degenerate runs) and agree with the exact field.
        let mut lpf = lp_f64();
        let mut lpr: Lp<Rat> = Lp::new();
        for v in 0..3 {
            lpf.add_var(format!("v{v}"), -1.0);
            lpr.add_var(format!("v{v}"), Rat::int(-1));
        }
        for _ in 0..5 {
            lpf.constrain(vec![(0, 1.0), (1, 1.0), (2, 1.0)], Cmp::Le, 4.0);
            lpr.constrain(
                vec![(0, Rat::int(1)), (1, Rat::int(1)), (2, Rat::int(1))],
                Cmp::Le,
                Rat::int(4),
            );
        }
        lpf.constrain(vec![(0, 1.0), (1, 2.0)], Cmp::Le, 4.0);
        lpr.constrain(vec![(0, Rat::int(1)), (1, Rat::int(2))], Cmp::Le, Rat::int(4));
        let sf = solve(&lpf).unwrap();
        let sr = solve(&lpr).unwrap();
        assert!((sf.objective - sr.objective.to_f64()).abs() < 1e-9);
        assert!(lpf.is_feasible(&sf.values));
        assert_eq!(sr.objective, Rat::int(-4));
    }

    #[test]
    fn ray_noise_is_an_f64_only_tolerance() {
        // The ray guard must treat noise-level f64 reduced costs as
        // non-rays while exact rationals always certify theirs.
        assert!((-1e-7f64).is_ray_noise());
        assert!(0.5f64.is_ray_noise());
        assert!(!(-1e-3f64).is_ray_noise());
        assert!(!Rat::new(-1, 1_000_000_000).is_ray_noise());
        assert!(!Rat::int(-1).is_ray_noise());
    }

    #[test]
    fn prop_f64_matches_exact_rational_on_random_small_lps() {
        prop::run("simplex f64 == exact", 150, |g| {
            let n = g.usize_in(1..=4);
            let m = g.usize_in(1..=4);
            let mut lpf = lp_f64();
            let mut lpr: Lp<Rat> = Lp::new();
            for v in 0..n {
                let c = g.u64_in(0..=6) as i64 - 2;
                lpf.add_var(format!("v{v}"), c as f64);
                lpr.add_var(format!("v{v}"), Rat::int(c as i128));
            }
            for _ in 0..m {
                let mut cf = Vec::new();
                let mut cr = Vec::new();
                for v in 0..n {
                    let a = g.u64_in(0..=4) as i64 - 1;
                    if a != 0 {
                        cf.push((v, a as f64));
                        cr.push((v, Rat::int(a as i128)));
                    }
                }
                let rhs = g.u64_in(0..=10) as i64 - 2;
                let cmp = *g.pick(&[Cmp::Le, Cmp::Ge, Cmp::Eq]);
                lpf.constrain(cf, cmp, rhs as f64);
                lpr.constrain(cr, cmp, Rat::int(rhs as i128));
            }
            // Bound all vars so unbounded cases are rare but still handled.
            for v in 0..n {
                lpf.constrain(vec![(v, 1.0)], Cmp::Le, 50.0);
                lpr.constrain(vec![(v, Rat::int(1))], Cmp::Le, Rat::int(50));
            }
            match (solve(&lpf), solve(&lpr)) {
                (Ok(sf), Ok(sr)) => {
                    let agree = (sf.objective - sr.objective.to_f64()).abs() < 1e-6;
                    let feas = lpf.is_feasible(&sf.values) && lpr.is_feasible(&sr.values);
                    prop::check(
                        agree && feas,
                        format!(
                            "obj f64={} exact={} feas={feas}",
                            sf.objective,
                            sr.objective.to_f64()
                        ),
                    )
                }
                (Err(a), Err(b)) => prop::check(a == b, format!("{a:?} vs {b:?}")),
                (a, b) => prop::fail(format!("divergent outcomes: f64={a:?} exact={b:?}")),
            }
        });
    }
}
