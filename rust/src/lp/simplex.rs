//! Two-phase dense primal simplex with Bland's anti-cycling rule.
//!
//! Generic over [`Scalar`], so the same code runs in `f64` (production) and
//! exact rationals (test oracle). Solves
//!
//! ```text
//! min c'x  s.t.  A x {<=,=,>=} b,  x >= 0
//! ```
//!
//! Phase 1 minimizes the sum of artificial variables to find a basic
//! feasible solution; phase 2 optimizes the real objective. Bland's rule
//! (smallest-index entering/leaving) guarantees termination.
//!
//! [`solve_with_threads`] shards the entering-variable pricing scan over
//! contiguous column chunks on scoped worker threads. Each chunk reports
//! its first negative-reduced-cost column and the lowest index wins, so
//! the entering column — and therefore the entire pivot sequence, basis,
//! and solution — is **bit-identical** to the serial scan for every
//! thread count. Per-column arithmetic is shared between the serial and
//! sharded paths (same fold order, same zero-cost skips), so chunking
//! cannot perturb a single float.

use super::problem::{Cmp, Lp, Scalar};

/// Entering-variable pricing floor: below this many candidate columns a
/// sharded scan costs more in thread spawns than it saves.
const PAR_MIN_COLS: usize = 128;

#[derive(Clone, Debug, PartialEq)]
pub enum LpError {
    Infeasible,
    Unbounded,
}

impl std::fmt::Display for LpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LpError::Infeasible => write!(f, "LP is infeasible"),
            LpError::Unbounded => write!(f, "LP is unbounded"),
        }
    }
}

impl std::error::Error for LpError {}

#[derive(Clone, Debug)]
pub struct Solution<S> {
    pub objective: S,
    /// Values of the original variables.
    pub values: Vec<S>,
    /// Simplex pivots performed (both phases) — used by bench_simplex.
    pub pivots: usize,
}

struct Tableau<S> {
    /// `rows x cols` coefficient matrix; last column is the RHS.
    a: Vec<Vec<S>>,
    /// Basis variable per row.
    basis: Vec<usize>,
    rows: usize,
    cols: usize, // total columns incl. rhs
}

impl<S: Scalar> Tableau<S> {
    fn rhs(&self, r: usize) -> &S {
        &self.a[r][self.cols - 1]
    }

    fn pivot(&mut self, r: usize, c: usize) {
        let piv = self.a[r][c].clone();
        debug_assert!(!piv.is_zero());
        for j in 0..self.cols {
            self.a[r][j] = self.a[r][j].div(&piv);
        }
        for i in 0..self.rows {
            if i != r && !self.a[i][c].is_zero() {
                let factor = self.a[i][c].clone();
                for j in 0..self.cols {
                    let delta = factor.mul(&self.a[r][j]);
                    self.a[i][j] = self.a[i][j].sub(&delta);
                }
            }
        }
        self.basis[r] = c;
    }

    /// Reduced cost `c_j − z_j` of column `j` under `cost`, with
    /// `z_j = Σ_i c_B[i]·a[i][j]` folded in row order, skipping zero
    /// basis costs. The serial and sharded pricing scans both call this,
    /// so chunking cannot change a bit of any column's value.
    fn reduced_cost(&self, cost: &[S], j: usize) -> S {
        let mut zj = S::zero();
        for i in 0..self.rows {
            let cb = &cost[self.basis[i]];
            if !cb.is_zero() {
                zj = zj.add(&cb.mul(&self.a[i][j]));
            }
        }
        cost[j].sub(&zj)
    }

    /// Bland pricing: the first column in `0..limit` with negative
    /// reduced cost, or `None` at optimality. `threads > 1` shards the
    /// scan over contiguous column chunks on scoped workers; each chunk
    /// reports its own first hit and the lowest index wins regardless of
    /// chunking, so the entering column equals the serial scan's.
    ///
    /// Bland's rule usually enters at a low index, so the first chunk is
    /// scanned serially before paying for any thread spawn — most pivots
    /// resolve without fanning out, and the fan-out (which cannot early-
    /// exit across chunks) only runs when the low columns are all priced
    /// out. Either path computes each column identically, so the result
    /// is the same column (or None) in every configuration.
    fn price_entering(&self, cost: &[S], limit: usize, threads: usize) -> Option<usize> {
        if threads <= 1 || limit < PAR_MIN_COLS {
            return (0..limit).find(|&j| self.reduced_cost(cost, j).is_neg());
        }
        let workers = threads.min(limit);
        let chunk = limit.div_ceil(workers);
        if let Some(j) = (0..chunk).find(|&j| self.reduced_cost(cost, j).is_neg()) {
            return Some(j);
        }
        let mut firsts: Vec<Option<usize>> = vec![None; workers - 1];
        // lint: allow(unordered-merge): each worker writes its own chunk slot; min() over slots is finish-order independent
        std::thread::scope(|s| {
            for (w, slot) in firsts.iter_mut().enumerate() {
                let lo = (w + 1) * chunk;
                let hi = ((w + 2) * chunk).min(limit);
                let tab = &*self;
                s.spawn(move || {
                    *slot = (lo..hi).find(|&j| tab.reduced_cost(cost, j).is_neg());
                });
            }
        });
        firsts.into_iter().flatten().min()
    }

    /// Minimize `cost` (length cols-1) over the columns `0..limit`
    /// starting from the current basis, pricing with up to `threads`
    /// workers. Returns (objective value, pivots) or Unbounded.
    fn optimize(
        &mut self,
        cost: &[S],
        limit: usize,
        threads: usize,
    ) -> Result<(S, usize), LpError> {
        let mut pivots = 0usize;
        loop {
            // Entering column: reduced cost c_j − z_j < 0 (minimization),
            // smallest index first (Bland).
            let entering = self.price_entering(cost, limit, threads);
            let Some(c) = entering else {
                // Optimal: objective = sum_i cost[basis[i]] * rhs[i].
                let mut obj = S::zero();
                for i in 0..self.rows {
                    obj = obj.add(&cost[self.basis[i]].mul(self.rhs(i)));
                }
                return Ok((obj, pivots));
            };
            // Ratio test (Bland tie-break on smallest basis index).
            let mut leave: Option<(usize, S)> = None;
            for i in 0..self.rows {
                if self.a[i][c].is_pos() {
                    let ratio = self.rhs(i).div(&self.a[i][c]);
                    let better = match &leave {
                        None => true,
                        Some((li, lr)) => {
                            let diff = ratio.sub(lr);
                            diff.is_neg()
                                || (diff.is_zero() && self.basis[i] < self.basis[*li])
                        }
                    };
                    if better {
                        leave = Some((i, ratio));
                    }
                }
            }
            let Some((r, _)) = leave else {
                return Err(LpError::Unbounded);
            };
            self.pivot(r, c);
            pivots += 1;
        }
    }
}

/// Solve the LP serially. See module docs.
pub fn solve<S: Scalar>(lp: &Lp<S>) -> Result<Solution<S>, LpError> {
    solve_with_threads(lp, 1)
}

/// Solve the LP with the entering-variable pricing scan sharded across
/// up to `threads` scoped workers (`<= 1` = serial). The returned basis,
/// objective, values, and pivot count are **bit-identical** to
/// [`solve`] for every thread count — sharding changes wall-clock only.
pub fn solve_with_threads<S: Scalar>(lp: &Lp<S>, threads: usize) -> Result<Solution<S>, LpError> {
    let n = lp.n_vars;
    let m = lp.constraints.len();

    // Column layout: [original n] [slack/surplus per row as needed] [artificials] [rhs]
    let mut n_slack = 0usize;
    for c in &lp.constraints {
        if matches!(c.cmp, Cmp::Le | Cmp::Ge) {
            n_slack += 1;
        }
    }
    // Artificials: Ge and Eq rows always; Le rows only if rhs < 0 after
    // normalization (we instead normalize rows so rhs >= 0 first).
    // Build dense rows with rhs >= 0.
    let mut rows: Vec<(Vec<S>, Cmp, S)> = Vec::with_capacity(m);
    for c in &lp.constraints {
        let mut row = vec![S::zero(); n];
        for (i, a) in &c.coeffs {
            row[*i] = row[*i].add(a);
        }
        let (row, cmp, rhs) = if c.rhs.is_neg() {
            let flipped = match c.cmp {
                Cmp::Le => Cmp::Ge,
                Cmp::Ge => Cmp::Le,
                Cmp::Eq => Cmp::Eq,
            };
            (
                row.iter().map(|x| x.neg()).collect::<Vec<_>>(),
                flipped,
                c.rhs.neg(),
            )
        } else {
            (row, c.cmp, c.rhs.clone())
        };
        rows.push((row, cmp, rhs));
    }

    let mut n_artif = 0usize;
    for (_, cmp, _) in &rows {
        if matches!(cmp, Cmp::Ge | Cmp::Eq) {
            n_artif += 1;
        }
    }
    let total = n + n_slack + n_artif;
    let cols = total + 1;

    let mut a = vec![vec![S::zero(); cols]; m];
    let mut basis = vec![0usize; m];
    let mut slack_idx = n;
    let mut artif_idx = n + n_slack;
    let artif_start = n + n_slack;
    for (i, (row, cmp, rhs)) in rows.iter().enumerate() {
        for j in 0..n {
            a[i][j] = row[j].clone();
        }
        a[i][cols - 1] = rhs.clone();
        match cmp {
            Cmp::Le => {
                a[i][slack_idx] = S::one();
                basis[i] = slack_idx;
                slack_idx += 1;
            }
            Cmp::Ge => {
                a[i][slack_idx] = S::one().neg();
                slack_idx += 1;
                a[i][artif_idx] = S::one();
                basis[i] = artif_idx;
                artif_idx += 1;
            }
            Cmp::Eq => {
                a[i][artif_idx] = S::one();
                basis[i] = artif_idx;
                artif_idx += 1;
            }
        }
    }

    let mut tab = Tableau {
        a,
        basis,
        rows: m,
        cols,
    };

    let mut total_pivots = 0usize;

    // Phase 1.
    if n_artif > 0 {
        let mut cost1 = vec![S::zero(); total];
        for item in cost1.iter_mut().take(total).skip(artif_start) {
            *item = S::one();
        }
        let (obj1, p1) = tab.optimize(&cost1, total, threads)?;
        total_pivots += p1;
        if obj1.is_pos() {
            return Err(LpError::Infeasible);
        }
        // Drive any artificial still in the basis out (degenerate rows).
        for i in 0..m {
            if tab.basis[i] >= artif_start {
                // Find a non-artificial column with nonzero coefficient.
                let mut found = None;
                for j in 0..artif_start {
                    if !tab.a[i][j].is_zero() {
                        found = Some(j);
                        break;
                    }
                }
                if let Some(j) = found {
                    tab.pivot(i, j);
                    total_pivots += 1;
                }
                // else: the row is all-zero over real columns — redundant
                // constraint; leave the artificial basic at value 0.
            }
        }
    }

    // Phase 2: minimize real objective; artificial columns are barred.
    let mut cost2 = vec![S::zero(); total];
    for j in 0..n {
        cost2[j] = lp.objective[j].clone();
    }
    let (obj, p2) = tab.optimize(&cost2, artif_start, threads)?;
    total_pivots += p2;

    let mut values = vec![S::zero(); n];
    for i in 0..m {
        if tab.basis[i] < n {
            values[tab.basis[i]] = tab.rhs(i).clone();
        }
    }
    Ok(Solution {
        objective: obj,
        values,
        pivots: total_pivots,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::rational::Rat;
    use crate::prop;

    fn lp_f64() -> Lp<f64> {
        Lp::new()
    }

    #[test]
    fn simple_minimization() {
        // min x + y s.t. x + y >= 4, x <= 3 -> obj 4.
        let mut lp = lp_f64();
        let x = lp.add_var("x", 1.0);
        let y = lp.add_var("y", 1.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 4.0);
        lp.constrain(vec![(x, 1.0)], Cmp::Le, 3.0);
        let sol = solve(&lp).unwrap();
        assert!((sol.objective - 4.0).abs() < 1e-9);
        assert!(lp.is_feasible(&sol.values));
    }

    #[test]
    fn maximization_via_negated_cost() {
        // max 3x + 2y s.t. x + y <= 4, x + 3y <= 6 -> x=4, y=0, obj 12.
        let mut lp = lp_f64();
        let x = lp.add_var("x", -3.0);
        let y = lp.add_var("y", -2.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Le, 4.0);
        lp.constrain(vec![(x, 1.0), (y, 3.0)], Cmp::Le, 6.0);
        let sol = solve(&lp).unwrap();
        assert!((sol.objective + 12.0).abs() < 1e-9);
        assert!((sol.values[0] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn equality_constraints() {
        // min 2x + 3y s.t. x + y = 10, x - y = 2 -> x=6, y=4, obj 24.
        let mut lp = lp_f64();
        let x = lp.add_var("x", 2.0);
        let y = lp.add_var("y", 3.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 10.0);
        lp.constrain(vec![(x, 1.0), (y, -1.0)], Cmp::Eq, 2.0);
        let sol = solve(&lp).unwrap();
        assert!((sol.objective - 24.0).abs() < 1e-9);
        assert!((sol.values[0] - 6.0).abs() < 1e-9);
        assert!((sol.values[1] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn detects_infeasible() {
        let mut lp = lp_f64();
        let x = lp.add_var("x", 1.0);
        lp.constrain(vec![(x, 1.0)], Cmp::Le, 1.0);
        lp.constrain(vec![(x, 1.0)], Cmp::Ge, 2.0);
        assert_eq!(solve(&lp).unwrap_err(), LpError::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        let mut lp = lp_f64();
        let x = lp.add_var("x", -1.0); // maximize x, no upper bound
        lp.constrain(vec![(x, 1.0)], Cmp::Ge, 0.0);
        assert_eq!(solve(&lp).unwrap_err(), LpError::Unbounded);
    }

    #[test]
    fn negative_rhs_normalization() {
        // x - y <= -2 with min x -> x=0, y>=2 feasible; obj 0.
        let mut lp = lp_f64();
        let x = lp.add_var("x", 1.0);
        let y = lp.add_var("y", 0.0);
        lp.constrain(vec![(x, 1.0), (y, -1.0)], Cmp::Le, -2.0);
        let sol = solve(&lp).unwrap();
        assert!(sol.objective.abs() < 1e-9);
        assert!(lp.is_feasible(&sol.values));
    }

    #[test]
    fn redundant_equalities_ok() {
        // x + y = 4 twice (redundant) — phase 1 leaves a zero artificial.
        let mut lp = lp_f64();
        let x = lp.add_var("x", 1.0);
        let y = lp.add_var("y", 1.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 4.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 4.0);
        let sol = solve(&lp).unwrap();
        assert!((sol.objective - 4.0).abs() < 1e-9);
    }

    #[test]
    fn exact_rational_solver_agrees() {
        // Same LP in both fields; rational is the oracle.
        let mut lpf = lp_f64();
        let mut lpr: Lp<Rat> = Lp::new();
        let xf = lpf.add_var("x", 1.0);
        let yf = lpf.add_var("y", 3.0);
        let xr = lpr.add_var("x", Rat::int(1));
        let yr = lpr.add_var("y", Rat::int(3));
        lpf.constrain(vec![(xf, 2.0), (yf, 1.0)], Cmp::Ge, 5.0);
        lpr.constrain(vec![(xr, Rat::int(2)), (yr, Rat::int(1))], Cmp::Ge, Rat::int(5));
        lpf.constrain(vec![(xf, 1.0)], Cmp::Le, 2.0);
        lpr.constrain(vec![(xr, Rat::int(1))], Cmp::Le, Rat::int(2));
        let sf = solve(&lpf).unwrap();
        let sr = solve(&lpr).unwrap();
        assert!((sf.objective - sr.objective.to_f64()).abs() < 1e-9);
        // optimum: x=2, y=1 -> obj 5.
        assert_eq!(sr.objective, Rat::int(5));
    }

    #[test]
    fn sharded_pricing_is_bit_identical_to_serial() {
        // Wide LP (past the PAR_MIN_COLS floor) so the sharded scan
        // actually engages: the basis walk, objective, values, and pivot
        // count must match the serial solve bit for bit at every thread
        // count — lowest qualifying index wins regardless of chunking.
        let mut lp = lp_f64();
        let n = 2 * PAR_MIN_COLS;
        for v in 0..n {
            let c = ((v * 7) % 5) as f64 - 2.0;
            lp.add_var(format!("v{v}"), c);
        }
        for v in 0..n {
            lp.constrain(vec![(v, 1.0)], Cmp::Le, 3.0);
        }
        let coupling: Vec<(usize, f64)> = (0..n).map(|v| (v, 1.0)).collect();
        lp.constrain(coupling, Cmp::Ge, 5.0);
        let serial = solve(&lp).unwrap();
        assert!(lp.is_feasible(&serial.values));
        for threads in [2usize, 3, 8] {
            let sharded = solve_with_threads(&lp, threads).unwrap();
            assert_eq!(
                serial.objective.to_bits(),
                sharded.objective.to_bits(),
                "threads={threads}: objective"
            );
            assert_eq!(serial.pivots, sharded.pivots, "threads={threads}: pivots");
            for (v, (a, b)) in serial.values.iter().zip(&sharded.values).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}: value {v}");
            }
        }
    }

    #[test]
    fn prop_f64_matches_exact_rational_on_random_small_lps() {
        prop::run("simplex f64 == exact", 150, |g| {
            let n = g.usize_in(1..=4);
            let m = g.usize_in(1..=4);
            let mut lpf = lp_f64();
            let mut lpr: Lp<Rat> = Lp::new();
            for v in 0..n {
                let c = g.u64_in(0..=6) as i64 - 2;
                lpf.add_var(format!("v{v}"), c as f64);
                lpr.add_var(format!("v{v}"), Rat::int(c as i128));
            }
            for _ in 0..m {
                let mut cf = Vec::new();
                let mut cr = Vec::new();
                for v in 0..n {
                    let a = g.u64_in(0..=4) as i64 - 1;
                    if a != 0 {
                        cf.push((v, a as f64));
                        cr.push((v, Rat::int(a as i128)));
                    }
                }
                let rhs = g.u64_in(0..=10) as i64 - 2;
                let cmp = *g.pick(&[Cmp::Le, Cmp::Ge, Cmp::Eq]);
                lpf.constrain(cf, cmp, rhs as f64);
                lpr.constrain(cr, cmp, Rat::int(rhs as i128));
            }
            // Bound all vars so unbounded cases are rare but still handled.
            for v in 0..n {
                lpf.constrain(vec![(v, 1.0)], Cmp::Le, 50.0);
                lpr.constrain(vec![(v, Rat::int(1))], Cmp::Le, Rat::int(50));
            }
            match (solve(&lpf), solve(&lpr)) {
                (Ok(sf), Ok(sr)) => {
                    let agree = (sf.objective - sr.objective.to_f64()).abs() < 1e-6;
                    let feas = lpf.is_feasible(&sf.values) && lpr.is_feasible(&sr.values);
                    prop::check(
                        agree && feas,
                        format!(
                            "obj f64={} exact={} feas={feas}",
                            sf.objective,
                            sr.objective.to_f64()
                        ),
                    )
                }
                (Err(a), Err(b)) => prop::check(a == b, format!("{a:?} vs {b:?}")),
                (a, b) => prop::fail(format!("divergent outcomes: f64={a:?} exact={b:?}")),
            }
        });
    }
}
