//! Exact rational arithmetic over `i128` for the exact simplex.
//!
//! Keeps fractions reduced with positive denominators. Overflow panics
//! (tests keep instances small; the f64 path handles production sizes).

use super::problem::Scalar;
use std::cmp::Ordering;
use std::fmt;

#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Rat {
    num: i128,
    den: i128, // > 0, gcd(num, den) == 1
}

fn gcd(mut a: i128, mut b: i128) -> i128 {
    a = a.abs();
    b = b.abs();
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl Rat {
    pub fn new(num: i128, den: i128) -> Self {
        assert!(den != 0, "zero denominator");
        let sign = if den < 0 { -1 } else { 1 };
        let (num, den) = (num * sign, den * sign);
        let g = gcd(num, den).max(1);
        Self {
            num: num / g,
            den: den / g,
        }
    }

    pub fn int(v: i128) -> Self {
        Self { num: v, den: 1 }
    }

    pub fn num(&self) -> i128 {
        self.num
    }

    pub fn den(&self) -> i128 {
        self.den
    }

    pub fn is_integer(&self) -> bool {
        self.den == 1
    }
}

impl fmt::Debug for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.den == 1 {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

impl fmt::Display for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

impl PartialOrd for Rat {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Rat {
    fn cmp(&self, other: &Self) -> Ordering {
        (self.num * other.den).cmp(&(other.num * self.den))
    }
}

impl Scalar for Rat {
    fn zero() -> Self {
        Rat::int(0)
    }
    fn one() -> Self {
        Rat::int(1)
    }
    fn from_i64(v: i64) -> Self {
        Rat::int(v as i128)
    }
    fn from_ratio(num: i64, den: i64) -> Self {
        Rat::new(num as i128, den as i128)
    }
    fn add(&self, o: &Self) -> Self {
        Rat::new(self.num * o.den + o.num * self.den, self.den * o.den)
    }
    fn sub(&self, o: &Self) -> Self {
        Rat::new(self.num * o.den - o.num * self.den, self.den * o.den)
    }
    fn mul(&self, o: &Self) -> Self {
        Rat::new(self.num * o.num, self.den * o.den)
    }
    fn div(&self, o: &Self) -> Self {
        assert!(o.num != 0, "division by zero");
        Rat::new(self.num * o.den, self.den * o.num)
    }
    fn neg(&self) -> Self {
        Rat {
            num: -self.num,
            den: self.den,
        }
    }
    fn is_pos(&self) -> bool {
        self.num > 0
    }
    fn is_neg(&self) -> bool {
        self.num < 0
    }
    fn lt(&self, o: &Self) -> bool {
        self < o
    }
    fn to_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn reduction_and_sign_normalization() {
        assert_eq!(Rat::new(2, 4), Rat::new(1, 2));
        assert_eq!(Rat::new(-2, -4), Rat::new(1, 2));
        assert_eq!(Rat::new(2, -4), Rat::new(-1, 2));
        assert!(Rat::new(0, 5).is_zero());
    }

    #[test]
    fn arithmetic() {
        let a = Rat::new(1, 2);
        let b = Rat::new(1, 3);
        assert_eq!(a.add(&b), Rat::new(5, 6));
        assert_eq!(a.sub(&b), Rat::new(1, 6));
        assert_eq!(a.mul(&b), Rat::new(1, 6));
        assert_eq!(a.div(&b), Rat::new(3, 2));
        assert_eq!(a.neg(), Rat::new(-1, 2));
    }

    #[test]
    fn ordering() {
        assert!(Rat::new(1, 3) < Rat::new(1, 2));
        assert!(Rat::new(-1, 2) < Rat::new(0, 1));
        assert_eq!(Rat::new(3, 3), Rat::int(1));
    }

    #[test]
    #[should_panic(expected = "zero denominator")]
    fn zero_denominator_panics() {
        Rat::new(1, 0);
    }

    #[test]
    fn prop_field_axioms_small() {
        prop::run("rat field axioms", 300, |g| {
            let r = |g: &mut prop::Gen| {
                Rat::new(g.u64_in(0..=40) as i128 - 20, g.u64_in(1..=12) as i128)
            };
            let (a, b, c) = (r(g), r(g), r(g));
            // associativity + commutativity + distributivity
            let assoc = a.add(&b.add(&c)) == a.add(&b).add(&c);
            let comm = a.mul(&b) == b.mul(&a);
            let dist = a.mul(&b.add(&c)) == a.mul(&b).add(&a.mul(&c));
            let inv = a.is_zero() || a.mul(&Rat::one().div(&a)) == Rat::one();
            prop::check(
                assoc && comm && dist && inv,
                format!("a={a:?} b={b:?} c={c:?}"),
            )
        });
    }

    #[test]
    fn to_f64_matches() {
        assert_eq!(Rat::new(3, 4).to_f64(), 0.75);
    }
}
