//! Linear-programming substrate, built from scratch for the §V algorithm.
//!
//! * [`problem`] — LP model builder, generic over the scalar field.
//! * [`simplex`] — two-phase dense primal simplex with Bland's rule.
//! * [`rational`] — exact `i128` rational arithmetic; instantiating the
//!   simplex at [`rational::Rat`] gives an exact solver used to validate
//!   the `f64` path in tests.

pub mod problem;
pub mod rational;
pub mod simplex;

pub use problem::{Cmp, Lp, Scalar};
pub use rational::Rat;
pub use simplex::{solve, solve_with_threads, LpError, Solution};
