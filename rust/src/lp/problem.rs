//! LP model representation, generic over the scalar field.
//!
//! All variables are implicitly non-negative (matching the paper's
//! `S_T >= 0`, `x_{jq} >= 0`); constraints are `<=`, `=`, or `>=` rows.

use std::fmt::Debug;

/// Scalar field abstraction: implemented for `f64` (tolerance-based) and
/// [`crate::lp::rational::Rat`] (exact). `Send + Sync` so tableaux can be
/// priced by sharded scans (see `simplex::solve_with_threads`).
pub trait Scalar: Clone + Debug + PartialEq + Send + Sync {
    fn zero() -> Self;
    fn one() -> Self;
    fn from_i64(v: i64) -> Self;
    /// `num / den` as a field element (den != 0).
    fn from_ratio(num: i64, den: i64) -> Self;
    fn add(&self, o: &Self) -> Self;
    fn sub(&self, o: &Self) -> Self;
    fn mul(&self, o: &Self) -> Self;
    fn div(&self, o: &Self) -> Self;
    fn neg(&self) -> Self;
    /// Strictly positive beyond tolerance.
    fn is_pos(&self) -> bool;
    /// Strictly negative beyond tolerance.
    fn is_neg(&self) -> bool;
    fn is_zero(&self) -> bool {
        !self.is_pos() && !self.is_neg()
    }
    /// Exact (tolerance-free) strict order `self < o`. Dantzig pricing and
    /// the Harris ratio test break ties with this: a tolerance-based
    /// comparison is not associative, so chunk-local winners merged across
    /// threads could disagree with a serial scan.
    fn lt(&self, o: &Self) -> bool;
    /// True when a reduced cost this close to zero cannot certify an
    /// unbounded ray (see the ray guard in `simplex`). Exact fields carry
    /// no rounding noise, so the default never skips a candidate ray.
    fn is_ray_noise(&self) -> bool {
        let _ = self;
        false
    }
    fn to_f64(&self) -> f64;
}

pub const F64_EPS: f64 = 1e-9;

/// Reduced costs in `(-F64_RAY_TOL, -F64_EPS]` are treated as rounding
/// noise by the unboundedness check (`Scalar::is_ray_noise`): a basic
/// free-variable pair can leave its negated twin with a noise-level
/// reduced cost whose FTRAN direction is exactly `-e_r`, which would
/// otherwise be mistaken for a ray.
pub const F64_RAY_TOL: f64 = 1e-6;

impl Scalar for f64 {
    fn zero() -> Self {
        0.0
    }
    fn one() -> Self {
        1.0
    }
    fn from_i64(v: i64) -> Self {
        v as f64
    }
    fn from_ratio(num: i64, den: i64) -> Self {
        num as f64 / den as f64
    }
    fn add(&self, o: &Self) -> Self {
        self + o
    }
    fn sub(&self, o: &Self) -> Self {
        self - o
    }
    fn mul(&self, o: &Self) -> Self {
        self * o
    }
    fn div(&self, o: &Self) -> Self {
        self / o
    }
    fn neg(&self) -> Self {
        -self
    }
    fn is_pos(&self) -> bool {
        *self > F64_EPS
    }
    fn is_neg(&self) -> bool {
        *self < -F64_EPS
    }
    fn lt(&self, o: &Self) -> bool {
        self < o
    }
    fn is_ray_noise(&self) -> bool {
        *self >= -F64_RAY_TOL
    }
    fn to_f64(&self) -> f64 {
        *self
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Eq,
    Ge,
}

#[derive(Clone, Debug)]
pub struct Constraint<S> {
    /// Sparse row: (variable index, coefficient).
    pub coeffs: Vec<(usize, S)>,
    pub cmp: Cmp,
    pub rhs: S,
}

/// Minimization LP over non-negative variables.
#[derive(Clone, Debug)]
pub struct Lp<S> {
    pub n_vars: usize,
    /// Objective coefficients (minimized), length `n_vars`.
    pub objective: Vec<S>,
    pub constraints: Vec<Constraint<S>>,
    pub var_names: Vec<String>,
}

impl<S: Scalar> Lp<S> {
    pub fn new() -> Self {
        Self {
            n_vars: 0,
            objective: Vec::new(),
            constraints: Vec::new(),
            var_names: Vec::new(),
        }
    }

    /// Add a variable with objective coefficient `cost`; returns its index.
    pub fn add_var(&mut self, name: impl Into<String>, cost: S) -> usize {
        let idx = self.n_vars;
        self.n_vars += 1;
        self.objective.push(cost);
        self.var_names.push(name.into());
        idx
    }

    pub fn set_cost(&mut self, var: usize, cost: S) {
        self.objective[var] = cost;
    }

    pub fn constrain(&mut self, coeffs: Vec<(usize, S)>, cmp: Cmp, rhs: S) {
        debug_assert!(coeffs.iter().all(|(i, _)| *i < self.n_vars));
        self.constraints.push(Constraint { coeffs, cmp, rhs });
    }

    /// Evaluate the objective at a point.
    pub fn objective_at(&self, x: &[S]) -> S {
        let mut acc = S::zero();
        for (c, xi) in self.objective.iter().zip(x) {
            acc = acc.add(&c.mul(xi));
        }
        acc
    }

    /// Check feasibility of a point (within scalar tolerance).
    pub fn is_feasible(&self, x: &[S]) -> bool {
        if x.len() != self.n_vars || x.iter().any(|v| v.is_neg()) {
            return false;
        }
        for c in &self.constraints {
            let mut lhs = S::zero();
            for (i, a) in &c.coeffs {
                lhs = lhs.add(&a.mul(&x[*i]));
            }
            let diff = lhs.sub(&c.rhs);
            let ok = match c.cmp {
                Cmp::Le => !diff.is_pos(),
                Cmp::Ge => !diff.is_neg(),
                Cmp::Eq => diff.is_zero(),
            };
            if !ok {
                return false;
            }
        }
        true
    }
}

impl<S: Scalar> Default for Lp<S> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_evaluate() {
        let mut lp: Lp<f64> = Lp::new();
        let x = lp.add_var("x", 1.0);
        let y = lp.add_var("y", 2.0);
        lp.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 4.0);
        lp.constrain(vec![(x, 1.0)], Cmp::Le, 3.0);
        assert_eq!(lp.n_vars, 2);
        assert_eq!(lp.objective_at(&[3.0, 1.0]), 5.0);
        assert!(lp.is_feasible(&[3.0, 1.0]));
        assert!(!lp.is_feasible(&[1.0, 1.0])); // violates >= 4
        assert!(!lp.is_feasible(&[4.0, 0.0])); // violates x <= 3
        assert!(!lp.is_feasible(&[-1.0, 6.0])); // negative var
    }
}
