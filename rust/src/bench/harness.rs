//! Minimal but honest timing harness: warmup, fixed-duration sampling,
//! summary statistics, and markdown table output — the pieces of
//! `criterion` the benches actually need, built from scratch.

use crate::util::stats::{fmt_ns, Summary};
use std::time::{Duration, Instant};

/// One benchmark's timing configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_samples: 10,
            max_samples: 100_000,
        }
    }
}

/// Timing result, printable as a one-line summary.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn throughput_per_s(&self) -> f64 {
        1e9 / self.mean_ns
    }

    /// Machine-readable form for bench artifacts (`hetcdc bench-json
    /// --timing`). Wall-clock numbers are inherently nondeterministic;
    /// regression gates must key on the byte/message metrics instead.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("samples".to_string(), Json::Num(self.samples as f64));
        m.insert("mean_ns".to_string(), Json::Num(self.mean_ns));
        m.insert("stddev_ns".to_string(), Json::Num(self.stddev_ns));
        m.insert("median_ns".to_string(), Json::Num(self.median_ns));
        m.insert("p95_ns".to_string(), Json::Num(self.p95_ns));
        Json::Obj(m)
    }

    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (median {}, p95 {}, n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            self.samples
        )
    }
}

/// Time `f` under `cfg`; prints and returns the result. `f` returns a
/// value which is black-boxed to keep the optimizer honest.
pub fn bench_fn<T>(name: &str, cfg: &Bench, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < cfg.warmup {
        std::hint::black_box(f());
    }
    // Measure.
    let mut samples = Summary::new();
    let start = Instant::now();
    while (start.elapsed() < cfg.measure || samples.count() < cfg.min_samples)
        && samples.count() < cfg.max_samples
    {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.add(t0.elapsed().as_nanos() as f64);
    }
    let result = BenchResult {
        name: name.to_string(),
        samples: samples.count(),
        mean_ns: samples.mean(),
        stddev_ns: samples.stddev(),
        median_ns: samples.median(),
        p95_ns: samples.percentile(95.0),
    };
    println!("{}", result.line());
    result
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a markdown table: header row + rows.
pub fn table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut line = String::from("|");
        for (i, cell) in cells.iter().enumerate() {
            line.push_str(&format!(" {:<w$} |", cell, w = widths[i]));
        }
        line
    };
    println!(
        "{}",
        fmt_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    println!("{}", fmt_row(&sep));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_collects_samples() {
        let cfg = Bench {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_samples: 5,
            max_samples: 10_000,
        };
        let r = bench_fn("noop", &cfg, || 1 + 1);
        assert!(r.samples >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.median_ns <= r.p95_ns);
    }

    #[test]
    fn bench_result_serializes() {
        let r = BenchResult {
            name: "x".into(),
            samples: 3,
            mean_ns: 10.0,
            stddev_ns: 1.0,
            median_ns: 9.0,
            p95_ns: 12.0,
        };
        let j = r.to_json();
        assert_eq!(j.get("name").and_then(|v| v.as_str()), Some("x"));
        assert_eq!(j.get("samples").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(j.get("p95_ns").and_then(|v| v.as_f64()), Some(12.0));
    }

    #[test]
    fn table_renders_without_panic() {
        table(
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
    }
}
