//! Benchmark harness substrate (no `criterion` in the offline build) and
//! the deterministic perf suite behind `hetcdc bench-json`.

pub mod harness;
pub mod suite;

pub use harness::{bench_fn, section, table, Bench, BenchResult};
pub use suite::{
    compare_to_baseline, default_suite, extended_suite, run_extended_suite_with, run_suite,
    run_suite_with, BaselineStatus, Comparison, PlanBuildStats, Scenario, ScenarioResult,
    SuiteReport,
};
