//! Benchmark harness substrate (no `criterion` in the offline build).

pub mod harness;

pub use harness::{bench_fn, section, table, Bench};
